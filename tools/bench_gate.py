#!/usr/bin/env python
"""Regression gate: fresh bench rows vs the committed BENCH_*.json.

    PYTHONPATH=src python tools/bench_gate.py --quick
    PYTHONPATH=src python tools/bench_gate.py --quick --tolerance 0.5
    PYTHONPATH=src python tools/bench_gate.py --serve-json /tmp/rows.json

Runs the benchmarks in-process at their CI-quick settings (kernel_bench
``reps=1``; serve_bench's mixed-load subset, 1 rep, no write) and
compares every row that exists in BOTH the fresh run and the committed
baseline, metric by metric, under a ONE-SIDED tolerance band:

  * throughput metrics (gen tok/s, total tok/s) regress when the fresh
    value falls below ``committed * (1 - tolerance)``;
  * latency/cost metrics (us_per_call, ITL percentiles, TTFT) regress
    when the fresh value rises above ``committed * (1 + tolerance)``
    plus a small per-metric absolute slack (``ABS_SLACK``) that keeps
    micro-scale rows from tripping on OS scheduler jitter.

One-sided because the committed numbers were measured on a quiet box
with full repeats and best-of/median aggregation, while the gate's quick
single-rep runs land on a noisy shared CI machine: the gate exists to
catch "this PR made serving 3x slower", not to re-certify the trajectory
(the full bench rewrites BENCH_*.json for that).  The default tolerance
is correspondingly wide.  Rows the fresh run produces that have NO
committed baseline are a hard failure — the committed file is stale and
needs a full bench run; so are baseline rows missing from a full fresh
dump (a scenario silently dropping out — quick SUBSET runs are exempt
from this direction, since a subset is a slice by construction).

``--serve-json``/``--kernels-json`` compare a pre-computed row dump
instead of re-running (rows under a ``{"rows": [...]}`` wrapper or a
bare list) — the hook for gating a full bench run's output.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)  # benchmarks package (repo root)

#: metric -> direction.  "higher" is better (regress when fresh is LOW),
#: "lower" is better (regress when fresh is HIGH).  Only metrics listed
#: here are gated; everything else in a row is descriptive.
METRICS: dict[str, str] = {
    "gen_tok_per_s": "higher",
    "total_tok_per_s": "higher",
    "us_per_call": "lower",
    "itl_p50_s": "lower",
    "itl_p95_s": "lower",
    "ttft_mean_s": "lower",
    "ttft_p50_s": "lower",
}

#: metric -> absolute slack ADDED to the one-sided band.  Micro-scale
#: rows (decode-shape kernel calls are ~50us) sit below the OS scheduler
#: jitter floor on a shared box, where a purely relative band flags
#: noise: 60us reading 110us is a quiet afternoon, 600us reading 1100us
#: is a real regression.  The slack is negligible against ms-scale rows,
#: so large rows are still gated by the relative band alone.
ABS_SLACK: dict[str, float] = {
    "us_per_call": 120.0,
}


def _rows(doc) -> dict[str, dict]:
    rows = doc.get("rows", doc) if isinstance(doc, dict) else doc
    return {r["name"]: r for r in rows}


def compare(fresh: dict[str, dict], base: dict[str, dict],
            tolerance: float, label: str) -> list[str]:
    """All gate violations between one fresh/baseline row set."""
    problems: list[str] = []
    for name in sorted(base):
        if name not in fresh:
            problems.append(f"{label}: baseline row {name!r} missing from "
                            "the fresh run (scenario dropped?)")
    for name in sorted(fresh):
        if name not in base:
            problems.append(f"{label}: fresh row {name!r} has no committed "
                            "baseline (run the full bench to refresh "
                            f"BENCH_{label}.json)")
    for name in sorted(set(fresh) & set(base)):
        f, b = fresh[name], base[name]
        for metric, direction in METRICS.items():
            fv, bv = f.get(metric), b.get(metric)
            if not (isinstance(fv, (int, float))
                    and isinstance(bv, (int, float))) or bv <= 0:
                continue
            if direction == "higher" and fv < bv * (1 - tolerance):
                problems.append(
                    f"{label}: {name} {metric} regressed: {fv:g} < "
                    f"{bv:g} * (1 - {tolerance:g})")
            elif (direction == "lower"
                  and fv > bv * (1 + tolerance) + ABS_SLACK.get(metric, 0.0)):
                problems.append(
                    f"{label}: {name} {metric} regressed: {fv:g} > "
                    f"{bv:g} * (1 + {tolerance:g})"
                    + (f" + {ABS_SLACK[metric]:g}" if metric in ABS_SLACK
                       else ""))
    return problems


def _fresh_serve_quick() -> dict[str, dict]:
    from benchmarks import serve_bench

    return _rows(serve_bench.run(reps=1, mixed_load_only=True, write=False))


def _fresh_kernels_quick() -> dict[str, dict]:
    from benchmarks import kernel_bench

    # reps=3, not 1: the decode-shape rows are ~50us, where a single rep
    # on a shared box can read 2x high; best-of-3 converges to within the
    # band while staying far cheaper than the committed reps=5 run
    return _rows(kernel_bench.run(reps=3, write=False))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate fresh bench rows against committed BENCH_*.json")
    ap.add_argument("--quick", action="store_true",
                    help="run the CI-quick benches in-process (kernel "
                         "reps=1 + serve mixed-load subset) and gate them")
    ap.add_argument("--serve-json", metavar="FILE",
                    help="gate these pre-computed serve rows instead of "
                         "running")
    ap.add_argument("--kernels-json", metavar="FILE",
                    help="gate these pre-computed kernel rows instead of "
                         "running")
    ap.add_argument("--tolerance", type=float, default=0.75,
                    help="one-sided relative band (default %(default)s: "
                         "quick single-rep runs on shared boxes are noisy; "
                         "the gate catches order-of-magnitude breaks)")
    ap.add_argument("--serve-baseline",
                    default=os.path.join(_ROOT, "BENCH_serve.json"))
    ap.add_argument("--kernels-baseline",
                    default=os.path.join(_ROOT, "BENCH_kernels.json"))
    args = ap.parse_args(argv)
    if not (args.quick or args.serve_json or args.kernels_json):
        ap.error("nothing to gate: pass --quick and/or --*-json inputs")

    # label, baseline path, fresh rows, subset?  (a quick run produces a
    # SLICE of the full row set, so "baseline row missing from fresh" is
    # expected there and only the fresh-side coverage is gated; a full
    # dump passed via --*-json is gated in both directions)
    jobs: list[tuple[str, str, dict[str, dict], bool]] = []
    if args.serve_json:
        with open(args.serve_json) as f:
            jobs.append(("serve", args.serve_baseline, _rows(json.load(f)),
                         False))
    if args.kernels_json:
        with open(args.kernels_json) as f:
            jobs.append(("kernels", args.kernels_baseline,
                         _rows(json.load(f)), False))
    if args.quick:
        jobs.append(("kernels", args.kernels_baseline,
                     _fresh_kernels_quick(), False))  # kernels have no subset
        jobs.append(("serve", args.serve_baseline, _fresh_serve_quick(),
                     True))

    problems: list[str] = []
    for label, base_path, fresh, subset in jobs:
        with open(base_path) as f:
            base = _rows(json.load(f))
        if subset:
            base = {n: r for n, r in base.items() if n in fresh}
        got = compare(fresh, base, args.tolerance, label)
        gated = sorted(set(fresh) & set(base))
        print(f"[bench_gate] {label}: {len(gated)} rows gated vs "
              f"{os.path.basename(base_path)} "
              f"(tolerance {args.tolerance:g}): "
              + ("OK" if not got else f"{len(got)} problem(s)"))
        problems += got
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
