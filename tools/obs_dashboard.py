#!/usr/bin/env python
"""Render serving traces as a static, self-contained HTML dashboard.

    python tools/obs_dashboard.py /tmp/trace.jsonl --out dash.html
    python tools/obs_dashboard.py --trace /tmp/fleet/trace-int8-0.jsonl \\
        --trace /tmp/fleet/trace-exact-0.jsonl --bench BENCH_serve.json \\
        --out fleet.html --assert-sections windows heatmap

One HTML file, no external assets, no JS dependencies — inline SVG for
the time-series and CSS-colored tables for everything else, so the file
opens anywhere (including CI artifact viewers).  Sections, each rendered
only when the trace carries its data:

  * **windows** — windowed gen tok/s and probe logits err-var series
    (``metrics_window`` spans);
  * **heatmap** — per-layer error-variance heatmap, layers x windows,
    log-scaled color (the ``probe_layers`` dict each window sample
    carries; JSONL traces only — the Chrome counter export drops nested
    args);
  * **governor** — accuracy-SLO governor switch history, including the
    breaching layer when a per-layer SLO drove the escalation;
  * **shadow** — A/B shadow replay rollup (token agreement, logit-delta
    stats, replay cost) plus any verdict rows from ``--verdict`` /
    ``--bench``;
  * **power** — modeled power attribution: token mix by numerics label
    and the traffic-weighted saving series.

Input is any trace ``tools/trace_report.py`` reads (JSONL or Chrome
JSON; several files merge into a fleet view).  ``--bench`` points at a
``BENCH_serve.json`` to surface its persisted ``serve/shadow/*`` verdict
rows; ``--verdict`` embeds one raw verdict JSON (the object
``ServingEngine.shadow_verdict()`` returns).  ``--assert-sections``
exits non-zero unless every named section rendered with data — the CI
smoke's dashboard gate.
"""

from __future__ import annotations

import argparse
import html
import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import trace_report  # noqa: E402  (same directory; reuse its loaders)

SECTIONS = ("windows", "heatmap", "governor", "shadow", "power")

_CSS = """
body { font-family: -apple-system, 'Segoe UI', sans-serif; margin: 2em;
       background: #fafafa; color: #222; }
h1 { font-size: 1.4em; } h2 { font-size: 1.1em; margin-top: 2em; }
table { border-collapse: collapse; font-size: 0.85em; }
th, td { border: 1px solid #ddd; padding: 3px 8px; text-align: right; }
th { background: #f0f0f0; } td.l { text-align: left; }
td.cell { min-width: 2.2em; text-align: center; color: #222; }
.verdict-adopt-shadow { background: #e6f4e6; }
.verdict-keep-primary { background: #fdf3e3; }
.muted { color: #888; font-size: 0.85em; }
svg { background: #fff; border: 1px solid #ddd; }
"""


def _collect_windows(events: list[dict]) -> list[dict]:
    """metrics_window samples in time order, engine label attached."""
    return [{**e["data"], "t": e["t"], "engine": e["engine"]}
            for e in events if e["kind"] == "metrics_window"]


def _svg_series(points: list[tuple[float, float]], title: str,
                unit: str = "", w: int = 640, h: int = 130) -> str:
    """One inline-SVG polyline chart (times on x, values on y)."""
    if not points:
        return ""
    pad = 8
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    xr = (x1 - x0) or 1.0
    yr = (y1 - y0) or 1.0

    def sx(x: float) -> float:
        return pad + (x - x0) / xr * (w - 2 * pad)

    def sy(y: float) -> float:
        return h - pad - (y - y0) / yr * (h - 2 * pad - 14)

    pts = " ".join(f"{sx(x):.1f},{sy(y):.1f}" for x, y in points)
    dots = "".join(f'<circle cx="{sx(x):.1f}" cy="{sy(y):.1f}" r="2.5" '
                   'fill="#1f77b4"/>' for x, y in points)
    return (
        f'<svg width="{w}" height="{h}" role="img">'
        f'<text x="{pad}" y="14" font-size="12" fill="#555">'
        f'{html.escape(title)} &#8212; min {y0:.4g} / max {y1:.4g} '
        f'{html.escape(unit)}</text>'
        f'<polyline points="{pts}" fill="none" stroke="#1f77b4" '
        'stroke-width="1.5"/>' + dots + "</svg>")


def _heat_color(v: float, lo: float, hi: float) -> str:
    """Log-scaled white -> red ramp (err variances span decades)."""
    if v <= 0:
        return "#ffffff"
    span = (hi - lo) or 1.0
    frac = min(1.0, max(0.0, (math.log10(v) - lo) / span))
    r, g, b = 255, round(245 - 205 * frac), round(240 - 220 * frac)
    return f"rgb({r},{g},{b})"


def _heatmap_html(windows: list[dict]) -> str:
    """Layers x windows err-var heatmap from the probe_layers samples."""
    sampled = [w for w in windows if w.get("probe_layers")]
    if not sampled:
        return ""
    layers = sorted({p for w in sampled for p in w["probe_layers"]})
    vals = [v for w in sampled for v in w["probe_layers"].values() if v > 0]
    lo = math.log10(min(vals)) if vals else 0.0
    hi = math.log10(max(vals)) if vals else 1.0
    head = "".join(f"<th>w{i}</th>" for i in range(len(sampled)))
    rows = []
    for path in layers:
        cells = []
        for w in sampled:
            v = w["probe_layers"].get(path)
            if v is None:
                cells.append('<td class="cell muted">&#183;</td>')
            else:
                cells.append(
                    f'<td class="cell" title="{v:.3g}" '
                    f'style="background:{_heat_color(v, lo, hi)}">'
                    f"{v:.0e}</td>")
        rows.append(f'<tr><td class="l">{html.escape(path)}</td>'
                    + "".join(cells) + "</tr>")
    return ("<h2>Per-layer error variance (heatmap)</h2>"
            f"<p class='muted'>{len(layers)} layers x {len(sampled)} "
            "windows; cell = that window's probe err-var, log-scaled "
            "color, hover for the value.</p>"
            f"<table><tr><th>layer</th>{head}</tr>{''.join(rows)}</table>")


def _governor_html(rep: dict) -> str:
    rb = rep.get("robustness") or {}
    switches = rb.get("governor_switches") or []
    if not switches:
        return ""
    rows = []
    for s in switches:
        ev = (f"{s['err_var']:.3e}" if isinstance(s.get("err_var"), float)
              else s.get("err_var"))
        rows.append(
            "<tr>"
            f"<td>{s.get('step')}</td><td class='l'>{s.get('action')}</td>"
            f"<td class='l'>{html.escape(str(s.get('from')))} &#8594; "
            f"{html.escape(str(s.get('to')))}</td>"
            f"<td class='l'>{html.escape(str(s.get('reason')))}</td>"
            f"<td class='l'>{html.escape(s['layer']) if s.get('layer') else '&#8212;'}</td>"
            f"<td>{ev}</td><td>{s.get('power_delta_pct')}%</td></tr>")
    return ("<h2>Governor switch history</h2>"
            "<table><tr><th>step</th><th>action</th><th>rung</th>"
            "<th>reason</th><th>layer</th><th>err_var</th>"
            f"<th>power &#916;</th></tr>{''.join(rows)}</table>")


def _shadow_html(rep: dict, verdicts: list[dict]) -> str:
    sh = rep.get("shadow")
    if not sh and not verdicts:
        return ""
    out = ["<h2>A/B shadow serving</h2>"]
    if sh:
        rate = (f"{sh['token_match_rate']:.2%}"
                if sh["token_match_rate"] is not None else "n/a")
        out.append(
            f"<p>{sh['replays']} replays, {sh['token_matches']}/"
            f"{sh['tokens']} tokens matched ({rate}), replay cost "
            f"{sh['replay_time_s']*1e3:.2f}ms total.</p>")
    if verdicts:
        rows = []
        for v in verdicts:
            cls = f"verdict-{v.get('verdict', '')}"
            rows.append(
                f"<tr class='{html.escape(cls)}'>"
                f"<td class='l'>{html.escape(str(v.get('primary')))}</td>"
                f"<td class='l'>{html.escape(str(v.get('shadow')))}</td>"
                f"<td>{v.get('sampled_requests')}</td>"
                f"<td>{v.get('token_match_rate')}</td>"
                f"<td>{v.get('logits_err_var'):.3g}</td>"
                f"<td>{v.get('power_delta_pct'):+g}pp</td>"
                f"<td class='l'><b>{html.escape(str(v.get('verdict')))}</b>"
                f"</td><td class='l'>{html.escape(str(v.get('reason')))}"
                "</td></tr>")
        out.append(
            "<table><tr><th>primary</th><th>shadow</th><th>sampled</th>"
            "<th>match rate</th><th>logits err-var</th>"
            "<th>power &#916;</th><th>verdict</th><th>reason</th></tr>"
            + "".join(rows) + "</table>")
    return "".join(out)


def _power_html(windows: list[dict]) -> str:
    powered = [w for w in windows if "modeled_power_saving_pct" in w]
    if not powered:
        return ""
    last = powered[-1]
    mix = last.get("tokens_by_numerics") or {}
    rows = "".join(
        f"<tr><td class='l'>{html.escape(str(k))}</td><td>{v}</td></tr>"
        for k, v in sorted(mix.items()))
    series = _svg_series(
        [(w["t"], w["modeled_power_saving_pct"]) for w in powered],
        "modeled power saving (traffic-weighted)", "%")
    return ("<h2>Modeled power attribution</h2>"
            f"<p>Latest window: {last['modeled_mac_units']:.3g} MAC-units "
            f"served, {last['modeled_mac_units_saved']:.3g} saved "
            f"(<b>{last['modeled_power_saving_pct']}%</b> modeled array-"
            "power saving, cost-model x live token mix).</p>"
            + (f"<table><tr><th>numerics</th><th>tokens (last window)</th>"
               f"</tr>{rows}</table>" if rows else "")
            + series)


def render(events: list[dict], verdicts: list[dict] | None = None,
           title: str = "repro serving dashboard") -> tuple[str, dict]:
    """Build the dashboard HTML; returns ``(html, rendered_sections)``."""
    rep = trace_report.report(events)
    windows = _collect_windows(events)
    tok = _svg_series([(w["t"], w["gen_tok_per_s"]) for w in windows
                       if "gen_tok_per_s" in w],
                      "generated tok/s (windowed)", "tok/s")
    perr = _svg_series([(w["t"], w["probe_logits_err_var"]) for w in windows
                        if "probe_logits_err_var" in w],
                       "probe logits err-var (windowed)")
    win_html = ""
    if tok or perr:
        win_html = "<h2>Windowed time-series</h2>" + tok + perr
    parts = {
        "windows": win_html,
        "heatmap": _heatmap_html(windows),
        "governor": _governor_html(rep),
        "shadow": _shadow_html(rep, verdicts or []),
        "power": _power_html(windows),
    }
    kinds = ", ".join(f"{k}={v}" for k, v in rep["kinds"].items())
    doc = (
        "<!doctype html><html><head><meta charset='utf-8'>"
        f"<title>{html.escape(title)}</title><style>{_CSS}</style></head>"
        f"<body><h1>{html.escape(title)}</h1>"
        f"<p class='muted'>{rep['events']} events "
        f"({len(rep['requests'])} requests): {html.escape(kinds)}</p>"
        + "".join(parts[s] for s in SECTIONS)
        + "</body></html>\n")
    return doc, {s: bool(parts[s]) for s in SECTIONS}


def _load_verdicts(verdict_path: str | None, bench_path: str | None) -> list[dict]:
    out: list[dict] = []
    if verdict_path:
        with open(verdict_path) as f:
            v = json.load(f)
        out.extend(v if isinstance(v, list) else [v])
    if bench_path:
        with open(bench_path) as f:
            doc = json.load(f)
        for row in doc.get("rows", []):
            if str(row.get("name", "")).startswith("serve/shadow"):
                out.append(row)
    return [v for v in out if v.get("verdict")]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render serving traces as a static HTML dashboard")
    ap.add_argument("trace", nargs="*",
                    help="trace file(s) written by --trace-out / --trace-dir")
    ap.add_argument("--trace", action="append", dest="traces", default=[],
                    metavar="FILE", help="additional trace file; repeatable")
    ap.add_argument("--out", default="obs_dashboard.html",
                    help="output HTML path (default: %(default)s)")
    ap.add_argument("--title", default="repro serving dashboard")
    ap.add_argument("--verdict", metavar="FILE",
                    help="shadow verdict JSON (ServingEngine.shadow_verdict)")
    ap.add_argument("--bench", metavar="FILE",
                    help="BENCH_serve.json; its serve/shadow/* verdict rows "
                         "are surfaced in the shadow section")
    ap.add_argument("--assert-sections", nargs="*", default=[],
                    choices=SECTIONS, metavar="SECTION",
                    help=f"fail unless these sections rendered {SECTIONS}")
    args = ap.parse_args(argv)
    paths = list(args.trace) + list(args.traces)
    if not paths:
        ap.error("no trace files given (positional or --trace)")
    events = trace_report.load_traces(paths)
    verdicts = _load_verdicts(args.verdict, args.bench)
    doc, rendered = render(events, verdicts, title=args.title)
    with open(args.out, "w") as f:
        f.write(doc)
    on = [s for s, ok in rendered.items() if ok]
    print(f"wrote {args.out} ({len(doc)} bytes; sections: "
          + (", ".join(on) if on else "none") + ")")
    missing = [s for s in args.assert_sections if not rendered[s]]
    if missing:
        print(f"FAIL: dashboard sections missing data: {missing}",
              file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
