#!/usr/bin/env bash
# CI smoke: tier-1 test suite + a ~30s reduced-model serving-engine smoke.
#
#   tools/ci_smoke.sh            # full tier-1 + engine smoke
#   SKIP_TESTS=1 tools/ci_smoke.sh   # engine smoke only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ -z "${SKIP_TESTS:-}" ]]; then
    echo "== tier-1 pytest =="
    python -m pytest -x -q
fi

echo "== numerics plan (declarative spec -> assignment table, no packing) =="
python -m repro.launch.serve plan --arch olmo-1b-reduced
python -m repro.launch.serve plan --arch olmo-1b-reduced --preset int8 --json > /dev/null

echo "== quickstart (spec/plan/apply public API) =="
python examples/quickstart.py

echo "== bench regression gate (quick kernel + mixed-load serve runs vs committed BENCH_*.json) =="
python tools/bench_gate.py --quick

echo "== serving-engine smoke (reduced model, approximate+CV) =="
python -m repro.launch.serve --engine --requests 8 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16

echo "== paged KV smoke (block_size=8, shared-prefix pair, prefix hit asserted) =="
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --kv-layout paged --block-size 8 --shared-prefix-pair

echo "== shared-prefix fleet bench (paged vs contiguous, 1 rep) =="
python -m benchmarks.serve_bench --paged-only --reps 1 --no-write

echo "== speculative serve smoke (approx drafts, exact verify, acceptance > 0 asserted) =="
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --speculative-k 4 --assert-acceptance

echo "== speculative serve bench (drafts vs plain exact decode, identity asserted, 1 rep) =="
python -m benchmarks.serve_bench --speculative-only --reps 1 --no-write

echo "== traced serve smoke (span trace + windowed metrics + error probe) =="
TRACE_OUT="$(mktemp -t repro_trace_XXXX.json)"
trap 'rm -f "$TRACE_OUT"' EXIT
python -m repro.launch.serve --engine --requests 8 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --trace-out "$TRACE_OUT" --metrics-window 0.2 --error-probe-every 2

echo "== trace report (>=1 span per lifecycle stage asserted) =="
python tools/trace_report.py "$TRACE_OUT" --assert-lifecycle

echo "== fault-injection smoke (NaN rows injected, quarantine + exact replay asserted) =="
FAULT_TRACE="$(mktemp -t repro_fault_trace_XXXX.jsonl)"
trap 'rm -f "$TRACE_OUT" "$FAULT_TRACE"' EXIT
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --preset int8 \
    --slots 4 --max-len 64 --chunk 16 \
    --inject-faults nan@3 --fault-seed 7 --trace-out "$FAULT_TRACE"

echo "== fault trace report (quarantine spans + lifecycle with new span kinds) =="
python tools/trace_report.py "$FAULT_TRACE" --assert-lifecycle --assert-quarantine

echo "== governor serve bench (SLO breach -> ladder escalation, 1 rep) =="
python -m benchmarks.serve_bench --governor-only --reps 1 --no-write

echo "== fleet smoke (2 numerics tiers, spec-aware routing, cross-replica prefix hit asserted) =="
FLEET_TRACE_DIR="$(mktemp -d -t repro_fleet_traces_XXXX)"
trap 'rm -f "$TRACE_OUT" "$FAULT_TRACE"; rm -rf "$FLEET_TRACE_DIR"' EXIT
python -m repro.launch.serve --engine --fleet \
    --arch olmo-1b-reduced \
    --tier int8=2 --tier serve-default=1 \
    --requests 6 --slots 4 --max-len 64 --chunk 16 \
    --kv-layout paged --block-size 8 \
    --assert-prefix-share --trace-dir "$FLEET_TRACE_DIR"

echo "== fleet trace report (per-replica traces merged, per-tier section) =="
python tools/trace_report.py "$FLEET_TRACE_DIR"/trace-*.jsonl --assert-lifecycle

echo "== fleet serve bench (2-tier fleet vs monolithic, token identity asserted, 1 rep) =="
python -m benchmarks.serve_bench --fleet-only --reps 1 --no-write

echo "== shadow A/B smoke (sampled teacher-forced replay, verdict asserted) + OpenMetrics export =="
PROM_OUT="$(mktemp -t repro_prom_XXXX.txt)"
OBS_TRACE="$(mktemp -t repro_obs_trace_XXXX.jsonl)"
DASH_OUT="$(mktemp -t repro_dash_XXXX.html)"
trap 'rm -f "$TRACE_OUT" "$FAULT_TRACE" "$PROM_OUT" "$OBS_TRACE" "$DASH_OUT"; rm -rf "$FLEET_TRACE_DIR"' EXIT
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --preset int8 \
    --slots 4 --max-len 64 --chunk 16 \
    --shadow-spec serve-default --shadow-fraction 0.5 --assert-shadow \
    --trace-out "$OBS_TRACE" --metrics-window 0.05 --error-probe-every 2 \
    --prom-out "$PROM_OUT"

echo "== OpenMetrics exposition (parse round-trip, required series asserted) =="
python -m repro.serving.prom "$PROM_OUT" \
    --require repro_generated_tokens repro_requests_finished repro_gen_tok_per_s

echo "== trace report --format json (shadow section present) =="
python tools/trace_report.py "$OBS_TRACE" --format json \
    | python -c "import json,sys; r=json.load(sys.stdin); assert r['shadow'] and r['shadow']['replays'] >= 1, r['shadow']"

echo "== observability dashboard (static HTML from the JSONL trace, sections asserted) =="
python tools/obs_dashboard.py "$OBS_TRACE" --out "$DASH_OUT" \
    --assert-sections windows heatmap shadow power

echo "== layer-SLO smoke (single-layer dense fault -> per-layer window err-var + named escalation) =="
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --governor --slo-err-var 100.0 --layer-slo 'blocks/0/*=1e-6' \
    --inject-faults 'dense-noise@1@blocks/0/*' --error-probe-every 2 \
    --metrics-window 0.05 --assert-layer-breach 'blocks/0/*'

echo "== shadow serve bench (verdict + exact-control null experiment, deterministic) =="
python -m benchmarks.serve_bench --shadow-only --reps 1 --no-write

echo "CI smoke OK"
