#!/usr/bin/env bash
# CI smoke: tier-1 test suite + a ~30s reduced-model serving-engine smoke.
#
#   tools/ci_smoke.sh            # full tier-1 + engine smoke
#   SKIP_TESTS=1 tools/ci_smoke.sh   # engine smoke only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ -z "${SKIP_TESTS:-}" ]]; then
    echo "== tier-1 pytest =="
    python -m pytest -x -q
fi

echo "== numerics plan (declarative spec -> assignment table, no packing) =="
python -m repro.launch.serve plan --arch olmo-1b-reduced
python -m repro.launch.serve plan --arch olmo-1b-reduced --preset int8 --json > /dev/null

echo "== quickstart (spec/plan/apply public API) =="
python examples/quickstart.py

echo "== kernel bench quick mode (1 rep; fails smoke on kernel-path breakage) =="
python -m benchmarks.kernel_bench --reps 1 --no-write > /dev/null

echo "== serving-engine smoke (reduced model, approximate+CV) =="
python -m repro.launch.serve --engine --requests 8 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16

echo "== mixed-load serve bench (decode stall p95, mixed on/off, 1 rep) =="
python -m benchmarks.serve_bench --mixed-load-only --reps 1 --no-write

echo "== paged KV smoke (block_size=8, shared-prefix pair, prefix hit asserted) =="
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --kv-layout paged --block-size 8 --shared-prefix-pair

echo "== shared-prefix fleet bench (paged vs contiguous, 1 rep) =="
python -m benchmarks.serve_bench --paged-only --reps 1 --no-write

echo "== speculative serve smoke (approx drafts, exact verify, acceptance > 0 asserted) =="
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --speculative-k 4 --assert-acceptance

echo "== speculative serve bench (drafts vs plain exact decode, identity asserted, 1 rep) =="
python -m benchmarks.serve_bench --speculative-only --reps 1 --no-write

echo "== traced serve smoke (span trace + windowed metrics + error probe) =="
TRACE_OUT="$(mktemp -t repro_trace_XXXX.json)"
trap 'rm -f "$TRACE_OUT"' EXIT
python -m repro.launch.serve --engine --requests 8 \
    --arch olmo-1b-reduced --mode perforated --m 2 \
    --slots 4 --max-len 64 --chunk 16 \
    --trace-out "$TRACE_OUT" --metrics-window 0.2 --error-probe-every 2

echo "== trace report (>=1 span per lifecycle stage asserted) =="
python tools/trace_report.py "$TRACE_OUT" --assert-lifecycle

echo "== fault-injection smoke (NaN rows injected, quarantine + exact replay asserted) =="
FAULT_TRACE="$(mktemp -t repro_fault_trace_XXXX.jsonl)"
trap 'rm -f "$TRACE_OUT" "$FAULT_TRACE"' EXIT
python -m repro.launch.serve --engine --requests 6 \
    --arch olmo-1b-reduced --preset int8 \
    --slots 4 --max-len 64 --chunk 16 \
    --inject-faults nan@3 --fault-seed 7 --trace-out "$FAULT_TRACE"

echo "== fault trace report (quarantine spans + lifecycle with new span kinds) =="
python tools/trace_report.py "$FAULT_TRACE" --assert-lifecycle --assert-quarantine

echo "== governor serve bench (SLO breach -> ladder escalation, 1 rep) =="
python -m benchmarks.serve_bench --governor-only --reps 1 --no-write

echo "== fleet smoke (2 numerics tiers, spec-aware routing, cross-replica prefix hit asserted) =="
FLEET_TRACE_DIR="$(mktemp -d -t repro_fleet_traces_XXXX)"
trap 'rm -f "$TRACE_OUT" "$FAULT_TRACE"; rm -rf "$FLEET_TRACE_DIR"' EXIT
python -m repro.launch.serve --engine --fleet \
    --arch olmo-1b-reduced \
    --tier int8=2 --tier serve-default=1 \
    --requests 6 --slots 4 --max-len 64 --chunk 16 \
    --kv-layout paged --block-size 8 \
    --assert-prefix-share --trace-dir "$FLEET_TRACE_DIR"

echo "== fleet trace report (per-replica traces merged, per-tier section) =="
python tools/trace_report.py "$FLEET_TRACE_DIR"/trace-*.jsonl --assert-lifecycle

echo "== fleet serve bench (2-tier fleet vs monolithic, token identity asserted, 1 rep) =="
python -m benchmarks.serve_bench --fleet-only --reps 1 --no-write

echo "CI smoke OK"
