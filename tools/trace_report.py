#!/usr/bin/env python
"""Summarize serving span traces (JSONL or Chrome trace_event JSON).

    python tools/trace_report.py /tmp/trace.json
    python tools/trace_report.py /tmp/trace.jsonl --format json
    python tools/trace_report.py /tmp/trace.json --assert-lifecycle
    python tools/trace_report.py --trace /tmp/fleet/trace-int8-0.jsonl \\
        --trace /tmp/fleet/trace-int8-1.jsonl ...

Reads either export format of ``repro.serving.telemetry.SpanTracer`` and
prints:

  * per-request timelines — queue wait, prefill chunks, decode steps,
    end-to-end span, finish reason; under speculative decode also the
    per-request draft rounds and acceptance rate (reconstructed from the
    ``draft``/``verify`` spans alone);
  * a speculative summary — trace-wide drafted/accepted counts and the
    acceptance rate, the draft-quality signal for the approximate spec;
  * stall attribution — the largest inter-decode-step gaps per request,
    attributed to prefill interference (another request's chunk ran in
    the gap), capacity stalls, an error-probe forward, an A/B shadow
    replay, or scheduler idle time;
  * probe error trend — the approximation-error probe's logits/layer
    error variance over time (first vs last, min/max);
  * shadow A/B — sampled replays through the second numerics pack:
    token agreement, logit-delta stats, and replay cost (``shadow``
    spans; see repro.serving.shadow);
  * windowed counters — min/median/max of the windowed gen tok/s series;
  * robustness — governor ladder switches (from/to rung, reason, cost-model
    power delta), detected faults, quarantine replays, and deadline
    evictions, when the trace carries any (old traces without the PR 8
    span kinds still load and report).

Fleet traces: pass several files (repeatable ``--trace FILE``, e.g. the
per-replica JSONLs ``FleetRouter.write_traces`` emits).  With more than
one trace, request ids are prefixed with the replica's engine id
(``"int8:0:7"`` — engine request counters are per-replica, so bare rids
collide across a fleet) and the report gains a **fleet** section:
per-tier request counts, routed classes/spills, TTFT, speculative
acceptance, prefix imports, and capacity-stall attribution.
Single-trace invocations are unchanged.

``--assert-lifecycle`` exits non-zero unless the trace holds at least one
span of every request-lifecycle stage (queued, admitted, prefill_chunk,
decode_step, finished) — the CI smoke's trace-integrity gate.
``--assert-quarantine`` exits non-zero unless every ``fault_detected``
span is matched by a ``quarantine`` span (the fault-injection smoke's
no-corrupted-emission gate; also requires >= 1 of each).
"""

from __future__ import annotations

import argparse
import collections
import json
import sys

LIFECYCLE = ("queued", "admitted", "prefill_chunk", "decode_step", "finished")


def load_events(path: str) -> list[dict]:
    """Normalize either export format to
    ``{kind, rid, t (s), dur (s), engine, data}`` sorted by time."""
    with open(path) as f:
        text = f.read()
    events: list[dict] = []
    try:
        doc = json.loads(text)  # Chrome trace is one JSON document
    except json.JSONDecodeError:
        doc = None  # JSONL: one object per line
    if isinstance(doc, dict) and "traceEvents" in doc:
        engine = (doc.get("otherData") or {}).get("engine")
        for e in doc["traceEvents"]:
            if e.get("ph") == "M":  # metadata (process/thread names)
                continue
            data = dict(e.get("args") or {})
            rid = data.pop("rid", None)
            events.append({"kind": e["name"], "rid": rid,
                           "t": e.get("ts", 0.0) / 1e6,
                           "dur": e.get("dur", 0.0) / 1e6,
                           "engine": engine, "data": data})
    else:
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            data = {k: v for k, v in d.items()
                    if k not in ("engine", "kind", "rid", "t", "dur")}
            events.append({"kind": d["kind"], "rid": d.get("rid"),
                           "t": d["t"], "dur": d.get("dur", 0.0),
                           "engine": d.get("engine"), "data": data})
    events.sort(key=lambda e: e["t"])
    return events


def load_traces(paths: list[str]) -> list[dict]:
    """Load and merge several traces (a fleet's per-replica files).

    With more than one file, every request id is prefixed with its
    replica's engine id — each engine numbers requests independently, so
    bare rids collide across a fleet; ``"<engine>:<rid>"`` keeps every
    request's timeline distinct.  One file behaves exactly like
    :func:`load_events` (integer rids, identical report)."""
    events: list[dict] = []
    for i, path in enumerate(paths):
        evs = load_events(path)
        if len(paths) > 1:
            for e in evs:
                if e["rid"] is not None:
                    e["rid"] = f"{e['engine'] or f'trace{i}'}:{e['rid']}"
        events.extend(evs)
    events.sort(key=lambda e: e["t"])
    return events


def _request_timelines(events: list[dict]) -> dict:
    reqs: dict[int, dict] = {}
    for e in events:
        rid = e["rid"]
        if rid is None:
            continue
        r = reqs.setdefault(rid, {
            "queued_t": None, "queue_wait_s": None, "prefill_chunks": 0,
            "decode_steps": 0, "prefill_s": 0.0, "decode_s": 0.0,
            "spec_rounds": 0, "drafted": 0, "accepted": 0,
            "prefix_hit_tokens": 0, "finish_reason": None, "generated": None,
            "t_first": e["t"], "t_last": e["t"] + e["dur"]})
        r["t_first"] = min(r["t_first"], e["t"])
        r["t_last"] = max(r["t_last"], e["t"] + e["dur"])
        k = e["kind"]
        if k == "queued":
            r["queued_t"] = e["t"]
        elif k == "admitted":
            r["queue_wait_s"] = e["data"].get("queue_wait_s")
        elif k == "prefill_chunk":
            r["prefill_chunks"] += 1
            r["prefill_s"] += e["dur"]
        elif k == "decode_step":
            r["decode_steps"] += 1
            r["decode_s"] += e["dur"]
        elif k == "verify":
            # one verify span per speculative round per request; drafted/
            # accepted ride in its args, so acceptance reconstructs from
            # the trace alone (no metrics snapshot needed)
            r["spec_rounds"] += 1
            r["drafted"] += e["data"].get("drafted", 0)
            r["accepted"] += e["data"].get("accepted", 0)
        elif k == "prefix_hit":
            r["prefix_hit_tokens"] = e["data"].get("hit_tokens", 0)
        elif k == "finished":
            r["finish_reason"] = e["data"].get("reason")
            r["generated"] = e["data"].get("generated")
        elif k in ("rejected", "evicted"):
            r["finish_reason"] = k
    for r in reqs.values():
        r["span_s"] = round(r["t_last"] - r["t_first"], 6)
        r["acceptance_rate"] = (round(r["accepted"] / r["drafted"], 4)
                                if r["drafted"] else None)
        del r["t_first"], r["t_last"]
    return reqs


def _speculative_summary(events: list[dict]) -> dict | None:
    verifies = [e for e in events if e["kind"] == "verify"]
    if not verifies:
        return None
    drafted = sum(e["data"].get("drafted", 0) for e in verifies)
    accepted = sum(e["data"].get("accepted", 0) for e in verifies)
    return {"rounds": len(verifies),
            "draft_spans": sum(1 for e in events if e["kind"] == "draft"),
            "drafted": drafted, "accepted": accepted,
            "acceptance_rate": (round(accepted / drafted, 4)
                                if drafted else None)}


def _stall_attribution(events: list[dict], top: int = 5) -> list[dict]:
    """Largest gaps between a request's consecutive decode steps, with a
    cause guess: prefill interference (another rid's chunk ran inside the
    gap), a recorded capacity stall, an error-probe forward or A/B shadow
    replay that ran in the gap (both carry real wall-time durations), or
    scheduler idle."""
    per_rid: dict[int, list[dict]] = collections.defaultdict(list)
    for e in events:
        if e["kind"] == "decode_step":
            per_rid[e["rid"]].append(e)

    def overlaps(kind: str, t0: float, t1: float) -> bool:
        return any(e["kind"] == kind and e["dur"] > 0
                   and e["t"] < t1 and e["t"] + e["dur"] > t0
                   for e in events)

    gaps = []
    for rid, evs in per_rid.items():
        for a, b in zip(evs, evs[1:]):
            gap = b["t"] - (a["t"] + a["dur"])
            if gap <= 0:
                continue
            t0, t1 = a["t"] + a["dur"], b["t"]
            interference = sum(
                1 for e in events
                if e["kind"] == "prefill_chunk" and e["rid"] != rid
                and e["t"] < t1 and e["t"] + e["dur"] > t0)
            stalls = sum(1 for e in events
                         if e["kind"] == "capacity_stall"
                         and t0 <= e["t"] <= t1)
            cause = ("prefill_interference" if interference
                     else "capacity_stall" if stalls
                     else "probe" if overlaps("probe", t0, t1)
                     else "shadow" if overlaps("shadow", t0, t1)
                     else "scheduler_idle")
            gaps.append({"rid": rid, "gap_s": round(gap, 6),
                         "t": round(t0, 6), "cause": cause,
                         "interfering_chunks": interference})
    gaps.sort(key=lambda g: -g["gap_s"])
    return gaps[:top]


def _probe_trend(events: list[dict]) -> dict | None:
    probes = [e for e in events if e["kind"] == "probe"]
    if not probes:
        return None
    series = [{"t": round(e["t"], 4),
               "logits_err_var": e["data"].get("logits_err_var"),
               "mean_layer_err_var": e["data"].get("mean_layer_err_var")}
              for e in probes]
    lv = [s["logits_err_var"] for s in series
          if s["logits_err_var"] is not None]
    return {"runs": len(series), "first": series[0], "last": series[-1],
            "logits_err_var_min": min(lv) if lv else None,
            "logits_err_var_max": max(lv) if lv else None}


def _shadow_summary(events: list[dict]) -> dict | None:
    """A/B shadow replay rollup from the ``shadow`` spans alone (one per
    sampled finished request; token/match counts and the replay's wall
    time ride in its args).  None when the run had no shadow serving."""
    shadows = [e for e in events if e["kind"] == "shadow"]
    if not shadows:
        return None
    tokens = sum(e["data"].get("tokens", 0) for e in shadows)
    matches = sum(e["data"].get("matches", 0) for e in shadows)
    evs = [e["data"]["logits_err_var"] for e in shadows
           if e["data"].get("logits_err_var") is not None]
    return {"replays": len(shadows), "tokens": tokens,
            "token_matches": matches,
            "token_match_rate": (round(matches / tokens, 4)
                                 if tokens else None),
            "logits_err_var_last": evs[-1] if evs else None,
            "replay_time_s": round(sum(e["dur"] for e in shadows), 6)}


def _robustness_summary(events: list[dict]) -> dict | None:
    """Governor/fault/deadline activity (PR 8 span kinds).  None when the
    trace predates them or the run had no robustness events — the report
    stays loadable for every trace vintage."""
    switches = [e for e in events if e["kind"] == "governor_switch"]
    faults = sum(1 for e in events if e["kind"] == "fault_detected")
    quars = [e for e in events if e["kind"] == "quarantine"]
    deadline_evictions = sum(
        1 for e in events if e["kind"] == "evicted"
        and e["data"].get("reason") == "deadline")
    deadline_finishes = sum(
        1 for e in events if e["kind"] == "finished"
        and e["data"].get("reason") == "deadline")
    if not (switches or faults or quars or deadline_evictions
            or deadline_finishes):
        return None
    return {
        "governor_switches": [
            {k: e["data"].get(k)
             for k in ("step", "action", "from", "to", "reason", "layer",
                       "err_var", "power_delta_pct")}
            for e in switches],
        "faults_detected": faults,
        "quarantines": len(quars),
        "replayed_tokens": sum(e["data"].get("replayed", 0) for e in quars),
        "deadline_evictions": deadline_evictions,
        "deadline_finishes": deadline_finishes,
    }


def _fleet_summary(events: list[dict]) -> dict | None:
    """Per-tier rollup when the events span several engines (a merged
    fleet trace).  Tier = the engine id up to its last ``:`` (replica ids
    are ``"<tier>:<index>"``).  None for single-engine traces, so plain
    reports are unchanged.

    TTFT here is trace-derived: queued span -> end of the request's last
    prefill chunk (the call that produces its first token), so it stays
    computable from the per-replica files alone."""
    engines = sorted({e["engine"] for e in events if e["engine"]})
    if len(engines) < 2:
        return None

    def tier_of(eng: str) -> str:
        return eng.rsplit(":", 1)[0] if ":" in eng else eng

    tiers: dict[str, list[str]] = {}
    for eng in engines:
        tiers.setdefault(tier_of(eng), []).append(eng)
    out: dict[str, dict] = {}
    for tname, engs in sorted(tiers.items()):
        evs = [e for e in events if e["engine"] in engs]
        queued = {e["rid"]: e["t"] for e in evs if e["kind"] == "queued"}
        first_tok: dict = {}
        for e in evs:
            if e["kind"] == "prefill_chunk" and e["rid"] in queued:
                end = e["t"] + e["dur"]
                first_tok[e["rid"]] = max(first_tok.get(e["rid"], end), end)
        ttfts = [first_tok[r] - queued[r] for r in first_tok]
        verifies = [e for e in evs if e["kind"] == "verify"]
        drafted = sum(e["data"].get("drafted", 0) for e in verifies)
        accepted = sum(e["data"].get("accepted", 0) for e in verifies)
        routed = collections.Counter(
            e["data"].get("klass") for e in evs if e["kind"] == "routed")
        out[tname] = {
            "engines": engs,
            "requests_finished": sum(
                1 for e in evs if e["kind"] == "finished"),
            "routed": dict(sorted(routed.items())),
            "spills": sum(1 for e in evs if e["kind"] == "routed"
                          and e["data"].get("spill")),
            "ttft_mean_s": (round(sum(ttfts) / len(ttfts), 6)
                            if ttfts else None),
            "acceptance_rate": (round(accepted / drafted, 4)
                                if drafted else None),
            "capacity_stalls": sum(
                1 for e in evs if e["kind"] == "capacity_stall"),
            "prefix_hits": sum(1 for e in evs if e["kind"] == "prefix_hit"),
            "prefix_import_blocks": sum(
                e["data"].get("blocks", 0) for e in evs
                if e["kind"] == "prefix_import"),
            "top_decode_gaps": _stall_attribution(evs, top=3),
        }
    return out


def _window_summary(events: list[dict]) -> dict | None:
    xs = sorted(e["data"]["gen_tok_per_s"] for e in events
                if e["kind"] == "metrics_window"
                and "gen_tok_per_s" in e["data"])
    if not xs:
        return None
    return {"samples": len(xs), "gen_tok_per_s_min": xs[0],
            "gen_tok_per_s_p50": xs[len(xs) // 2],
            "gen_tok_per_s_max": xs[-1]}


def report(events: list[dict]) -> dict:
    kinds = collections.Counter(e["kind"] for e in events)
    return {"events": len(events), "kinds": dict(sorted(kinds.items())),
            "requests": _request_timelines(events),
            "top_decode_gaps": _stall_attribution(events),
            "speculative": _speculative_summary(events),
            "probe": _probe_trend(events),
            "shadow": _shadow_summary(events),
            "windows": _window_summary(events),
            "robustness": _robustness_summary(events),
            "fleet": _fleet_summary(events)}


def _rid_s(rid) -> str:
    """rids are ints (single trace) or ``"engine:rid"`` strings (merged
    fleet traces) — format either without breaking old output."""
    return f"{rid:4d}" if isinstance(rid, int) else f"{rid:>16}"


def _print_human(rep: dict) -> None:
    print(f"{rep['events']} events: "
          + ", ".join(f"{k}={v}" for k, v in rep["kinds"].items()))
    print("\nper-request timelines:")
    for rid, r in sorted(rep["requests"].items()):
        wait = (f"{r['queue_wait_s']*1e3:8.2f}ms"
                if r["queue_wait_s"] is not None else "       ?")
        print(f"  req {_rid_s(rid)}  wait {wait}  "
              f"prefill {r['prefill_chunks']:3d} chunks "
              f"({r['prefill_s']*1e3:8.2f}ms)  "
              f"decode {r['decode_steps']:3d} steps "
              f"({r['decode_s']*1e3:8.2f}ms)  "
              f"span {r['span_s']*1e3:8.2f}ms  "
              f"[{r['finish_reason'] or 'running'}]"
              + (f"  prefix_hit={r['prefix_hit_tokens']}"
                 if r["prefix_hit_tokens"] else "")
              + (f"  spec {r['accepted']}/{r['drafted']} accepted "
                 f"({r['spec_rounds']} rounds)"
                 if r["spec_rounds"] else ""))
    if rep["top_decode_gaps"]:
        print("\nlargest inter-decode gaps:")
        for g in rep["top_decode_gaps"]:
            print(f"  req {_rid_s(g['rid'])}  {g['gap_s']*1e3:8.2f}ms at "
                  f"t={g['t']:.3f}s  cause={g['cause']}"
                  + (f" ({g['interfering_chunks']} chunks)"
                     if g["interfering_chunks"] else ""))
    if rep["speculative"]:
        s = rep["speculative"]
        rate = (f"{s['acceptance_rate']:.2%}"
                if s["acceptance_rate"] is not None else "n/a")
        print(f"\nspeculative decode: {s['rounds']} verify rounds, "
              f"{s['accepted']}/{s['drafted']} drafts accepted ({rate})")
    if rep["probe"]:
        p = rep["probe"]
        print(f"\nerror probe: {p['runs']} runs, logits_err_var "
              f"{p['first']['logits_err_var']:.3e} (first) -> "
              f"{p['last']['logits_err_var']:.3e} (last), "
              f"range [{p['logits_err_var_min']:.3e}, "
              f"{p['logits_err_var_max']:.3e}]")
    if rep["shadow"]:
        sh = rep["shadow"]
        rate = (f"{sh['token_match_rate']:.2%}"
                if sh["token_match_rate"] is not None else "n/a")
        print(f"\nshadow A/B: {sh['replays']} replays, "
              f"{sh['token_matches']}/{sh['tokens']} tokens matched "
              f"({rate}), replay cost {sh['replay_time_s']*1e3:.2f}ms")
    if rep["windows"]:
        w = rep["windows"]
        print(f"\nwindowed gen tok/s: {w['samples']} samples, "
              f"min {w['gen_tok_per_s_min']} / p50 {w['gen_tok_per_s_p50']} "
              f"/ max {w['gen_tok_per_s_max']}")
    if rep["robustness"]:
        rb = rep["robustness"]
        print(f"\nrobustness: faults_detected={rb['faults_detected']} "
              f"quarantines={rb['quarantines']} "
              f"(replayed {rb['replayed_tokens']} tokens), "
              f"deadline evictions={rb['deadline_evictions']} "
              f"finishes={rb['deadline_finishes']}")
        for s in rb["governor_switches"]:
            ev = (f"{s['err_var']:.3e}" if isinstance(s["err_var"], float)
                  else s["err_var"])
            layer = f"  layer={s['layer']}" if s.get("layer") else ""
            print(f"  step {s['step']:5}  {s['action']:8} "
                  f"{s['from']} -> {s['to']}  [{s['reason']}]{layer}  "
                  f"err_var={ev}  power_delta={s['power_delta_pct']}%")
    if rep["fleet"]:
        print("\nfleet (per tier):")
        for tname, t in rep["fleet"].items():
            ttft = (f"{t['ttft_mean_s']*1e3:.2f}ms"
                    if t["ttft_mean_s"] is not None else "n/a")
            acc = (f"{t['acceptance_rate']:.2%}"
                   if t["acceptance_rate"] is not None else "n/a")
            print(f"  tier {tname}: {len(t['engines'])} replicas, "
                  f"{t['requests_finished']} finished, "
                  f"routed={t['routed']} spills={t['spills']}, "
                  f"ttft {ttft}, acceptance {acc}, "
                  f"stalls={t['capacity_stalls']}, "
                  f"prefix hits={t['prefix_hits']} "
                  f"imported_blocks={t['prefix_import_blocks']}")
            for g in t["top_decode_gaps"]:
                print(f"    gap {_rid_s(g['rid'])}  "
                      f"{g['gap_s']*1e3:8.2f}ms  cause={g['cause']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize serving span traces (JSONL or Chrome JSON)")
    ap.add_argument("trace", nargs="*",
                    help="trace file(s) written by --trace-out / --trace-dir")
    ap.add_argument("--trace", action="append", dest="traces", default=[],
                    metavar="FILE",
                    help="additional trace file; repeatable (several files "
                         "= a fleet: rids get engine-id prefixes and the "
                         "report gains a per-tier fleet section)")
    ap.add_argument("--format", choices=("text", "json"), default=None,
                    help="output format (default: text)")
    ap.add_argument("--json", action="store_true",
                    help="alias for --format json (kept for old scripts)")
    ap.add_argument("--assert-lifecycle", action="store_true",
                    help="fail unless >= 1 span of every lifecycle stage "
                         f"{list(LIFECYCLE)} is present")
    ap.add_argument("--assert-quarantine", action="store_true",
                    help="fail unless the trace holds >= 1 fault_detected "
                         "span and every one is matched by a quarantine "
                         "span (the fault-injection smoke gate)")
    args = ap.parse_args(argv)
    paths = list(args.trace) + list(args.traces)
    if not paths:
        ap.error("no trace files given (positional or --trace)")
    events = load_traces(paths)
    rep = report(events)
    fmt = args.format or ("json" if args.json else "text")
    if fmt == "json":
        print(json.dumps(rep, indent=2))
    else:
        _print_human(rep)
    if args.assert_lifecycle:
        missing = [k for k in LIFECYCLE if not rep["kinds"].get(k)]
        if missing:
            print(f"\nFAIL: lifecycle stages missing from trace: {missing}",
                  file=sys.stderr)
            return 2
        print("\nlifecycle OK: "
              + ", ".join(f"{k}={rep['kinds'][k]}" for k in LIFECYCLE))
    if args.assert_quarantine:
        detected = rep["kinds"].get("fault_detected", 0)
        quars = rep["kinds"].get("quarantine", 0)
        if not detected or quars < detected:
            print(f"\nFAIL: quarantine gate: fault_detected={detected} "
                  f"quarantine={quars} (need >= 1 detection, all "
                  "quarantined)", file=sys.stderr)
            return 3
        print(f"\nquarantine OK: {detected} detected, {quars} quarantined")
    return 0


if __name__ == "__main__":
    sys.exit(main())
