"""Jitted public wrappers around the Pallas kernels.

Handles: arbitrary leading batch dims, padding to block multiples, backend
selection (real TPU vs interpret-mode CPU validation), and the bridge from
the framework's packed-parameter representation (QuantizedDense) to raw
kernel operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.multipliers import Mode
from repro.kernels import approx_matmul as _amk


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


#: Row counts at or below this are decode-shaped (engine slot counts): the
#: block picker specializes to the thinnest M tile and a single K step, so a
#: one-token-per-slot step never pays prefill-sized tiles.  The serving
#: engine's ``EngineConfig.slots`` maps onto M here via ``decode_slots``
#: (tokens are (slots, 1) during continuous decode).
DECODE_M_MAX = 8

#: Largest fully-unrolled K extent a decode step takes in one grid step
#: (a (8, 4096) activation tile + (4096, 128) weight tile stay far under
#: VMEM; a single K step also drops the cross-step accumulator carry).
DECODE_FULL_K_MAX = 4096


def _pick_blocks(mm: int, kk: int, nn: int, bm: int, bn: int, bk: int):
    """Shrink default blocks for small operands (keeps grid >= 1 per axis).

    Decode-shaped calls (mm <= DECODE_M_MAX) additionally widen the K block
    to the whole (padded) contraction when it fits, collapsing the grid's
    K axis to one parallel step.
    """
    from repro.quant.quantize import shrink_block as shrink

    bm_ = shrink(mm, bm, 8)
    bn_ = shrink(nn, bn, 128 if nn >= 128 else 8)
    bk_ = shrink(kk, bk, 128 if kk >= 128 else 8)
    if mm <= DECODE_M_MAX:
        # one K block spanning the whole padded contraction (same padding
        # granularity, merged steps)
        bk_full = -(-kk // bk_) * bk_
        if bk_full <= DECODE_FULL_K_MAX:
            bk_ = bk_full
    return bm_, bn_, bk_


def approx_matmul_cv_op(
    a_q: jax.Array,  # (..., K) uint8 codes
    w_q: jax.Array,  # (K, N) uint8 codes
    c: jax.Array,
    c0: jax.Array,
    sum_qw: jax.Array,
    bias: jax.Array | None,
    sa,
    sw,
    za,
    zw,
    *,
    mode: Mode,
    m: int,
    use_cv: bool = True,
    bm: int = _amk.DEFAULT_BM,
    bn: int = _amk.DEFAULT_BN,
    bk: int = _amk.DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused approx-matmul+CV over arbitrary leading dims; returns f32 (..., N)."""
    if interpret is None:
        interpret = not on_tpu()

    lead = a_q.shape[:-1]
    kk = a_q.shape[-1]
    nn = w_q.shape[-1]
    a2 = a_q.reshape(-1, kk)
    mm = a2.shape[0]

    bm_, bn_, bk_ = _pick_blocks(mm, kk, nn, bm, bn, bk)
    a2 = _pad_to(_pad_to(a2, 0, bm_), 1, bk_)
    w2 = _pad_to(_pad_to(w_q, 0, bk_), 1, bn_)

    # NOTE on K padding: padded activation codes are 0, padded weight codes
    # are 0 — every AM is 0 on zero codes and x(0) = 0, so acc/sumx are
    # unaffected; sum_qa/sum_qw likewise.  The only k-sensitive term is
    # k*za*zw, for which the kernel receives the PADDED k and we compensate
    # here by folding (k_pad - k_true)*za*zw out of the result.
    k_pad = a2.shape[1]
    pad_terms = jnp.float32(k_pad - kk) * jnp.float32(za) * jnp.float32(zw)

    cN = _pad_to(jnp.asarray(c, jnp.float32), 0, bn_)
    c0N = _pad_to(jnp.asarray(c0, jnp.float32), 0, bn_)
    sqwN = _pad_to(jnp.asarray(sum_qw, jnp.int32), 0, bn_)
    biasN = (
        _pad_to(jnp.asarray(bias, jnp.float32), 0, bn_)
        if bias is not None
        else jnp.zeros((w2.shape[1],), jnp.float32)
    )

    out = _amk.approx_matmul_cv(
        a2,
        w2,
        cN,
        c0N,
        sqwN,
        biasN,
        jnp.float32(sa),
        jnp.float32(sw),
        jnp.float32(za),
        jnp.float32(zw),
        mode=mode,
        m=m,
        use_cv=use_cv,
        bm=bm_,
        bn=bn_,
        bk=bk_,
        interpret=interpret,
    )
    out = out - pad_terms * (jnp.float32(sa) * jnp.float32(sw))
    return out[:mm, :nn].reshape(*lead, nn)


def quantized_dense_fused_op(
    x: jax.Array,  # (..., k) FLOAT activations
    blocked,  # repro.quant.BlockedPack
    *,
    mode: Mode,
    m: int,
    use_cv: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Zero-overhead serving path: float activations against an
    offline-blocked pack, one kernel launch (quantize + matmul + epilogue).

    Only the activations are padded here (M to the picked tile, K from the
    true fan-in to the pack's blocked extent); every static operand was laid
    out at pack time.  Returns ``x.dtype`` (..., n).
    """
    if interpret is None:
        interpret = not on_tpu()

    lead = x.shape[:-1]
    kk = x.shape[-1]
    assert kk == blocked.k, (x.shape, blocked.k)
    kb, nb = blocked.w_qb.shape
    x2 = x.reshape(-1, kk)
    mm = x2.shape[0]

    bm_, _, bk_ = _pick_blocks(mm, kb, nb, _amk.DEFAULT_BM, blocked.bn,
                               blocked.bk)
    # K blocks must tile the offline layout exactly: fall back to the pack
    # granularity unless the decode merge consumed all of Kb
    if kb % bk_ != 0:
        bk_ = blocked.bk
    x2 = _pad_to(_pad_to(x2, 0, bm_), 1, kb)

    out = _amk.approx_matmul_cv_fused(
        x2,
        blocked.w_qb,
        blocked.epilogue,
        blocked.meta,
        mode=mode,
        m=m,
        use_cv=use_cv,
        bm=bm_,
        bn=blocked.bn,
        bk=bk_,
        out_dtype=x.dtype,
        interpret=interpret,
    )
    return out[:mm, : blocked.n].reshape(*lead, blocked.n)


def quantized_dense_pallas(x: jax.Array, qd) -> jax.Array:
    """Bridge: QuantizedDense params + float activations -> fused kernel.

    Packs carrying the offline-blocked serving layout take the
    float-in/float-out fused kernel (quantize-in-kernel, no per-call padding
    of static operands); legacy packs quantize here and run the original
    kernel with per-call padding.
    """
    from repro.quant.quantize import quantize

    pol = qd.policy
    if pol.groups != 1:
        raise NotImplementedError(
            "grouped CV uses the jnp path (set backend='jnp' for groups > 1)"
        )
    if getattr(qd, "blocked", None) is not None:
        return quantized_dense_fused_op(
            x, qd.blocked, mode=pol.mode, m=pol.m, use_cv=pol.use_cv)
    a_q = quantize(x, qd.a_qp)
    pack = qd.pack
    bias = pack.bias
    return approx_matmul_cv_op(
        a_q,
        pack.w_q,
        pack.c,
        pack.c0,
        pack.sum_qw,
        bias,
        qd.a_qp.scale,
        pack.w_scale,
        qd.a_qp.zero_point,
        pack.w_zp,
        mode=pol.mode,
        m=pol.m,
        use_cv=pol.use_cv,
    )
