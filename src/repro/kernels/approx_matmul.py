"""Fused approximate-multiplier matmul with control-variate epilogue (Pallas TPU).

This is the TPU realization of the paper's approximate systolic array
(DESIGN.md Sec. 2): one kernel computes, for uint8 activation codes A (M, K)
and weight codes W (K, N),

    acc[m, n]  = sum_k AM(W[k, n], A[m, k])          (bit-slice MXU algebra)
    sumx[m]    = sum_k x(A[m, k])                    (the MAC* side-adder)
    sumqa[m]   = sum_k A[m, k]                       (gemmlowp correction)
    out[m, n]  = sa*sw * ( acc + CV + zero-point corrections ) + bias
       CV      = sumx[m] * C[n] + C0[n]              (the MAC+ column == fused
                                                      rank-1 epilogue)

All integer arithmetic is exact int32; the AM semantics are bit-exact with
the scalar hardware definitions in :mod:`repro.core.multipliers` (asserted
against `ref.py` in tests).  The approximate products are *decompositions
into exact integer matmuls* so the MXU runs at full rate:

    perforated: dot(A & ~mask, W)
    recursive : dot(A, W) - dot(A & mask, W & mask)
    truncated : dot(A, W) - sum_{i<m} dot(bit_i(A) << i, W mod 2^{m-i})

Grid: (M/bm, N/bn, K/bk) with the K axis innermost ("arbitrary" semantics);
accumulators live in VMEM scratch across K steps; the epilogue fires on the
final K step.  Block shapes default to MXU-aligned (128, 128, 512).

TPU is the *target*; CPU validation uses interpret=True (set by ops.py when
no TPU is present).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipliers import Mode
from repro.quant.quantize import (EPI_BIAS, EPI_C, EPI_C0, EPI_ROWS, EPI_SUM_QW,
                                  EPI_SW, EPI_ZW, META_LEN, META_SA,
                                  META_TRUE_K, META_ZA)

# MXU-aligned defaults: int8-friendly tiles, K deep enough to amortize the
# epilogue; A tile (128x512) + W tile (512x128) + int32 acc (128x128) stay
# well under VMEM with double buffering.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _dot_i32(a, b):
    """Exact int32 matmul of int32-valued tiles (int8-rate on the MXU)."""
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _am_tile_acc(a_i32, w_i32, mode: Mode, m: int):
    """sum_k AM(w, a) for one (bm, bk) x (bk, bn) tile — bit-slice algebra."""
    if mode == "exact" or m == 0:
        return _dot_i32(a_i32, w_i32)
    mask = (1 << m) - 1
    if mode == "perforated":
        return _dot_i32(a_i32 - (a_i32 & mask), w_i32)
    if mode == "recursive":
        return _dot_i32(a_i32, w_i32) - _dot_i32(a_i32 & mask, w_i32 & mask)
    if mode == "truncated":
        acc = _dot_i32(a_i32, w_i32)
        for i in range(m):
            plane_a = ((a_i32 >> i) & 1) << i
            plane_w = w_i32 & ((1 << (m - i)) - 1)
            acc = acc - _dot_i32(plane_a, plane_w)
        return acc
    raise ValueError(f"unknown mode {mode}")


def _x_tile(a_i32, mode: Mode, m: int):
    """x(A) per element for one tile (the MAC* statistic)."""
    mask = (1 << m) - 1
    if mode in ("perforated", "recursive"):
        return a_i32 & mask
    if mode == "truncated":
        return ((a_i32 & mask) != 0).astype(jnp.int32)
    raise ValueError(f"unknown mode {mode}")


def _kernel(
    # inputs
    a_ref,  # (bm, bk) uint8 codes
    w_ref,  # (bk, bn) uint8 codes
    c_ref,  # (1, bn) f32   CV constant C
    c0_ref,  # (1, bn) f32  CV constant C0
    sum_qw_ref,  # (1, bn) i32  column sums of W codes
    bias_ref,  # (1, bn) f32
    meta_ref,  # (1, 8) f32: [sa, sw, za, zw, true_k, 0, 0, 0]
    # outputs
    out_ref,  # (bm, bn) f32
    # scratch
    acc_ref,  # (bm, bn) i32
    sumx_ref,  # (bm, 1) i32
    sumqa_ref,  # (bm, 1) i32
    *,
    mode: Mode,
    m: int,
    use_cv: bool,
    nk: int,
):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)
        sumqa_ref[...] = jnp.zeros_like(sumqa_ref)

    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)

    acc_ref[...] += _am_tile_acc(a, w, mode, m)
    sumqa_ref[...] += jnp.sum(a, axis=1, dtype=jnp.int32, keepdims=True)
    if use_cv and mode != "exact" and m > 0:
        sumx_ref[...] += jnp.sum(
            _x_tile(a, mode, m), axis=1, dtype=jnp.int32, keepdims=True
        )

    @pl.when(k_step == nk - 1)
    def _epilogue():
        sa = meta_ref[0, 0]
        sw = meta_ref[0, 1]
        za = meta_ref[0, 2]
        zw = meta_ref[0, 3]
        true_k = meta_ref[0, 4]

        out = acc_ref[...].astype(jnp.float32)
        if use_cv and mode != "exact" and m > 0:
            # the paper's MAC+ column: rank-1 update + bias-folded C0
            out = out + sumx_ref[...].astype(jnp.float32) * c_ref[...]
            out = out + c0_ref[...]
        # exact gemmlowp zero-point corrections
        out = out - zw * sumqa_ref[...].astype(jnp.float32)
        out = out - za * sum_qw_ref[...].astype(jnp.float32)
        out = out + true_k * za * zw
        out = out * (sa * sw) + bias_ref[...]
        out_ref[...] = out


def _compiler_params(nk: int):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    # single K step (decode-specialized tiles): no cross-step accumulator
    # carry, so every grid axis is freely parallel/reorderable
    sem = "parallel" if nk == 1 else "arbitrary"
    return cls(dimension_semantics=("parallel", "parallel", sem))


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "m", "use_cv", "bm", "bn", "bk", "interpret",
    ),
)
def approx_matmul_cv(
    a_q: jax.Array,  # (M, K) uint8 codes
    w_q: jax.Array,  # (K, N) uint8 codes
    c: jax.Array,  # (N,) f32
    c0: jax.Array,  # (N,) f32
    sum_qw: jax.Array,  # (N,) i32
    bias: jax.Array,  # (N,) f32 (zeros if no bias)
    sa: jax.Array,  # scalar f32 activation scale
    sw: jax.Array,  # scalar f32 weight scale
    za: jax.Array,  # scalar i32/f32 activation zero point
    zw: jax.Array,  # scalar
    *,
    mode: Mode,
    m: int,
    use_cv: bool = True,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Fused quantized approximate matmul; returns float32 (M, N).

    Shapes must be pre-padded to block multiples (ops.py handles padding and
    arbitrary leading batch dims).
    """
    mm, kk = a_q.shape
    kk2, nn = w_q.shape
    assert kk == kk2, (a_q.shape, w_q.shape)
    assert mm % bm == 0 and nn % bn == 0 and kk % bk == 0, (
        (mm, kk, nn), (bm, bk, bn),
    )
    nk = kk // bk
    true_k = jnp.float32(kk)  # padding contributes zero codes; za==0 when padded

    meta = jnp.zeros((1, 8), jnp.float32)
    meta = meta.at[0, 0].set(jnp.float32(sa))
    meta = meta.at[0, 1].set(jnp.float32(sw))
    meta = meta.at[0, 2].set(jnp.float32(za))
    meta = meta.at[0, 3].set(jnp.float32(zw))
    meta = meta.at[0, 4].set(true_k)

    kernel = functools.partial(_kernel, mode=mode, m=m, use_cv=use_cv, nk=nk)
    grid = (mm // bm, nn // bn, nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 8), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        compiler_params=_compiler_params(nk),
        interpret=interpret,
    )(
        a_q,
        w_q,
        c.reshape(1, nn).astype(jnp.float32),
        c0.reshape(1, nn).astype(jnp.float32),
        sum_qw.reshape(1, nn).astype(jnp.int32),
        bias.reshape(1, nn).astype(jnp.float32),
        meta,
    )


# ---------------------------------------------------------------------------
# Fused serving kernel: quantize-in-kernel over the offline-blocked layout
# ---------------------------------------------------------------------------
#
# One launch computes  float x -> quantize -> bit-slice AM matmuls ->
# MAC* statistics -> CV + zero-point epilogue -> output dtype cast.  The
# static operands arrive pre-blocked (repro.quant.BlockedPack): weight codes
# padded to tile multiples offline and all per-column epilogue operands in
# one aligned (EPI_ROWS, Nb) table — the forward pass does no padding of
# static parameters and no meta assembly.  Per-COLUMN weight quant params
# (epilogue rows EPI_SW / EPI_ZW) make the same kernel serve fan-out-fused
# multi-projection packs (Q|K|V, gate|up): activations are quantized once
# and sumx/sumqa are computed once for every fused output column.


def _fused_kernel(
    # inputs
    x_ref,  # (bm, bk) float activations
    w_ref,  # (bk, bn) uint8 codes (zero-padded offline)
    epi_ref,  # (EPI_ROWS, bn) f32 epilogue table
    meta_ref,  # (1, META_LEN) f32 scalars
    # outputs
    out_ref,  # (bm, bn) out_dtype
    # scratch
    acc_ref,  # (bm, bn) i32
    sumx_ref,  # (bm, 1) i32
    sumqa_ref,  # (bm, 1) i32
    *,
    mode: Mode,
    m: int,
    use_cv: bool,
    nk: int,
    bk: int,
):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)
        sumqa_ref[...] = jnp.zeros_like(sumqa_ref)

    sa = meta_ref[0, META_SA]
    za = meta_ref[0, META_ZA]
    true_k = meta_ref[0, META_TRUE_K]

    # quantize in-kernel (identical arithmetic to quant.quantize_i32), then
    # zero the K-padding columns: padded float zeros would quantize to the
    # zero-point code, which must not reach acc/sumx/sumqa
    x = x_ref[...].astype(jnp.float32)
    a = jnp.clip(jnp.round(x / sa) + za, 0.0, 255.0).astype(jnp.int32)
    kcol = k_step * bk + jax.lax.broadcasted_iota(jnp.float32, a.shape, 1)
    a = jnp.where(kcol < true_k, a, 0)
    w = w_ref[...].astype(jnp.int32)

    acc_ref[...] += _am_tile_acc(a, w, mode, m)
    sumqa_ref[...] += jnp.sum(a, axis=1, dtype=jnp.int32, keepdims=True)
    if use_cv and mode != "exact" and m > 0:
        sumx_ref[...] += jnp.sum(
            _x_tile(a, mode, m), axis=1, dtype=jnp.int32, keepdims=True
        )

    @pl.when(k_step == nk - 1)
    def _epilogue():
        epi = epi_ref[...]
        c = epi[EPI_C : EPI_C + 1, :]
        c0 = epi[EPI_C0 : EPI_C0 + 1, :]
        sum_qw = epi[EPI_SUM_QW : EPI_SUM_QW + 1, :]
        bias = epi[EPI_BIAS : EPI_BIAS + 1, :]
        sw = epi[EPI_SW : EPI_SW + 1, :]
        zw = epi[EPI_ZW : EPI_ZW + 1, :]

        out = acc_ref[...].astype(jnp.float32)
        if use_cv and mode != "exact" and m > 0:
            out = out + sumx_ref[...].astype(jnp.float32) * c
            out = out + c0
        # exact gemmlowp zero-point corrections (true_k: K padding excluded)
        out = out - zw * sumqa_ref[...].astype(jnp.float32)
        out = out - za * sum_qw
        out = out + true_k * za * zw
        out = out * (sa * sw) + bias
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "m", "use_cv", "bm", "bn", "bk", "out_dtype", "interpret",
    ),
)
def approx_matmul_cv_fused(
    x: jax.Array,  # (M, Kb) float activations (M/K pre-padded to blocks)
    w_qb: jax.Array,  # (Kb, Nb) uint8 codes, blocked offline
    epilogue: jax.Array,  # (EPI_ROWS, Nb) f32
    meta: jax.Array,  # (1, META_LEN) f32
    *,
    mode: Mode,
    m: int,
    use_cv: bool = True,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Fused float->float approximate matmul; returns ``out_dtype`` (M, Nb)."""
    mm, kk = x.shape
    kk2, nn = w_qb.shape
    assert kk == kk2, (x.shape, w_qb.shape)
    assert mm % bm == 0 and nn % bn == 0 and kk % bk == 0, (
        (mm, kk, nn), (bm, bk, bn),
    )
    assert epilogue.shape == (EPI_ROWS, nn), epilogue.shape
    nk = kk // bk

    kernel = functools.partial(
        _fused_kernel, mode=mode, m=m, use_cv=use_cv, nk=nk, bk=bk
    )
    grid = (mm // bm, nn // bn, nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((EPI_ROWS, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, META_LEN), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        compiler_params=_compiler_params(nk),
        interpret=interpret,
    )(x, w_qb, epilogue, meta)
