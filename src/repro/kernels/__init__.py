"""Pallas TPU kernels for the framework's compute hot-spots.

  approx_matmul.py    fused int8 approximate matmul + control-variate rank-1
                      epilogue (the paper's MAC array, DESIGN.md Sec. 2)
  rwkv6_scan.py       chunked RWKV6 WKV linear-attention recurrence
  flash_attention.py  blocked online-softmax attention (causal/window/GQA)
  ops.py              jitted wrappers (padding, batching, backend selection)
  ref.py              pure-jnp oracles (the scalar hardware definitions)

TPU is the compilation target; CPU correctness runs use interpret=True.
"""
