"""Blocked online-softmax attention (flash) Pallas TPU kernel.

Forward-only fused attention for the serving paths (prefill is the
attention-bound cell in the roofline table).  Supports causal masking,
sliding windows (hymba), and GQA via head-index mapping — one kernel serves
qwen3/granite/deepseek/olmo/hubert (bidirectional) and hymba (windowed).

Grid: (B*Hq, Tq/bq, Tk/bk), K innermost with VMEM scratch carrying the
running max/denominator/accumulator.  Fully-masked K tiles are skipped with
pl.when so the causal lower triangle costs ~half the FLOPs (same trick as
the TPU flash reference).  ref.py's flash_attention_ref is the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, bq, d)
    k_ref,  # (1, bk, d)
    v_ref,  # (1, bk, d)
    out_ref,  # (1, bq, d)
    m_ref,  # scratch (bq, 1) f32
    l_ref,  # scratch (bq, 1) f32
    acc_ref,  # scratch (bq, d) f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    tq: int,
    tk: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions; q rows are aligned to the END of the kv axis
    # (tq == tk for prefill; tq < tk for chunked decode paths)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (tk - tq)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: fully masked K tiles do no work
    first_q = iq * bq + (tk - tq)
    last_q = first_q + bq - 1
    tile_needed = True
    if causal:
        tile_needed = jnp.asarray(ik * bk <= last_q)
    if window is not None:
        tile_needed = jnp.logical_and(
            tile_needed, jnp.asarray((ik + 1) * bk - 1 > first_q - window)
        )

    @pl.when(tile_needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _epilogue():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / denom).astype(out_ref.dtype)


def _compiler_params():
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    rep = hq // hkv
    if scale is None:
        scale = d**-0.5
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0, ((tq, bq), (tk, bk))

    qf = q.reshape(b * hq, tq, d)
    grid = (b * hq, tq // bq, tk // bk)

    def kv_map(h_flat, iq, ik):
        # flat q-head -> (batch, kv-head) for GQA
        return (h_flat // hq) * hkv + (h_flat % hq) // rep, ik, 0

    kf = k.reshape(b * hkv, tk, d)
    vf = v.reshape(b * hkv, tk, d)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=float(scale),
            causal=causal,
            window=window,
            bq=bq,
            bk=bk,
            tq=tq,
            tk=tk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, tq, d)
