"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *scalar hardware definitions* — elementwise approximate
products summed explicitly — deliberately the slowest, most obviously-correct
form.  Kernel tests sweep shapes/modes and assert bit-exact (integer paths)
or allclose (float epilogue) agreement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import multipliers as am
from repro.core import control_variate as cvlib
from repro.core.multipliers import Mode


def approx_matmul_cv_ref(
    a_q,
    w_q,
    c,
    c0,
    sum_qw,
    bias,
    sa,
    sw,
    za,
    zw,
    *,
    mode: Mode,
    m: int,
    use_cv: bool = True,
) -> jax.Array:
    """Oracle for kernels.approx_matmul.approx_matmul_cv.

    a_q: (M, K) uint8 codes; w_q: (K, N) uint8 codes.  O(M*K*N) memory —
    test shapes only.
    """
    a_i = jnp.asarray(a_q, jnp.int32)
    w_i = jnp.asarray(w_q, jnp.int32)
    kk = a_i.shape[-1]

    acc = am.approx_matmul_ref(a_i, w_i, mode, m).astype(jnp.float32)
    if use_cv and mode != "exact" and m > 0:
        sumx = cvlib.sum_x(a_i, mode, m, axis=-1).astype(jnp.float32)
        acc = acc + sumx[:, None] * jnp.asarray(c, jnp.float32)[None, :]
        acc = acc + jnp.asarray(c0, jnp.float32)[None, :]

    sum_qa = jnp.sum(a_i, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    acc = acc - jnp.float32(zw) * sum_qa[:, None]
    acc = acc - jnp.float32(za) * jnp.asarray(sum_qw, jnp.float32)[None, :]
    acc = acc + jnp.float32(kk) * jnp.float32(za) * jnp.float32(zw)
    return acc * (jnp.float32(sa) * jnp.float32(sw)) + jnp.asarray(
        bias, jnp.float32
    )[None, :]


def rwkv6_scan_ref(r, k, v, w, u, state0):
    """Oracle for kernels.rwkv6_scan: sequential RWKV6 WKV recurrence.

    Shapes (B, T, H, Dk) for r/k/w, (B, T, H, Dv) for v, u: (H, Dk),
    state0: (B, H, Dk, Dv).  Returns (out (B, T, H, Dv), stateT).

        out_t   = r_t^T (diag(u) k_t v_t^T + S_{t-1})
        S_t     = diag(w_t) S_{t-1} + k_t v_t^T
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]

    def step(state, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B, H, Dk), ..., (B, H, Dv), (B, H, Dk)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, Dk, Dv)
        att = state + u[None, :, :, None] * kv  # (B, H, Dk, Dv)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        new_state = w_t[..., :, None] * state + kv
        return new_state, out

    xs = (
        jnp.moveaxis(r, 1, 0),
        jnp.moveaxis(k, 1, 0),
        jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(w, 1, 0),
    )
    stateT, out = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(out, 0, 1), stateT


def flash_attention_ref(q, k, v, *, causal: bool, window: int | None = None,
                        scale: float | None = None):
    """Oracle for kernels.flash_attention: plain softmax attention.

    q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D); GQA by head-group broadcast.
    window (if set) = sliding-window size (causal only).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if scale is None:
        scale = d**-0.5
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    tk = k.shape[2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)  # align ends (decode-friendly)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (ki > qi - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
