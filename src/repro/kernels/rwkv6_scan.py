"""RWKV6 (Finch) WKV recurrence as a chunked Pallas TPU kernel.

The recurrence per head (state S in R^{Dk x Dv}, data-dependent decay w_t):

    out_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

A naive scan is sequential in T.  The kernel uses the standard chunked
linear-attention reformulation: within a chunk of length L, with cumulative
decays D_t = prod_{s<=t} w_s (D_0 = 1),

    r~_t = r_t * D_{t-1}          k~_s = k_s / D_s
    A[t,s] = (r~_t . k~_s)  for s < t;   A[t,t] = r_t . (u * k_t)
    out = A @ V + r~ @ S_0
    S_L = diag(D_L) (S_0 + sum_s k~_s v_s^T)

so each chunk is three small matmuls (MXU) instead of L rank-1 updates, and
the sequential dependency is only chunk-to-chunk through S (kept in VMEM
scratch across the T grid axis).  Chunk length is bounded (default 32) so the
1/D_s terms stay in f32 range for decays w >= exp(-8) (RWKV6's
exp(-softplus) parameterization keeps w in (0, 1); tests cover the extremes).

Grid: (B, H, T/L) with T innermost ("arbitrary"); per-(b,h) state persists in
scratch across chunk steps.  ref.py's rwkv6_scan_ref is the sequential
oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _kernel(
    r_ref,  # (1, L, 1, Dk)
    k_ref,  # (1, L, 1, Dk)
    v_ref,  # (1, L, 1, Dv)
    w_ref,  # (1, L, 1, Dk)  decays in (0, 1)
    u_ref,  # (1, Dk)        bonus
    out_ref,  # (1, L, 1, Dv)
    state_ref,  # scratch (Dk, Dv) f32
    *,
    nt: int,
):
    t_step = pl.program_id(2)

    @pl.when(t_step == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (L, Dk)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (L, Dv)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)  # (Dk,)

    logw = jnp.log(w)
    logD = jnp.cumsum(logw, axis=0)  # log D_t, t = 1..L
    d_full = jnp.exp(logD[-1])  # D_L
    r_t = r * jnp.exp(jnp.concatenate([jnp.zeros_like(logD[:1]), logD[:-1]], 0))
    k_t = k * jnp.exp(-logD)

    s0 = state_ref[...]
    ell = r.shape[0]
    # strictly-lower-triangular inter-position matrix + diagonal u term
    a = jnp.dot(r_t, k_t.T, preferred_element_type=jnp.float32)  # (L, L)
    ti = jax.lax.broadcasted_iota(jnp.int32, (ell, ell), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (ell, ell), 1)
    a = jnp.where(si < ti, a, 0.0)
    diag = jnp.sum(r * (u[None, :] * k), axis=1)  # (L,)
    out = jnp.dot(a, v, preferred_element_type=jnp.float32)
    out = out + diag[:, None] * v
    out = out + jnp.dot(r_t, s0, preferred_element_type=jnp.float32)

    state_ref[...] = d_full[:, None] * (
        s0 + jnp.dot(k_t.T, v, preferred_element_type=jnp.float32)
    )
    out_ref[0, :, 0, :] = out.astype(out_ref.dtype)


def _compiler_params():
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # (B, T, H, Dk)
    k: jax.Array,
    v: jax.Array,  # (B, T, H, Dv)
    w: jax.Array,  # (B, T, H, Dk) decays in (0, 1)
    u: jax.Array,  # (H, Dk)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Chunked WKV: returns out (B, T, H, Dv).  T must divide by ``chunk``
    (ops.py pads).  Initial state is zero (prefill semantics); decode-time
    stateful stepping uses the jnp path in models/rwkv_lm.py."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nt = t // chunk
    grid = (b, h, nt)

    def tile(d):
        return pl.BlockSpec((1, chunk, 1, d), lambda bi, hi, ti: (bi, ti, hi, 0))

    return pl.pallas_call(
        functools.partial(_kernel, nt=nt),
        grid=grid,
        in_specs=[
            tile(dk),
            tile(dk),
            tile(dv),
            tile(dk),
            pl.BlockSpec((1, dk), lambda bi, hi, ti: (hi, 0)),
        ],
        out_specs=tile(dv),
        out_shape=jax.ShapeDtypeStruct((b, t, h, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(r, k, v, w, u)
