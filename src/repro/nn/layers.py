"""Common layers: norms, embeddings, MLPs, rotary embeddings (RoPE + M-RoPE).

Parameter convention: plain nested dicts of arrays; ``init_*`` builds them,
``*_apply``-style pure functions consume them.  Linear leaves are
``{"w": (k, n)[, "b": (n,)]}`` so :func:`repro.core.approx_linear.pack_params`
can swap them for approximate packed versions.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.approx_linear import dense, init_dense


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict | None, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Parametric LN, or non-parametric (olmo-style) when ``p`` is None."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if p is not None:
        x = x * p["scale"] + p["bias"]
    return x.astype(dt)


def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return init_rmsnorm(d, dtype)
    if kind == "layernorm":
        return init_layernorm(d, dtype)
    if kind == "nonparametric_ln":
        return {}  # no params
    raise ValueError(kind)


def apply_norm(kind: str, p, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(p, x)
    if kind == "layernorm":
        return layernorm(p, x)
    if kind == "nonparametric_ln":
        return layernorm(None, x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Logits head; accepts an embedding table (tied) or a linear leaf."""
    if "table" in p:
        return jnp.matmul(x, p["table"].T)
    return dense(p, x, name="lm_head")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d: int, ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, ff, bias=False, dtype=dtype),
        "up": init_dense(k2, d, ff, bias=False, dtype=dtype),
        "down": init_dense(k3, ff, d, bias=False, dtype=dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    if "gateup" in p:  # fan-out-fused serving pack: one wide-N call
        from repro.core.approx_linear import dense_group

        gu = dense_group(p["gateup"], x)
        g, u = gu["gate"], gu["up"]
    else:
        g = dense(p["gate"], x, name="gate")
        u = dense(p["up"], x, name="up")
    return dense(p["down"], jax.nn.silu(g) * u, name="down")


def init_gelu_mlp(key, d: int, ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": init_dense(k1, d, ff, bias=True, dtype=dtype),
        "down": init_dense(k2, ff, d, bias=True, dtype=dtype),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x, name="up")), name="down")


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE) and multimodal M-RoPE (qwen2-vl)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def rope_angles(positions: jax.Array, head_dim: int, theta: float = 10000.0):
    """cos/sin tables for integer positions (..., T) -> (..., T, head_dim//2)."""
    ang = positions[..., None].astype(jnp.float32) * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, D) with cos/sin (B, T, D//2) (head-broadcast).

    Rotate-half convention (llama-style: split halves, not interleaved).
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def mrope_angles(
    positions_3d: jax.Array,  # (3, B, T): temporal / height / width ids
    head_dim: int,
    sections: tuple[int, int, int],
    theta: float = 10000.0,
):
    """qwen2-vl M-RoPE: the head_dim//2 frequency slots are partitioned into
    (temporal, height, width) sections, each driven by its own position id.
    For pure text the three ids coincide and M-RoPE reduces to RoPE.
    Returns cos/sin of shape (B, T, head_dim//2).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)  # (d2,)
    splits = [0]
    for s in sections:
        splits.append(splits[-1] + s)
    parts_cos, parts_sin = [], []
    for i in range(3):
        f = freqs[splits[i] : splits[i + 1]]
        ang = positions_3d[i][..., None].astype(jnp.float32) * f
        parts_cos.append(jnp.cos(ang))
        parts_sin.append(jnp.sin(ang))
    return jnp.concatenate(parts_cos, -1), jnp.concatenate(parts_sin, -1)
