"""Selective SSM (Mamba-style) block — the SSM half of hymba's hybrid heads.

Hymba (arXiv:2411.13676) runs attention heads and mamba heads *in parallel*
within each layer and averages their (re-normalized) outputs.  This module
implements the mamba head: in-projection with gate, causal depthwise conv,
data-dependent (dt, B, C) selective scan with d_state=16, gated
out-projection.

The scan is `jax.lax.scan` over time for prefill/training (HLO-compact,
sequential) and a single fused step for decode.  A chunked parallel scan is
a known optimization (same chunking algebra as kernels/rwkv6_scan.py) and is
left as a recorded perf lever for the hillclimb phase.

All projections are `dense` leaves (approximable); the recurrence itself is
exact vector-unit work.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.approx_linear import dense, init_dense


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int  # expansion (hymba: 2 * d_model over the ssm heads)
    d_state: int = 16
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    conv_kernel: int = 4

    @property
    def dtr(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def init_ssm(key, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d_in = cfg.d_inner
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": init_dense(k1, cfg.d_model, 2 * d_in, bias=False, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, d_in)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": init_dense(k3, d_in, cfg.dtr + 2 * cfg.d_state, bias=False, dtype=dtype),
        "dt_proj": init_dense(k4, cfg.dtr, d_in, bias=True, dtype=dtype),
        "a_log": jnp.log(a).astype(dtype),
        "d_skip": jnp.ones((d_in,), dtype),
        "out_proj": init_dense(k5, d_in, cfg.d_model, bias=False, dtype=dtype),
    }


def _causal_conv(p: dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: (B, T, C)."""
    k = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # k is tiny (4): unrolled taps fuse well
        out = out + xp[:, i : i + x.shape[1], :] * p["conv_w"][i]
    return out + p["conv_b"]


def _ssm_inputs(p: dict, cfg: SSMConfig, xc: jax.Array):
    """Data-dependent dt/B/C from the conv output.  xc: (B, T, d_inner)."""
    proj = dense(p["x_proj"], xc, name="x_proj")
    dt_low = proj[..., : cfg.dtr]
    b = proj[..., cfg.dtr : cfg.dtr + cfg.d_state]
    c = proj[..., cfg.dtr + cfg.d_state :]
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_low, name="dt_proj"))
    return dt, b, c


def ssm_prefill(p: dict, x: jax.Array, cfg: SSMConfig) -> jax.Array:
    """x: (B, T, d_model) -> (B, T, d_model); zero initial state."""
    b_, t, _ = x.shape
    xz = dense(p["in_proj"], x, name="in_proj")
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(p, xin))
    dt, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (d_inner, d_state)

    def step(h, inp):
        xc_t, dt_t, b_t, c_t = inp  # (B,d_in), (B,d_in), (B,ds), (B,ds)
        da = jnp.exp(dt_t[..., None] * a)  # (B, d_in, ds)
        h = da * h + (dt_t * xc_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((b_, cfg.d_inner, cfg.d_state), jnp.float32)
    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(bmat, 1, 0),
        jnp.moveaxis(cmat, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = (y + xc * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return dense(p["out_proj"], y, name="out_proj").astype(x.dtype)


def init_ssm_state(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def ssm_decode_step(p: dict, x: jax.Array, state: dict, cfg: SSMConfig):
    """x: (B, 1, d_model); O(1) per-token state update."""
    xz = dense(p["in_proj"], x, name="in_proj")
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, 1, d_in)
    conv_buf = jnp.concatenate([state["conv"], xin], axis=1)  # (B, k, d_in)
    xc = jnp.einsum("bkd,kd->bd", conv_buf, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]
    dt, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0, :, None] * a)
    h = da * state["h"] + (dt[:, 0] * xc[:, 0])[..., None] * bmat[:, 0][:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :].astype(x.dtype)
    y = (y + xc * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y, name="out_proj")
    return out.astype(x.dtype), {"conv": conv_buf[:, 1:].astype(state["conv"].dtype), "h": h}
