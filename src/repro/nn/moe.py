"""Mixture-of-Experts layer: shared experts + top-k routed experts with
dropless sort-based grouped GEMM, and expert parallelism via shard_map.

Routing follows DeepSeek-V2-lite / Moonlight: softmax router, top-k (k=6)
over 64 routed experts with renormalized gates, plus always-on shared
experts.

Execution strategies (cfg-selected, identical math):

  local      all experts on every device: sort tokens by expert ->
             `jax.lax.ragged_dot` grouped GEMM -> unsort.  Used on single
             host and as the per-shard body under EP.
  ep_psum    expert stacks sharded over the "model" mesh axis inside
             shard_map.  Each shard selects the (token, expert) pairs that
             hit its local experts (capacity-bounded, GShard-style drops),
             runs the local grouped GEMM, scatter-adds into the local token
             buffer and psums over "model".  Comm = one all-reduce of the
             token activations per MoE layer — the collective-bound baseline
             the §Perf hillclimb attacks with the a2a dispatch variant.

The router stays float (policy functions skip "router"); expert weight
stacks are (E, k, n) linear leaves, so `pack_params` gives every expert its
OWN quant scales and control-variate constants — the per-expert CV noted in
DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_map_compat
from jax.sharding import PartitionSpec as P

from repro.core.approx_linear import dense, init_dense
from repro.nn.layers import init_swiglu, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int  # per-expert FFN width (1408 for dsv2-lite)
    n_experts: int  # routed experts
    top_k: int
    n_shared: int = 0  # shared experts (width = n_shared * d_ff_expert)
    capacity_factor: float = 1.25
    impl: str = "local"  # "local" | "ep_psum"
    ep_axis: str = "model"


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    kr, ks, kg, ku, kd = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    scale = d**-0.5
    p = {
        "router": init_dense(kr, d, e, bias=False, dtype=jnp.float32),
        "experts": {
            "gate": {"w": (jax.random.normal(kg, (e, d, f)) * scale).astype(dtype)},
            "up": {"w": (jax.random.normal(ku, (e, d, f)) * scale).astype(dtype)},
            "down": {"w": (jax.random.normal(kd, (e, f, d)) * (f**-0.5)).astype(dtype)},
        },
    }
    if cfg.n_shared:
        p["shared"] = init_swiglu(ks, d, cfg.n_shared * f, dtype)
    return p


def _route(p: dict, x_flat: jax.Array, cfg: MoEConfig):
    """Top-k routing with renormalized gates.  x_flat: (N, D)."""
    logits = dense(p["router"], x_flat.astype(jnp.float32), name="router")
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)  # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _expert_ffn_sorted(experts: dict, xs: jax.Array, group_sizes: jax.Array):
    """Grouped swiglu over expert-sorted rows via ragged_dot.

    xs: (M, D) rows sorted by expert; group_sizes: (E_local,).
    Supports float expert stacks; packed (approximate) stacks run through
    the grouped approximate matmul in repro.core (quantized expert path).
    """
    from repro.core.approx_linear import QuantizedDense

    if isinstance(experts["gate"], QuantizedDense):
        from repro.core.grouped_approx import grouped_quantized_swiglu

        return grouped_quantized_swiglu(experts, xs, group_sizes)
    g = jax.lax.ragged_dot(xs, experts["gate"]["w"], group_sizes)
    u = jax.lax.ragged_dot(xs, experts["up"]["w"], group_sizes)
    h = jax.nn.silu(g) * u
    return jax.lax.ragged_dot(h, experts["down"]["w"], group_sizes)


def _moe_local(p: dict, x_flat: jax.Array, cfg: MoEConfig,
               e_start: int, e_local: int, capacity: int | None):
    """Dropless (or capacity-bounded) MoE over experts [e_start, e_start+e_local).

    Returns the combined routed-expert output for the local token buffer.
    """
    n, d = x_flat.shape
    k = cfg.top_k
    gates, idx, _ = _route(p, x_flat, cfg)

    pair_expert = idx.reshape(-1)  # (N*k,)
    pair_gate = gates.reshape(-1)
    pair_token = jnp.repeat(jnp.arange(n), k)

    local = (pair_expert >= e_start) & (pair_expert < e_start + e_local)
    # sort pairs: non-local pairs pushed to the end, locals ordered by expert
    sort_key = jnp.where(local, pair_expert - e_start, e_local)
    order = jnp.argsort(sort_key, stable=True)
    if capacity is not None and capacity < order.shape[0]:
        order = order[:capacity]
    sel_expert = sort_key[order]  # e_local == "dropped/non-local"
    sel_valid = sel_expert < e_local
    sel_token = pair_token[order]
    sel_gate = jnp.where(sel_valid, pair_gate[order], 0.0)

    xs = x_flat[sel_token]  # (M, D) gather
    group_sizes = jnp.bincount(
        jnp.where(sel_valid, sel_expert, e_local), length=e_local + 1
    )[:e_local].astype(jnp.int32)
    ys = _expert_ffn_sorted(p["experts"], xs, group_sizes)
    ys = ys * sel_gate[:, None].astype(ys.dtype)
    out = jnp.zeros((n, d), ys.dtype).at[sel_token].add(
        jnp.where(sel_valid[:, None], ys, 0.0)
    )
    return out


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, mesh=None) -> jax.Array:
    """x: (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    x_flat = x.reshape(-1, d)

    if cfg.impl == "local" or mesh is None:
        routed = _moe_local(p, x_flat, cfg, 0, cfg.n_experts, None)
    elif cfg.impl == "ep_psum":
        routed = _moe_ep_psum(p, x_flat, cfg, mesh)
    else:
        raise ValueError(cfg.impl)

    out = routed.reshape(b, t, d).astype(x.dtype)
    if "shared" in p:
        out = out + swiglu(p["shared"], x)
    return out


def _moe_ep_psum(p: dict, x_flat: jax.Array, cfg: MoEConfig, mesh) -> jax.Array:
    """Expert-parallel execution: experts sharded over cfg.ep_axis."""
    ep = cfg.ep_axis
    n_shards = mesh.shape[ep]
    assert cfg.n_experts % n_shards == 0, (cfg.n_experts, n_shards)
    e_local = cfg.n_experts // n_shards

    data_axes = tuple(a for a in mesh.axis_names if a != ep)

    def shard_fn(router, experts, xl):
        shard_id = jax.lax.axis_index(ep)
        n_loc = xl.shape[0]
        cap = int(n_loc * cfg.top_k * cfg.capacity_factor / n_shards)
        cap = max(cap, cfg.top_k)
        p_loc = {"router": router, "experts": experts}
        out = _moe_local(p_loc, xl, cfg, shard_id * e_local, e_local, cap)
        return jax.lax.psum(out, ep)

    return shard_map_compat(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(),  # router replicated
            P(ep),  # expert stacks sharded on leading (expert) dim
            P(data_axes),  # tokens sharded over data axes
        ),
        out_specs=P(data_axes),
    )(p["router"], p["experts"], x_flat)
