"""RWKV6 "Finch" blocks (arXiv:2404.05892): attention-free LM with
data-dependent decay.

Time-mix: token-shift DDLerp (low-rank data-dependent interpolation between
x_t and x_{t-1} per r/k/v/w/g stream), data-dependent per-channel decay
w_t = exp(-exp(.)), the WKV linear-attention recurrence, per-head GroupNorm,
silu-gated output.  Channel-mix: token-shift + squared-ReLU FFN with
receptance gate.

Prefill uses the chunked WKV form (same algebra as kernels/rwkv6_scan.py —
pure-jnp here so it lowers/shards under pjit; the Pallas kernel is the
TPU-target fast path).  Decode keeps (shift, state) per layer and is O(1)
per token.

Projections are `dense` leaves (approximable); the recurrence/normalization
are exact, matching the paper's array/non-array split.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.approx_linear import dense, init_dense


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_dim: int = 64
    mix_rank: int = 32  # DDLerp LoRA dim (TIME_MIX_EXTRA_DIM)
    decay_rank: int = 64  # decay LoRA dim (TIME_DECAY_EXTRA_DIM)
    wkv_chunk: int = 32

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def init_time_mix(key, cfg: RWKVConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu_rkvwg": (jax.random.normal(ks[0], (5, d)) * 0.02 + 0.5).astype(dtype),
        "mix_w1": (jax.random.normal(ks[1], (d, 5 * cfg.mix_rank)) * 0.02).astype(dtype),
        "mix_w2": (jax.random.normal(ks[2], (5, cfg.mix_rank, d)) * 0.02).astype(dtype),
        "decay_base": (jax.random.normal(ks[3], (d,)) * 0.5 - 6.0).astype(dtype),
        "decay_w1": (jax.random.normal(ks[4], (d, cfg.decay_rank)) * 0.02).astype(dtype),
        "decay_w2": (jax.random.normal(ks[5], (cfg.decay_rank, d)) * 0.02).astype(dtype),
        "bonus": (jax.random.normal(ks[6], (h, cfg.head_dim)) * 0.02).astype(dtype),
        "r": init_dense(ks[7], d, d, bias=False, dtype=dtype),
        "k": init_dense(ks[8], d, d, bias=False, dtype=dtype),
        "v": init_dense(ks[9], d, d, bias=False, dtype=dtype),
        "g": init_dense(ks[0], d, d, bias=False, dtype=dtype),
        "out": init_dense(ks[1], d, d, bias=False, dtype=dtype),
        "ln_x_scale": jnp.ones((d,), dtype),
        "ln_x_bias": jnp.zeros((d,), dtype),
    }


def _group_norm_heads(x: jax.Array, scale, bias, n_heads: int, eps=1e-5):
    """GroupNorm with one group per head.  x: (B, T, d_model)."""
    b, t, d = x.shape
    xh = x.reshape(b, t, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, t, d) * scale + bias).astype(x.dtype)


def _ddlerp(p: dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift interpolation -> (xw, xk, xv, xr, xg)."""
    dx = x_prev - x
    xx = x + dx * p["mu_x"]
    low = jnp.tanh(jnp.matmul(xx, p["mix_w1"]))  # (B, T, 5*rank)
    low = low.reshape(*low.shape[:-1], 5, -1)  # (B, T, 5, rank)
    deltas = jnp.einsum("btfr,frd->fbtd", low, p["mix_w2"])
    outs = []
    for i in range(5):
        mu = p["mu_rkvwg"][i] + deltas[i]
        outs.append(x + dx * mu)
    return outs  # order: w, k, v, r, g


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay in (0, 1): exp(-exp(base + lora))."""
    lora = jnp.matmul(jnp.tanh(jnp.matmul(xw, p["decay_w1"])), p["decay_w2"])
    return jnp.exp(-jnp.exp((p["decay_base"] + lora).astype(jnp.float32)))


def wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunked WKV (same algebra as the Pallas kernel), carrying ``state``.

    r/k/w: (B, T, H, D), v: (B, T, H, D), u: (H, D),
    state: (B, H, D, D) -> returns (out, new_state).
    """
    b, t, h, d = r.shape
    if t % chunk != 0:
        pad = chunk - t % chunk
        zeros = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    tt = r.shape[1]
    nch = tt // chunk

    def chunk_step(s, inp):
        rc, kc, vc, wc = inp  # (B, L, H, D)
        logw = jnp.log(wc.astype(jnp.float32))
        logD = jnp.cumsum(logw, axis=1)
        d_full = jnp.exp(logD[:, -1])  # (B, H, D)
        rt = rc.astype(jnp.float32) * jnp.exp(
            jnp.concatenate([jnp.zeros_like(logD[:, :1]), logD[:, :-1]], 1)
        )
        kt = kc.astype(jnp.float32) * jnp.exp(-logD)
        a = jnp.einsum("bthd,bshd->bhts", rt, kt)
        ti = jnp.arange(chunk)
        a = jnp.where(ti[:, None] > ti[None, :], a[..., :, :], 0.0)
        diag = jnp.einsum("bthd,hd,bthd->bth", rc.astype(jnp.float32), u, kc.astype(jnp.float32))
        out = jnp.einsum("bhts,bshd->bthd", a, vc.astype(jnp.float32))
        out = out + diag[..., None] * vc.astype(jnp.float32)
        out = out + jnp.einsum("bthk,bhkv->bthv", rt, s)
        new_s = d_full[..., None] * (
            s + jnp.einsum("bshk,bshv->bhkv", kt, vc.astype(jnp.float32))
        )
        return new_s, out

    xs = tuple(
        jnp.moveaxis(a.reshape(b, nch, chunk, h, d), 1, 0) for a in (r, k, v, w)
    )
    state, outs = jax.lax.scan(chunk_step, state, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tt, h, d)[:, :t]
    return out, state


def time_mix(p: dict, x: jax.Array, cfg: RWKVConfig, shift_state=None, wkv_state=None):
    """x: (B, T, D).  shift_state: (B, D) last token of previous segment."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    if shift_state is None:
        shift_state = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)

    r = dense(p["r"], xr, name="r").reshape(b, t, h, hd)
    k = dense(p["k"], xk, name="k").reshape(b, t, h, hd)
    v = dense(p["v"], xv, name="v").reshape(b, t, h, hd)
    g = dense(p["g"], xg, name="g")
    w = _decay(p, xw).reshape(b, t, h, hd)

    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, hd, hd), jnp.float32)
    out, new_state = wkv_chunked(r, k, v, w, p["bonus"], wkv_state, cfg.wkv_chunk)
    out = out.reshape(b, t, d).astype(x.dtype)
    out = _group_norm_heads(out, p["ln_x_scale"], p["ln_x_bias"], h)
    out = out * jax.nn.silu(g)
    return dense(p["out"], out, name="out"), x[:, -1, :], new_state


def time_mix_step(p: dict, x: jax.Array, cfg: RWKVConfig, shift_state, wkv_state):
    """Single-token time-mix: x (B, 1, D); O(1) state update (no chunk pad)."""
    b, _, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x_prev = shift_state[:, None, :]
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    r = dense(p["r"], xr, name="r").reshape(b, h, hd)
    k = dense(p["k"], xk, name="k").reshape(b, h, hd)
    v = dense(p["v"], xv, name="v").reshape(b, h, hd)
    g = dense(p["g"], xg, name="g")
    w = _decay(p, xw).reshape(b, h, hd)

    kv = k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    att = wkv_state + p["bonus"][None, :, :, None].astype(jnp.float32) * kv
    out = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), att)
    new_state = w[..., :, None].astype(jnp.float32) * wkv_state + kv
    out = out.reshape(b, 1, d).astype(x.dtype)
    out = _group_norm_heads(out, p["ln_x_scale"], p["ln_x_bias"], h)
    out = out * jax.nn.silu(g)
    return dense(p["out"], out, name="out"), x[:, -1, :], new_state


def init_channel_mix(key, cfg: RWKVConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "key": init_dense(k1, d, cfg.d_ff, bias=False, dtype=dtype),
        "value": init_dense(k2, cfg.d_ff, d, bias=False, dtype=dtype),
        "receptance": init_dense(k3, d, d, bias=False, dtype=dtype),
    }


def channel_mix(p: dict, x: jax.Array, shift_state=None):
    b, t, d = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    k = jnp.square(jax.nn.relu(dense(p["key"], xk, name="key")))
    kv = dense(p["value"], k, name="value")
    return jax.nn.sigmoid(dense(p["receptance"], xr, name="receptance")) * kv, x[:, -1, :]
