"""CNN substrate for the paper's accuracy evaluation (Tables 2-4, Fig. 10).

Convolutions are implemented as im2col + `dense`, because that is literally
what the paper's systolic MAC array computes: each output pixel is a k-term
dot product of weights and activation patches.  Routing convs through
`dense` means `pack_params` turns a trained float CNN into an
approximate-multiplier + control-variate CNN with zero model rewrite, with
per-conv CV constants — faithful to the TFApprox evaluation flow.

Conv parameter leaves are plain linear dicts {"w": (k*k*cin, cout), "b"};
kernel sizes are static and supplied at the call site, so packed
(QuantizedDense) leaves drop in transparently.

Model families mirror the paper's six CNNs at CPU-trainable scale:
VGG-style (VGG13/16 stand-ins), ResNet-style (ResNet44/56 stand-ins),
Inception-style (GoogLeNet stand-in) and ShuffleNet-style.  CIFAR is not
available offline (DESIGN.md); the accuracy benchmark validates the paper's
accuracy-recovery TREND on these families over a procedural dataset.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.approx_linear import dense, init_dense
from repro.quant import observers


# ---------------------------------------------------------------------------
# conv2d as im2col + dense
# ---------------------------------------------------------------------------


def init_conv(key, cin: int, cout: int, ksize: int, dtype=jnp.float32) -> dict:
    """Conv kernel stored directly in matmul layout: (k*k*cin, cout)."""
    fan_in = ksize * ksize * cin
    return {
        "w": (jax.random.truncated_normal(key, -2, 2, (fan_in, cout))
              * (2.0 / fan_in) ** 0.5).astype(dtype),
        "b": jnp.zeros((cout,), dtype),
    }


def _im2col(x: jax.Array, ksize: int, stride: int, padding: int) -> jax.Array:
    """x: (B, H, W, C) -> patches (B, Ho, Wo, k*k*C)."""
    b, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - ksize) // stride + 1
    wo = (w + 2 * padding - ksize) // stride + 1
    cols = []
    for di in range(ksize):
        for dj in range(ksize):
            cols.append(
                jax.lax.slice(
                    x,
                    (0, di, dj, 0),
                    (b, di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    return jnp.concatenate(cols, axis=-1)


def conv2d(p, x: jax.Array, ksize: int, stride: int = 1,
           padding: int | None = None, name: str = "conv") -> jax.Array:
    """p: linear leaf (float dict or QuantizedDense) in im2col layout."""
    if padding is None:
        padding = ksize // 2
    if ksize == 1 and stride == 1 and padding == 0:
        return dense(p, x, name=name)  # pointwise: no patch extraction
    patches = _im2col(x, ksize, stride, padding)
    return dense(p, patches, name=name)


def maxpool(x: jax.Array, k: int = 2, stride: int | None = None) -> jax.Array:
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def avgpool_global(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


def init_bn(c: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def batchnorm_infer(p: dict, x: jax.Array) -> jax.Array:
    """Per-channel affine (BN with folded statistics — what TFLite deploys;
    trained directly by SGD at our scale)."""
    return x * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    family: str  # vgg | resnet | inception | shufflenet
    num_classes: int = 10
    width: int = 32  # base channel count
    depth: int = 2  # blocks per stage
    img_size: int = 32
    in_channels: int = 3


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> dict:
    return {
        "vgg": _init_vgg,
        "resnet": _init_resnet,
        "inception": _init_inception,
        "shufflenet": _init_shuffle,
    }[cfg.family](key, cfg, dtype)


def cnn_apply(p: dict, x: jax.Array, cfg: CNNConfig) -> jax.Array:
    return {
        "vgg": _vgg_apply,
        "resnet": _resnet_apply,
        "inception": _inception_apply,
        "shufflenet": _shuffle_apply,
    }[cfg.family](p, x, cfg)


# --- VGG ---


def _init_vgg(key, cfg: CNNConfig, dtype) -> dict:
    w = cfg.width
    chans = [cfg.in_channels, w, w * 2, w * 4]
    keys = iter(jax.random.split(key, 3 * cfg.depth + 2))
    p: dict = {"stages": []}
    for s in range(3):
        stage, cin = [], chans[s]
        for _ in range(cfg.depth):
            stage.append({
                "conv": init_conv(next(keys), cin, chans[s + 1], 3, dtype),
                "bn": init_bn(chans[s + 1], dtype),
            })
            cin = chans[s + 1]
        p["stages"].append(stage)
    p["head"] = {
        "fc1": init_dense(next(keys), chans[-1], 4 * w, dtype=dtype),
        "fc2": init_dense(next(keys), 4 * w, cfg.num_classes, dtype=dtype),
    }
    return p


def _vgg_apply(p, x, cfg):
    for si, stage in enumerate(p["stages"]):
        with observers.scope("stages", si):
            for bi, blk in enumerate(stage):
                with observers.scope(str(bi)):
                    x = conv2d(blk["conv"], x, 3, name="conv")
                    x = jax.nn.relu(batchnorm_infer(blk["bn"], x))
        x = maxpool(x)
    x = avgpool_global(x)
    with observers.scope("head"):
        x = jax.nn.relu(dense(p["head"]["fc1"], x, name="fc1"))
        return dense(p["head"]["fc2"], x, name="fc2")


# --- ResNet (CIFAR-style basic blocks) ---


def _init_resnet(key, cfg: CNNConfig, dtype) -> dict:
    w = cfg.width
    keys = iter(jax.random.split(key, 6 * cfg.depth * 3 + 4))
    p: dict = {
        "stem": init_conv(next(keys), cfg.in_channels, w, 3, dtype),
        "stem_bn": init_bn(w, dtype),
        "stages": [],
    }
    cin = w
    for s, cout in enumerate([w, 2 * w, 4 * w]):
        stage = []
        for b in range(cfg.depth):
            blk = {
                "conv1": init_conv(next(keys), cin, cout, 3, dtype),
                "bn1": init_bn(cout, dtype),
                "conv2": init_conv(next(keys), cout, cout, 3, dtype),
                "bn2": init_bn(cout, dtype),
            }
            if cin != cout:
                blk["proj"] = init_conv(next(keys), cin, cout, 1, dtype)
            stage.append(blk)
            cin = cout
        p["stages"].append(stage)
    p["head"] = {"fc": init_dense(next(keys), cin, cfg.num_classes, dtype=dtype)}
    return p


def _resnet_apply(p, x, cfg):
    x = jax.nn.relu(batchnorm_infer(p["stem_bn"], conv2d(p["stem"], x, 3, name="stem")))
    for si, stage in enumerate(p["stages"]):
        for bi, blk in enumerate(stage):
            with observers.scope("stages", si, bi):
                stride = 2 if (bi == 0 and si > 0) else 1
                h = conv2d(blk["conv1"], x, 3, stride=stride, name="conv1")
                h = jax.nn.relu(batchnorm_infer(blk["bn1"], h))
                h = conv2d(blk["conv2"], h, 3, name="conv2")
                h = batchnorm_infer(blk["bn2"], h)
                if "proj" in blk:
                    sc = conv2d(blk["proj"], x, 1, stride=stride, padding=0, name="proj")
                elif stride != 1:
                    sc = x[:, ::stride, ::stride, :]
                else:
                    sc = x
                x = jax.nn.relu(h + sc)
    x = avgpool_global(x)
    with observers.scope("head"):
        return dense(p["head"]["fc"], x, name="fc")


# --- Inception (GoogLeNet stand-in) ---


def _init_inception(key, cfg: CNNConfig, dtype) -> dict:
    w = cfg.width
    keys = iter(jax.random.split(key, 6 * (cfg.depth + 1) + 3))
    p: dict = {"stem": init_conv(next(keys), cfg.in_channels, w, 3, dtype), "blocks": []}
    cin = w
    for _ in range(cfg.depth + 1):
        b1, b3, b5, bp = w // 2, w // 2, w // 4, w // 4
        p["blocks"].append({
            "b1": init_conv(next(keys), cin, b1, 1, dtype),
            "b3_red": init_conv(next(keys), cin, b3 // 2, 1, dtype),
            "b3": init_conv(next(keys), b3 // 2, b3, 3, dtype),
            "b5_red": init_conv(next(keys), cin, b5 // 2, 1, dtype),
            "b5": init_conv(next(keys), b5 // 2, b5, 5, dtype),
            "bp": init_conv(next(keys), cin, bp, 1, dtype),
        })
        cin = b1 + b3 + b5 + bp
    p["head"] = {"fc": init_dense(next(keys), cin, cfg.num_classes, dtype=dtype)}
    return p


def _inception_apply(p, x, cfg):
    x = jax.nn.relu(conv2d(p["stem"], x, 3, name="stem"))
    for bi, blk in enumerate(p["blocks"]):
        with observers.scope("blocks", bi):
            y1 = jax.nn.relu(conv2d(blk["b1"], x, 1, padding=0, name="b1"))
            y3 = jax.nn.relu(conv2d(blk["b3_red"], x, 1, padding=0, name="b3_red"))
            y3 = jax.nn.relu(conv2d(blk["b3"], y3, 3, name="b3"))
            y5 = jax.nn.relu(conv2d(blk["b5_red"], x, 1, padding=0, name="b5_red"))
            y5 = jax.nn.relu(conv2d(blk["b5"], y5, 5, name="b5"))
            yp = maxpool(jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)),
                                 constant_values=-jnp.inf), 3, 1)
            yp = jax.nn.relu(conv2d(blk["bp"], yp, 1, padding=0, name="bp"))
            x = jnp.concatenate([y1, y3, y5, yp], axis=-1)
        if bi % 2 == 1:
            x = maxpool(x)
    x = avgpool_global(x)
    with observers.scope("head"):
        return dense(p["head"]["fc"], x, name="fc")


# --- ShuffleNet-style (pointwise + channel shuffle + depthwise) ---


def _init_shuffle(key, cfg: CNNConfig, dtype) -> dict:
    w = cfg.width
    keys = iter(jax.random.split(key, 8 * cfg.depth + 3))
    p: dict = {"stem": init_conv(next(keys), cfg.in_channels, w, 3, dtype), "blocks": []}
    cin = w
    for s in range(2):
        cout = cin * 2
        for b in range(cfg.depth):
            blk = {
                "pw1": init_conv(next(keys), cin, cout, 1, dtype),
                "dw": {"kernel": (jax.random.normal(next(keys), (3, 3, cout)) * 0.1).astype(dtype)},
                "pw2": init_conv(next(keys), cout, cout, 1, dtype),
            }
            if b == 0:
                blk["proj"] = init_conv(next(keys), cin, cout, 1, dtype)
            p["blocks"].append(blk)
            cin = cout
    p["head"] = {"fc": init_dense(next(keys), cin, cfg.num_classes, dtype=dtype)}
    return p


def _channel_shuffle(x: jax.Array, groups: int) -> jax.Array:
    b, h, w, c = x.shape
    return (
        x.reshape(b, h, w, groups, c // groups).swapaxes(-1, -2).reshape(b, h, w, c)
    )


def _depthwise(dw: dict, x: jax.Array, stride: int) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        dw["kernel"][..., None].transpose(0, 1, 3, 2),  # (3, 3, 1, C)
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )


def _shuffle_apply(p, x, cfg):
    x = jax.nn.relu(conv2d(p["stem"], x, 3, name="stem"))
    for bi, blk in enumerate(p["blocks"]):
        with observers.scope("blocks", bi):
            first_in_stage = "proj" in blk
            stride = 2 if first_in_stage else 1
            h = jax.nn.relu(conv2d(blk["pw1"], x, 1, padding=0, name="pw1"))
            h = _channel_shuffle(h, 4)
            h = _depthwise(blk["dw"], h, stride)
            h = conv2d(blk["pw2"], h, 1, padding=0, name="pw2")
            if first_in_stage:
                sc = conv2d(blk["proj"], x, 1, stride=stride, padding=0, name="proj")
            else:
                sc = x
            x = jax.nn.relu(h + sc)
    x = avgpool_global(x)
    with observers.scope("head"):
        return dense(p["head"]["fc"], x, name="fc")
