"""Model substrate: pure-functional layers over plain-dict parameter pytrees.

Every matmul in every layer routes through
:func:`repro.core.approx_linear.dense`, so the paper's approximate-multiplier
+ control-variate technique is a *parameter transformation*
(``pack_params``), never a model rewrite.
"""

from repro.nn import layers, attention, moe, rwkv, ssm, cnn  # noqa: F401
