"""Attention variants for the assigned architectures.

  * GQA multi-head attention with optional qk-norm (qwen3), sliding window
    (hymba), bidirectional mode (hubert), RoPE / M-RoPE (qwen2-vl) or no
    positional encoding.
  * MLA — DeepSeek-V2 multi-head latent attention (kv_lora compression),
    with decompressed prefill and weight-absorbed decode over the latent
    cache.

Both expose ``prefill`` (full-sequence, also the training forward) and
``decode_step`` (single token against a cache).  Caches are dicts of arrays
so they shard/checkpoint like any other pytree:

  GQA cache: {"k": (B, Hkv, S, hd), "v": (B, Hkv, S, hd), "pos": i32[]}
  MLA cache: {"latent": (B, S, r), "rope": (B, S, dr), "pos": i32[]}

QKV/O projections are `dense` leaves (approximable); score/softmax/context
math is exact vector-unit work, matching the paper's array/non-array split.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.approx_linear import dense, dense_group, init_dense
from repro.nn.layers import (
    apply_rope,
    init_rmsnorm,
    mrope_angles,
    rmsnorm,
    rope_angles,
)
from repro.quant import observers


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    qk_norm: bool = False
    window: int | None = None  # sliding-window size (hymba)
    rope: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    qkv_bias: bool = False  # qwen2-vl uses bias on qkv

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "q": init_dense(kq, cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias, dtype=dtype),
        "k": init_dense(kk, cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "v": init_dense(kv, cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "o": init_dense(ko, cfg.q_dim, cfg.d_model, bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.head_dim, dtype)
        p["k_norm"] = init_rmsnorm(cfg.head_dim, dtype)
    return p


def _angles(cfg: AttnConfig, positions: jax.Array):
    """positions: (B, T) int32, or (3, B, T) for mrope."""
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        if positions.ndim == 2:  # text-only: broadcast the same ids
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(positions, cfg.head_dim, cfg.mrope_sections, cfg.rope_theta)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _project_qkv(p: dict, x: jax.Array, cfg: AttnConfig, angles):
    b, t, _ = x.shape
    if "qkv" in p:  # fan-out-fused serving pack: one wide-N projection call
        qkv = dense_group(p["qkv"], x)
        q, k, v = qkv["q"], qkv["k"], qkv["v"]
    else:
        q = dense(p["q"], x, name="q")
        k = dense(p["k"], x, name="k")
        v = dense(p["v"], x, name="v")
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if angles is not None:
        cos, sin = angles
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(
    q: jax.Array,  # (B, Tq, Hq, d)
    k: jax.Array,  # (B, Tk, Hkv, d)
    v: jax.Array,  # (B, Tk, Hkv, d)
    *,
    causal: bool,
    window: int | None,
    kv_valid_len: jax.Array | None = None,
) -> jax.Array:
    """Grouped-head attention without materializing repeated KV heads.

    Query rows are aligned to the END of the key axis (training: Tq == Tk;
    decode: Tq == 1 with ``kv_valid_len`` marking the filled cache length).
    """
    b, tq, hq, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * (d**-0.5)

    end = kv_valid_len if kv_valid_len is not None else jnp.int32(tk)
    q_pos = jnp.arange(tq)[:, None] + (end - tq)
    k_pos = jnp.arange(tk)[None, :]
    mask = k_pos < end  # only filled cache slots
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return ctx.reshape(b, tq, hq, d)


def attention_prefill(
    p: dict,
    x: jax.Array,  # (B, T, D)
    cfg: AttnConfig,
    positions: jax.Array | None = None,
) -> jax.Array:
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    q, k, v = _project_qkv(p, x, cfg, _angles(cfg, positions))
    ctx = _sdpa(q, k, v, causal=cfg.causal, window=cfg.window)
    return dense(p["o"], ctx.reshape(b, t, cfg.q_dim), name="o")


def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, cfg.kv_heads, max_len, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.kv_heads, max_len, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


#: fixed-point scale for int8 KV caches (values are O(1) after qk-norm /
#: rope; 1/16 resolution keeps decode logits within ~1e-2 of bf16 — the
#: int8-cache serving mode halves decode cache traffic, §Perf)
KV_INT8_SCALE = 16.0


def _to_cache(x: jax.Array, dtype) -> jax.Array:
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x * KV_INT8_SCALE), -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def _from_cache(x: jax.Array, dtype) -> jax.Array:
    if x.dtype == jnp.int8:
        return x.astype(dtype) * (1.0 / KV_INT8_SCALE)
    return x.astype(dtype)


def attention_decode_step(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,
    cfg: AttnConfig,
) -> tuple[jax.Array, dict]:
    """One-token decode against the (B, Hkv, S, d) cache (bf16 or int8).

    The score/context einsums consume the cache LAYOUT DIRECTLY — an earlier
    version transposed the full cache to (B, S, H, d) per layer per token,
    which materialized ~77 GB/step of pure layout traffic on the decode_32k
    cells (EXPERIMENTS.md §Perf, qwen3 decode iteration 1)."""
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, _angles(cfg, positions))
    # cache layout (B, Hkv, S, d); new k/v: (B, 1, Hkv, d)
    k_c = jax.lax.dynamic_update_slice(
        cache["k"], _to_cache(jnp.moveaxis(k, 1, 2), cache["k"].dtype), (0, 0, pos, 0)
    )
    v_c = jax.lax.dynamic_update_slice(
        cache["v"], _to_cache(jnp.moveaxis(v, 1, 2), cache["v"].dtype), (0, 0, pos, 0)
    )

    hq, hkv, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    logits = jnp.einsum(
        "bqhgd,bhkd->bhgqk", qg, _from_cache(k_c, q.dtype)) * (d**-0.5)
    mask = jnp.arange(k_c.shape[2]) < (pos + 1)
    if cfg.window is not None:
        mask = mask & (jnp.arange(k_c.shape[2]) > pos - cfg.window)
    logits = jnp.where(mask[None, None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bhkd->bqhgd", probs, _from_cache(v_c, q.dtype))
    y = dense(p["o"], ctx.reshape(b, 1, cfg.q_dim), name="o")
    return y, {"k": k_c, "v": v_c, "pos": pos + 1}


def attention_decode_ring(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # k/v: (B, Hkv, W, d) ring buffers
    cfg: AttnConfig,
) -> tuple[jax.Array, dict]:
    """Sliding-window decode against a RING cache of length W.

    Invariant: absolute position a lives at slot a mod W.  The window mask
    is implicit — the ring only ever holds the last W positions; slots not
    yet written (pos < W) are masked via the recovered absolute position
    abs_j = pos - ((pos - j) mod W) >= 0.
    """
    b = x.shape[0]
    pos = cache["pos"]
    w_len = cache["k"].shape[2]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, _angles(cfg, positions))
    slot = pos % w_len
    k_c = jax.lax.dynamic_update_slice(
        cache["k"], jnp.moveaxis(k, 1, 2).astype(cache["k"].dtype), (0, 0, slot, 0)
    )
    v_c = jax.lax.dynamic_update_slice(
        cache["v"], jnp.moveaxis(v, 1, 2).astype(cache["v"].dtype), (0, 0, slot, 0)
    )

    hq, hkv, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    kk = jnp.moveaxis(k_c, 1, 2).astype(q.dtype)  # (B, W, Hkv, d)
    vv = jnp.moveaxis(v_c, 1, 2).astype(q.dtype)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kk) * (d**-0.5)
    j = jnp.arange(w_len)
    abs_j = pos - ((pos - j) % w_len)
    mask = abs_j >= 0
    logits = jnp.where(mask[None, None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vv).reshape(b, 1, hq * d)
    y = dense(p["o"], ctx, name="o")
    return y, {"k": k_c, "v": v_c, "pos": pos + 1}


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32) -> dict:
    kq, ka, kb, ko = jax.random.split(key, 4)
    h = cfg.n_heads
    return {
        "q": init_dense(kq, cfg.d_model, h * cfg.qk_head_dim, bias=False, dtype=dtype),
        "kv_a": init_dense(
            kq, cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, bias=False, dtype=dtype
        ),
        "kv_a_norm": init_rmsnorm(cfg.kv_lora_rank, dtype),
        # kv_b stays float (absorbed-decode einsums need the raw matrix; see
        # DESIGN.md Arch-applicability) — policy functions skip "kv_b".
        "kv_b": init_dense(
            kb,
            cfg.kv_lora_rank,
            h * (cfg.qk_nope_dim + cfg.v_head_dim),
            bias=False,
            dtype=dtype,
        ),
        "o": init_dense(ko, h * cfg.v_head_dim, cfg.d_model, bias=False, dtype=dtype),
    }


def _mla_q(p, x, cfg: MLAConfig, positions):
    b, t, _ = x.shape
    q = dense(p["q"], x, name="q").reshape(b, t, cfg.n_heads, cfg.qk_head_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    cos, sin = rope_angles(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(p, x, cfg: MLAConfig, positions):
    kv_a = dense(p["kv_a"], x, name="kv_a")
    latent = rmsnorm(p["kv_a_norm"], kv_a[..., : cfg.kv_lora_rank])
    k_rope = kv_a[..., cfg.kv_lora_rank :][:, :, None, :]  # (B, T, 1, dr)
    cos, sin = rope_angles(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0, :]  # shared across heads
    return latent, k_rope


def mla_prefill(p, x, cfg: MLAConfig, positions=None) -> jax.Array:
    """Decompressed path: materialize per-head K/V from the latent."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    latent, k_rope = _mla_latent(p, x, cfg, positions)
    kv = dense(p["kv_b"], latent, name="kv_b").reshape(
        b, t, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim
    )
    k_nope, v = kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim :]

    scale = cfg.qk_head_dim**-0.5
    logits = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    ) * scale
    q_pos = jnp.arange(t)[:, None]
    mask = jnp.arange(t)[None, :] <= q_pos
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return dense(p["o"], ctx.reshape(b, t, -1), name="o")


def init_mla_cache(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return {
        "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_decode_step(p, x, cache: dict, cfg: MLAConfig) -> tuple[jax.Array, dict]:
    """Weight-absorbed decode: attention runs entirely in latent space.

    q~ = q_nope @ W_UK  per head (r-dim);  logits = q~ . latent + rope part;
    ctx_latent = probs . latent;  out_head = ctx_latent @ W_UV.
    """
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    latent_t, k_rope_t = _mla_latent(p, x, cfg, positions)

    lat_c = jax.lax.dynamic_update_slice(
        cache["latent"], latent_t.astype(cache["latent"].dtype), (0, pos, 0)
    )
    rope_c = jax.lax.dynamic_update_slice(
        cache["rope"], k_rope_t.astype(cache["rope"].dtype), (0, pos, 0)
    )

    w_b = p["kv_b"]["w"].reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim + cfg.v_head_dim
    )
    w_uk, w_uv = w_b[..., : cfg.qk_nope_dim], w_b[..., cfg.qk_nope_dim :]

    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)  # absorbed q
    scale = cfg.qk_head_dim**-0.5
    lat = lat_c.astype(x.dtype)
    logits = (
        jnp.einsum("bqhr,bkr->bhqk", q_lat, lat)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, rope_c.astype(x.dtype))
    ) * scale
    mask = jnp.arange(lat_c.shape[1])[None, :] < (pos + 1)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs, lat)
    ctx = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_uv)
    y = dense(p["o"], ctx.reshape(b, 1, -1), name="o")
    return y, {"latent": lat_c, "rope": rope_c, "pos": pos + 1}
