"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff_expert=1408
vocab=102400, MLA kv_lora=512, MoE 64 routed experts top-6 + 2 shared,
first layer dense (d_ff 10944).  [arXiv:2405.04434; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=10944,          # the first (dense) layer's FFN width
    vocab=102400,
    attn="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mlp="moe",
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_dense_layers=1,
    remat="full",
)
