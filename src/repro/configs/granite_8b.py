"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code model (rope theta 1e7, tied embeddings).
[arXiv:2405.04324; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=49152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    remat="full",
)
