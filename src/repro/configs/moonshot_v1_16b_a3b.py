"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16)
d_ff_expert=1408 vocab=163840, MoE 64 routed experts top-6 + 2 shared
(kimi/moonlight family).  [hf:moonshotai/Moonlight-16B-A3B]

The assigned config specifies GQA (kv=16) and 48 layers; all layers MoE.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    mlp="moe",
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_dense_layers=0,
    remat="full",
)
