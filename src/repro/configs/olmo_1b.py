"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 —
non-parametric LayerNorm, tied embeddings.  [arXiv:2402.00838; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=8192,
    vocab=50304,
    norm="nonparametric_ln",
    tie_embeddings=True,
    remat="full",
)
