"""Unified architecture configuration.

One frozen dataclass describes every assigned architecture; model builders
(models/lm.py, models/rwkv_lm.py) interpret it.  Published configs live in
one module per arch (configs/<id>.py) and are registered in
configs/registry.py.  ``reduced()`` derives the CPU-smoke-test variant of
the same family (small depth/width/vocab/experts — structure preserved).
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Continuous-batching serving knobs (consumed by ``repro.serving``).

    ``slots`` fixes the decode batch shape (the jitted step never
    recompiles); ``max_len`` is the per-slot KV capacity; prompts are
    processed in ``prefill_chunk``-token pieces interleaved with decode.
    Slot counts <= repro.kernels.ops.DECODE_M_MAX additionally hit the
    packed-dense kernels' decode-specialized (thin-M, single-K-step) tiles.
    """

    slots: int = 8
    max_len: int = 256
    prefill_chunk: int = 32
    max_queue: int = 256
    cache_dtype: str = "bfloat16"  # "bfloat16" | "float32" | "int8"
    interleave: bool = True  # alternate prefill/decode when both are pending
    #: decode rows ride chunk-shaped prefill calls with n_valid=1, so a
    #: running decode advances every iteration (no stall behind prefill
    #: turns); off falls back to whole-batch alternation (``interleave``)
    mixed_batches: bool = True
    #: "contiguous": every slot owns a max_len KV stripe (the original
    #: layout).  "paged": slots map logical positions onto refcounted
    #: fixed-size blocks from a shared pool (repro.serving.paged) —
    #: heterogeneous lengths stop costing max_len each, and requests
    #: sharing a prompt prefix attach to already-filled blocks
    #: copy-on-write instead of re-prefilling them.  Either layout keeps
    #: the two-compiled-shapes invariant for its jitted step.
    kv_layout: str = "contiguous"  # "contiguous" | "paged"
    kv_block_size: int = 16  # tokens per KV block (paged layout)
    #: usable KV blocks in the shared pool; 0 = capacity parity with the
    #: contiguous layout (slots * ceil(max_len / kv_block_size))
    kv_blocks: int = 0
    #: content-hash prefix cache over full prompt blocks (paged layout):
    #: requests sharing a cached prefix skip its prefill entirely
    prefix_cache: bool = True
    #: request-span tracing (repro.serving.telemetry): record typed span
    #: events (queued/admitted/prefill_chunk/decode_step/...) into a
    #: per-engine ring buffer, exportable as JSONL or Chrome trace JSON
    trace: bool = False
    trace_buffer: int = 65536  # span ring capacity; oldest events dropped
    #: windowed time-series: every ``metrics_window_s`` seconds the
    #: metrics emit one sample of rates/depths/utilization (0 disables)
    metrics_window_s: float = 0.0
    #: approximation-error probe: every N engine steps re-run one
    #: scheduled batch row through the exact-int8 path and record
    #: per-layer + logits error moments (repro.quant.error_probe);
    #: 0 disables (the default — two extra eager forwards per probe)
    error_probe_every: int = 0
    #: self-verifying speculative decode (repro.serving.speculative):
    #: each decoding slot drafts up to k greedy tokens through the
    #: APPROXIMATE draft parameters on the thin (slots, 1) step, then one
    #: chunk-shaped EXACT call verifies all of them at once; the longest
    #: agreeing prefix plus the verifier's correction token is emitted,
    #: so outputs stay bit-identical to plain exact decode.  0 disables.
    #: Requires ``ServingEngine(..., draft_params=...)``.
    speculative_k: int = 0
    #: engine-side NaN/divergence detection on every step's consumed
    #: logits columns: flagged rows are quarantined — KV cursor rolled
    #: back, the step replayed on the exact pack — before any token is
    #: emitted (repro.quant.faults).  Implied on when a fault injector is
    #: attached; off (the default) costs nothing on the hot path.
    detect_faults: bool = False
    #: A/B shadow serving (repro.serving.shadow): every round(1/fraction)
    #: finished requests, one replays teacher-forced through a SECOND
    #: NumericsSpec pack on the same engine; token agreement, logit-delta
    #: moments and modeled power feed an automated accuracy-vs-power
    #: verdict.  0 disables.  Requires ``ServingEngine(shadow_params=)``.
    shadow_fraction: float = 0.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "vlm", "audio", "ssm"]
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # norm / positional
    norm: Literal["rmsnorm", "layernorm", "nonparametric_ln"] = "rmsnorm"
    qk_norm: bool = False
    rope: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)

    # attention
    attn: Literal["gqa", "mla", "none"] = "gqa"
    causal: bool = True
    window: int | None = None  # sliding-window attention
    qkv_bias: bool = False
    # MLA (deepseek-v2)
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # mlp
    mlp: Literal["swiglu", "gelu", "moe"] = "swiglu"
    mlp_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    moe_impl: str = "local"  # "local" | "ep_psum" (launch overrides for pods)
    capacity_factor: float = 1.25

    # hybrid SSM heads (hymba)
    parallel_ssm: bool = False
    ssm_state: int = 16
    ssm_expand: int = 2
    # rwkv
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # io
    input_mode: Literal["tokens", "embeds"] = "tokens"
    tie_embeddings: bool = False

    # execution
    scan_layers: bool = True
    remat: Literal["none", "full", "dots"] = "none"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # sequence parallelism: shard the residual stream's sequence axis over
    # "model" between blocks (Megatron-SP style; GSPMD inserts the
    # all-gather/reduce-scatter pairs).  Cuts per-layer saved activations by
    # the TP degree — the §Perf lever for the large dense train cells.
    sequence_parallel: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived sub-configs ------------------------------------------------

    def attn_config(self):
        from repro.nn.attention import AttnConfig

        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            kv_heads=self.kv_heads,
            head_dim=self.head_dim,
            causal=self.causal,
            qk_norm=self.qk_norm,
            window=self.window,
            rope=self.rope,
            rope_theta=self.rope_theta,
            mrope_sections=self.mrope_sections,
            qkv_bias=self.qkv_bias,
        )

    def mla_config(self):
        from repro.nn.attention import MLAConfig

        return MLAConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim,
            qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim,
            rope_theta=self.rope_theta,
        )

    def moe_config(self):
        from repro.nn.moe import MoEConfig

        return MoEConfig(
            d_model=self.d_model,
            d_ff_expert=self.d_ff_expert,
            n_experts=self.n_experts,
            top_k=self.top_k,
            n_shared=self.n_shared_experts,
            capacity_factor=self.capacity_factor,
            impl=self.moe_impl,
        )

    def ssm_config(self):
        from repro.nn.ssm import SSMConfig

        return SSMConfig(
            d_model=self.d_model,
            d_inner=self.ssm_expand * self.d_model,
            d_state=self.ssm_state,
        )

    def rwkv_config(self):
        from repro.nn.rwkv import RWKVConfig

        return RWKVConfig(
            d_model=self.d_model, d_ff=self.d_ff, head_dim=self.rwkv_head_dim
        )

    # -- bookkeeping ---------------------------------------------------------

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear-attn / windowed hybrids)."""
        return self.rwkv or (self.parallel_ssm and self.window is not None)

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only archs have no decode step

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            tm = d * d * 5 + d * (5 * 32 + 5 * 32) + d * 64 * 2 + 2 * d
            cm = d * ff * 2 + d * d
            return emb + L * (tm + cm + 4 * d)
        if self.attn == "mla":
            attn = (
                d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.kv_heads * hd + self.n_heads * hd * d
        if self.mlp == "moe":
            moe_l = (
                3 * d * self.d_ff_expert * self.n_experts
                + 3 * d * self.d_ff_expert * self.n_shared_experts
                + d * self.n_experts
            )
            dense_l = 3 * d * ff
            n_moe = L - self.first_dense_layers
            mlp_total = n_moe * moe_l + self.first_dense_layers * dense_l
        else:
            mlp_total = L * 3 * d * ff
        ssm = 0
        if self.parallel_ssm:
            di = self.ssm_expand * d
            ssm = L * (2 * d * di + di * d + di * (self.ssm_state * 2 + d // 16))
        return emb + L * attn + mlp_total + ssm

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed top-k)."""
        if self.mlp != "moe":
            return self.param_count()
        full = self.param_count()
        moe_all = 3 * self.d_model * self.d_ff_expert * self.n_experts
        moe_act = 3 * self.d_model * self.d_ff_expert * self.top_k
        n_moe = self.n_layers - self.first_dense_layers
        return full - n_moe * (moe_all - moe_act)

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant: same family/structure, tiny dims."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 + self.first_dense_layers,
            d_model=64,
            n_heads=4,
            kv_heads=max(1, min(self.kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_ff_expert=32 if self.d_ff_expert else 0,
            kv_lora_rank=32,
            qk_nope_dim=16,
            qk_rope_dim=8,
            v_head_dim=16,
            rwkv_head_dim=16,
            mrope_sections=(2, 3, 3) if self.rope == "mrope" else self.mrope_sections,
            window=min(self.window, 8) if self.window else None,
            remat="none",
            compute_dtype="float32",
            moe_impl="local",
        )
