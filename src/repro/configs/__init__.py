"""Architecture configs: one module per assigned architecture (exact published
hyper-parameters) + the paper's CNN suite + shared shape definitions."""

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCH_REGISTRY, get_config, list_archs

__all__ = ["ArchConfig", "ARCH_REGISTRY", "get_config", "list_archs"]
