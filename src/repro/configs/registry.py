"""Registry: arch id -> ArchConfig (exact assigned configs) + CNN suite."""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.deepseek_67b import CONFIG as _ds67
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moon

ARCH_REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _qwen3,
        _ds67,
        _olmo,
        _granite,
        _hymba,
        _qwen2vl,
        _hubert,
        _rwkv6,
        _dsv2,
        _moon,
    )
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return ARCH_REGISTRY[name[: -len("-reduced")]].reduced()
    return ARCH_REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)
