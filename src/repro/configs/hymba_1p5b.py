"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer,
sliding-window attention (window 1024).  [arXiv:2411.13676; hf]

Simplifications recorded in DESIGN.md: meta-tokens and the few
global-attention layers are omitted; all layers use SWA + parallel SSM, so
the arch is sub-quadratic and runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    window=1024,
    parallel_ssm=True,
    ssm_state=16,
    ssm_expand=2,
    remat="full",
)
