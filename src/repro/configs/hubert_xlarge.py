"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only transformer (same arch as wav2vec2).  [arXiv:2106.07447]

The conv waveform frontend is a STUB per the assignment: inputs are
precomputed frame embeddings.  Training objective is HuBERT-style masked
frame cluster prediction (CE on masked frames).  Encoder-only: no decode
shapes (recorded skip).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    kv_heads=16,
    d_ff=5120,
    vocab=504,
    norm="layernorm",
    mlp="gelu",
    mlp_bias=True,
    rope="none",
    causal=False,
    input_mode="embeds",
    remat="full",
)
