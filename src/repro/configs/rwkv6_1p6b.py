"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: data-dependent decay, DDLerp token shift, WKV
linear-attention recurrence.  [arXiv:2404.05892]

Attention-free: decode state is O(1) in sequence length, so long_500k runs.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,       # wkv heads = d_model / rwkv_head_dim
    kv_heads=32,
    d_ff=7168,
    vocab=65536,
    attn="none",
    rwkv=True,
    rwkv_head_dim=64,
    norm="layernorm",
    remat="full",
)
