"""The paper's six CNNs (Tables 2-4) at CPU-trainable scale.

Name mapping (paper -> family stand-in): GoogLeNet -> inception,
ResNet44/ResNet56 -> resnet (two depths), ShuffleNet -> shufflenet,
VGG13/VGG16 -> vgg (two depths).  CIFAR-10/100 are emulated by the
procedural dataset in repro.data.vision at matching image geometry
(32x32x3) and class counts.
"""

from __future__ import annotations

from repro.nn.cnn import CNNConfig

CNN_SUITE: dict[str, CNNConfig] = {
    "googlenet": CNNConfig(family="inception", width=32, depth=2),
    "resnet44": CNNConfig(family="resnet", width=16, depth=2),
    "resnet56": CNNConfig(family="resnet", width=16, depth=3),
    "shufflenet": CNNConfig(family="shufflenet", width=24, depth=2),
    "vgg13": CNNConfig(family="vgg", width=32, depth=2),
    "vgg16": CNNConfig(family="vgg", width=32, depth=3),
}


def get_cnn(name: str, num_classes: int = 10) -> CNNConfig:
    import dataclasses

    return dataclasses.replace(CNN_SUITE[name], num_classes=num_classes)
