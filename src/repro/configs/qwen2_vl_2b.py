"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE (sections 16/24/24 over head_dim 128), dynamic
resolution.  [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: prefill consumes
precomputed patch/text embeddings (B, T, d_model) plus 3D M-RoPE position
ids; decode consumes generated token ids through the embedding table.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    input_mode="embeds",
    remat="full",
)
