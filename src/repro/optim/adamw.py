"""AdamW with decoupled weight decay and global-norm gradient clipping.

Pure-pytree implementation (no optax in this container).  The second-moment
accumulator dtype is configurable (f32 default; bf16 halves optimizer HBM —
a recorded distributed-memory lever for the 67B FSDP cell).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves m/v memory


def _mdt(cfg: AdamWConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, _mdt(cfg))
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(path: tuple, leaf) -> bool:
    """No weight decay on norms/biases/scalars (standard)."""
    names = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    if leaf.ndim <= 1:
        return False
    return not any(s in names for s in ("norm", "scale", "bias", "ln_"))


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig, lr: jax.Array | float
) -> tuple[Any, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = _mdt(cfg)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if _decay_mask(path, p):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    p_leaves = [l for _, l in flat[0]]
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state["m"])
    v_leaves = jax.tree.leaves(state["v"])
    out = [upd(pa, p, g, m, v) for pa, p, g, m, v in
           zip(paths, p_leaves, g_leaves, m_leaves, v_leaves)]
    treedef = flat[1]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}
