"""int8 error-feedback gradient compression (distributed-optimization trick).

For data-parallel training, gradients cross the pod interconnect every step.
Quantizing them to int8 (per-leaf absmax scale) cuts the all-reduce bytes 4x
vs f32 / 2x vs bf16; the quantization residual is carried in an error-
feedback accumulator so the bias vanishes over steps (Karimireddy et al.'s
EF-SGD argument).  This is also a natural companion to the paper: the same
"cheap arithmetic + explicit error compensation" structure, applied to the
communication domain instead of the multiplier array.

`compress_decompress` is the numerics (usable under pjit — XLA then reduces
the already-quantized values); `runtime/overlap.py` provides the shard_map
all-reduce that actually moves int8 on the wire.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

CompressorState = Any  # pytree of residuals, like grads


def compressor_init(grads_like: Any) -> CompressorState:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(
    grads: Any, state: CompressorState
) -> tuple[Any, CompressorState]:
    """Error-feedback int8 round-trip: returns (decompressed grads, state')."""

    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize_leaf(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(leaf, grads, state)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, res
