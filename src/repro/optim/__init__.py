"""Optimizer substrate: AdamW with decoupled weight decay, global-norm
clipping, LR schedules, and int8 error-feedback gradient compression."""

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedules import warmup_cosine, constant_lr
from repro.optim.grad_compress import (
    CompressorState,
    compressor_init,
    compress_decompress,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "warmup_cosine",
    "constant_lr",
    "CompressorState",
    "compressor_init",
    "compress_decompress",
]
