"""Self-verifying speculative decode: the approximate model drafts, the
exact model verifies.

The paper's control-variate scheme hands us two *numerics personalities of
the same weights*: a cheap perforated+CV path and an exact-int8 path, packed
from one checkpoint (`repro.launch.serve.build_serving_params` under two
NumericsSpecs).  That is exactly the draft/verifier pair speculative
decoding wants — with zero extra parameter memory — and it turns
approximation error from an accuracy cost into pure latency headroom:
outputs stay bit-identical to exact-int8 greedy decode, and the draft
acceptance rate becomes a *measurable draft-quality signal* for the CV knob
(closing the loop the error probe opened: the probe reports numeric error,
acceptance reports its argmax-level consequence).

One speculative round, per participating slot
=============================================

State before a round: the request has emitted ``g`` tokens, the last one
``x = generated[-1]`` not yet fed to the model, cursor ``L = plen + g - 1``.

1. **Plan.**  ``k_eff = min(k, budget - 1, chunk - 1)`` where ``budget`` is
   the remaining generation allowance.  The ``budget - 1`` cap guarantees
   the round's emissions (``<= k_eff + 1``) never exceed the budget and
   that every cursor the draft phase writes stays ``<= max_len - 1`` (the
   thin-call fast path in ``_slot_update`` cannot clamp) and inside the
   paged layout's up-front block reservation.  Slots with ``k_eff == 0``
   (one token of budget left) ride the verify call as plain ``n_valid = 1``
   decode rows instead.
2. **Draft.**  ``max(k_eff)`` thin ``(slots, 1)`` calls with the DRAFT
   parameters, each feeding the previous greedy output (``x`` first);
   row ``b`` participates while ``i < k_eff[b]`` and pads with
   ``n_valid = 0`` after.  This writes *approximate* K/V at ``[L, L+k)``
   and collects drafts ``d_1 .. d_k``.
3. **Rollback.**  Cursors retreat to their pre-draft values (a pure cursor
   move — see ``repro.models.lm.rollback_slots``).  The draft K/V above the
   cursor is now masked, and the verify call overwrites it with exact K/V.
4. **Verify.**  ONE chunk-shaped call with the EXACT parameters: verify
   rows carry ``[x, d_1 .. d_k]`` with ``n_valid = k + 1`` (PR 4's
   mixed-batch machinery — decode rows riding the chunk shape — already
   proved chunk-riding rows token-identical to thin calls), prefill rows
   their next prompt chunk, plain rows their one token.  Column ``i``'s
   argmax is the exact model's greedy token ``v_{i+1}`` after input ``i``.
5. **Accept.**  ``j`` = longest prefix with ``v_i == d_i``.  The emission
   candidates are ``v_1 .. v_{j+1}`` — the agreeing drafts plus the
   verifier's correction token, all of them *exact-model* outputs, so the
   emitted stream is bit-identical to sequential exact greedy decode by
   induction (every verified position's inputs and attended K/V are the
   accepted exact history).
6. **Stop + final rollback.**  Candidates are emitted one at a time through
   the engine's normal stop check; eos/length can only fire on an emitted
   (= accepted) token — a drafted-but-rejected eos is never seen by the
   stop logic.  The cursor lands at ``L + emitted``; exact K/V beyond it
   (rejected positions, or accepted-but-truncated ones) stays masked until
   overwritten next round.

Compile-shape accounting
========================

The engine's one jitted step takes the parameters as an argument, so the
jit cache keys on (parameter structure, token shape).  Draft parameters
only ever run the ``(slots, 1)`` shape; the exact parameters only ever run
``(slots, chunk)`` — under speculation even decode-only turns go
chunk-shaped (as ``n_valid = 1`` rows), never thin.  Exactly two cache
entries per KV layout, the same bound as non-speculative serving.

This module is pure host-side planning/acceptance logic; the engine owns
dispatch and the scheduler owns batch construction
(``SlotScheduler.draft_batch`` / ``verify_batch``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.request import Request, RequestState

__all__ = ["SpecRow", "SpecRound", "plan_round", "draft_inputs",
           "record_drafts", "accept"]


@dataclasses.dataclass
class SpecRow:
    """One decoding slot's state across a single speculative round."""

    req: Request
    #: draft tokens this round (>= 1; capped by remaining budget and chunk)
    k_eff: int
    #: greedy draft tokens d_1..d_k_eff, filled during the draft phase
    drafts: list[int] = dataclasses.field(default_factory=list)
    #: longest agreeing draft prefix (set at verify; the acceptance metric
    #: counts THIS, independent of stop-condition truncation)
    accepted: int = 0
    #: tokens actually emitted (accepted prefix + correction, truncated at
    #: the first stop condition); the final cursor is base + emitted
    emitted: int = 0


@dataclasses.dataclass
class SpecRound:
    """One engine iteration's speculative plan.

    ``prefilling`` rows advance their prompt chunk inside the verify call;
    ``spec_rows`` draft then verify; ``plain`` rows (no draft budget this
    round) decode one token as ``n_valid = 1`` riders on the verify call —
    keeping every exact-parameter dispatch chunk-shaped."""

    prefilling: list[Request]
    spec_rows: list[SpecRow]
    plain: list[Request]

    @property
    def max_k(self) -> int:
        return max((row.k_eff for row in self.spec_rows), default=0)


def plan_round(active: dict[int, Request], k: int,
               prefill_chunk: int) -> SpecRound | None:
    """Partition the active requests into this round's roles.

    ``k_eff = min(k, budget - 1, chunk - 1)``: the budget cap makes the
    round's maximum emission count (``k_eff + 1``) fit the remaining
    generation allowance — which is also what keeps draft-phase cursors
    ``<= max_len - 1`` and verify writes inside the paged layout's
    reserved blocks; the chunk cap fits ``[x, d_1..d_k]`` in one verify
    row.  Returns None when nothing is runnable."""
    prefilling = [r for r in active.values()
                  if r.state == RequestState.PREFILL]
    decoding = [r for r in active.values()
                if r.state == RequestState.DECODE]
    if not prefilling and not decoding:
        return None
    spec_rows: list[SpecRow] = []
    plain: list[Request] = []
    for r in decoding:
        budget = r.max_new_tokens - len(r.generated)
        k_eff = min(k, budget - 1, prefill_chunk - 1)
        if k_eff >= 1:
            spec_rows.append(SpecRow(r, k_eff))
        else:
            plain.append(r)
    return SpecRound(prefilling, spec_rows, plain)


def draft_inputs(rnd: SpecRound, slots: int,
                 i: int) -> tuple[np.ndarray, np.ndarray]:
    """Token/n_valid arrays for draft call ``i`` (thin ``(slots, 1)``).

    Each participating row feeds its previous greedy output: the request's
    last emitted token on call 0, then its own latest draft.  Rows done
    drafting (and prefill/plain/idle slots) are ``n_valid = 0`` padding —
    their cursors do not move and their writes are masked."""
    tokens = np.zeros((slots, 1), np.int32)
    n_valid = np.zeros((slots,), np.int32)
    for row in rnd.spec_rows:
        if i < row.k_eff:
            r = row.req
            tokens[r.slot, 0] = row.drafts[-1] if row.drafts else r.generated[-1]
            n_valid[r.slot] = 1
    return tokens, n_valid


def record_drafts(rnd: SpecRound, i: int, toks: np.ndarray) -> None:
    """Fold draft call ``i``'s per-slot argmax into each active row."""
    for row in rnd.spec_rows:
        if i < row.k_eff:
            row.drafts.append(int(toks[row.req.slot]))


def accept(row: SpecRow, verifier_row: np.ndarray) -> list[int]:
    """Longest-agreeing-prefix acceptance for one verify row.

    ``verifier_row[i]`` is the exact model's greedy token after verify
    input ``i`` (inputs are ``[x, d_1 .. d_k]``), i.e. ``v_{i+1}``.
    Returns the emission candidates ``v_1 .. v_{j+1}`` — the ``j``
    accepted drafts (``v_i == d_i`` for ``i <= j``) plus the verifier's
    correction token.  Every candidate is an exact-model output over
    accepted-exact history, so emitting them preserves bit-identity with
    sequential exact decode; the caller truncates at the first stop
    condition and sets ``row.emitted``."""
    k = row.k_eff
    v = [int(t) for t in verifier_row[:k + 1]]
    j = 0
    while j < k and v[j] == row.drafts[j]:
        j += 1
    row.accepted = j
    return v[:j + 1]
