"""Request-span tracing for the serving engine.

The engine and scheduler record typed :class:`SpanEvent`\\ s at the points
that already touch a request — submission, admission, every batch row it
rides, COW copies, prefix hits, eviction, finish — into a per-engine ring
buffer (:class:`SpanTracer`).  One end-of-run ``snapshot()`` says *what* a
trace averaged to; the span buffer says *when* each thing happened and
*which* request paid for it.

Span taxonomy (:data:`SPAN_KINDS`):

  * ``queued``        — request entered the queue (instant, at submit)
  * ``admitted``      — placed into a slot; ``queue_wait_s`` rides in args
  * ``prefill_chunk`` — one chunk-shaped batch row advanced its prompt
    (duration = that engine iteration's wall time)
  * ``decode_step``   — one generated-token batch row (duration likewise)
  * ``cow_copy``      — copy-on-write block copies flushed before a step
  * ``prefix_hit``    — admission attached to cached prefix blocks
  * ``capacity_stall``— queued work could not be placed this iteration
  * ``evicted``       — re-rejected from a full queue by higher priority
  * ``rejected``      — admission control refused the request
  * ``finished``      — terminal; ``reason``/``generated`` ride in args
  * ``draft``         — one speculative round's draft phase for a
    participating slot: ``k`` approximate-spec tokens proposed
    (:mod:`repro.serving.speculative`)
  * ``verify``        — the exact-spec verification of those drafts:
    ``drafted``/``accepted``/``emitted`` ride in args, so per-request
    acceptance is reconstructable from the trace alone
  * ``probe``         — one approximation-error probe result
    (:mod:`repro.quant.error_probe`); carries the eager probe forward's
    wall time as its duration, so stall attribution can classify the
    decode gap it created as probe cost rather than scheduler idle
  * ``shadow``        — one A/B shadow replay of a finished sampled
    request through the second pack (:mod:`repro.serving.shadow`);
    ``tokens``/``matches``/``logits_err_var`` ride in args and the
    replay's wall time is the duration
  * ``metrics_window``— one windowed time-series sample
    (:class:`~repro.serving.metrics.EngineMetrics`); exported as Chrome
    *counter* events so Perfetto plots the series
  * ``governor_switch`` — the accuracy-SLO governor hot-swapped the live
    numerics pack (:mod:`repro.serving.governor`); ``from``/``to``/
    ``reason``/``power_delta_pct`` ride in args
  * ``fault_detected`` — engine-side NaN/divergence detection flagged a
    batch row before emission (:mod:`repro.quant.faults`)
  * ``quarantine``    — a flagged row's KV cursor was rolled back and the
    step replayed on the exact pack; ``replayed`` tokens ride in args
  * ``routed``        — the fleet router assigned a request to a replica
    (:mod:`repro.serving.fleet`); ``klass``/``tier``/``replica``/``spill``
    ride in args, so tier placement is auditable from the trace alone
  * ``prefix_import`` — this replica adopted prefix-cache blocks exported
    by another replica (cross-replica sharing); ``blocks`` rides in args

Timestamps are ``time.perf_counter()`` (monotonic); exports rebase them to
the tracer's construction time.  Two export formats:

  * **JSONL** (``write("x.jsonl")``) — one event object per line; trivially
    greppable and the format ``tools/trace_report.py`` consumes natively;
  * **Chrome ``trace_event`` JSON** (``write("x.json")``) — opens directly
    in Perfetto / ``chrome://tracing``: the engine is a process, every
    request is a track (tid), batch rows are duration events, the windowed
    metrics are counter tracks.

The ring buffer drops the OLDEST events once ``capacity`` is reached
(``dropped`` counts them) so a long-running engine's tracing cost is a
bounded append, never an unbounded list.
"""

from __future__ import annotations

import collections
import json
import time
import typing

SPAN_KINDS: tuple[str, ...] = (
    "queued",
    "admitted",
    "prefill_chunk",
    "decode_step",
    "cow_copy",
    "prefix_hit",
    "capacity_stall",
    "evicted",
    "rejected",
    "finished",
    "draft",
    "verify",
    "probe",
    "shadow",
    "metrics_window",
    "governor_switch",
    "fault_detected",
    "quarantine",
    "routed",
    "prefix_import",
)

#: request-lifecycle stages every served-to-completion request passes
#: through (the CI smoke asserts >= 1 span of each in a traced run)
LIFECYCLE_KINDS: tuple[str, ...] = (
    "queued", "admitted", "prefill_chunk", "decode_step", "finished")

_SPAN_KIND_SET = frozenset(SPAN_KINDS)  # O(1) hot-path validation


class SpanEvent(typing.NamedTuple):
    """One typed telemetry event.  ``rid`` None = engine-scoped.

    A NamedTuple, not a (frozen) dataclass: events are constructed on the
    engine's hot step loop, and frozen-dataclass ``__init__`` goes through
    ``object.__setattr__`` per field."""

    kind: str
    rid: int | None
    t: float  # time.perf_counter() seconds (monotonic)
    dur: float = 0.0  # seconds; 0 = instant event
    data: dict | None = None


class SpanTracer:
    """Bounded per-engine span ring buffer with JSONL / Chrome export."""

    def __init__(self, capacity: int = 65536, engine: str = "engine",
                 pid: int = 0) -> None:
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.engine = engine
        self.pid = pid
        self.dropped = 0  # events evicted by the ring (oldest first)
        self.t0 = time.perf_counter()  # trace epoch; exports rebase to it
        self._buf: collections.deque[SpanEvent] = collections.deque(
            maxlen=capacity)

    # -- recording -----------------------------------------------------------

    def record(self, kind: str, rid: int | None = None, t: float | None = None,
               dur: float = 0.0, **data) -> None:
        if kind not in _SPAN_KIND_SET:
            raise ValueError(f"unknown span kind {kind!r}; "
                             f"valid: {list(SPAN_KINDS)}")
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append(SpanEvent(
            kind, rid, time.perf_counter() if t is None else t, dur,
            data or None))

    def __len__(self) -> int:
        return len(self._buf)

    def events(self) -> list[SpanEvent]:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.dropped = 0

    # -- export --------------------------------------------------------------

    def to_jsonl(self) -> str:
        """One JSON object per line; times in seconds from the trace epoch."""
        lines = []
        for e in self._buf:
            d = {"engine": self.engine, "kind": e.kind, "rid": e.rid,
                 "t": round(e.t - self.t0, 9), "dur": round(e.dur, 9)}
            if e.data:
                d.update(e.data)
            lines.append(json.dumps(d))
        return "\n".join(lines) + ("\n" if lines else "")

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (the Perfetto-compatible subset).

        ts/dur are microseconds from the trace epoch.  Events with a
        duration become ``"X"`` (complete) events, instants ``"i"``,
        windowed metrics samples ``"C"`` (counter) events.  Each request
        gets its own thread track (``tid = rid + 1``; tid 0 is the
        engine-scoped track), named via metadata events.
        """
        evs: list[dict] = []
        evs.append({"ph": "M", "pid": self.pid, "tid": 0,
                    "name": "process_name", "args": {"name": self.engine}})
        named_tids = {0}
        evs.append({"ph": "M", "pid": self.pid, "tid": 0,
                    "name": "thread_name", "args": {"name": "engine"}})
        for e in self._buf:
            tid = 0 if e.rid is None else e.rid + 1
            if tid not in named_tids:
                named_tids.add(tid)
                evs.append({"ph": "M", "pid": self.pid, "tid": tid,
                            "name": "thread_name",
                            "args": {"name": f"request {e.rid}"}})
            args = dict(e.data or {})
            if e.rid is not None:
                args["rid"] = e.rid
            base = {"name": e.kind, "cat": "serving", "pid": self.pid,
                    "tid": tid, "ts": round((e.t - self.t0) * 1e6, 3),
                    "args": args}
            if e.kind == "metrics_window":
                # counter track: numeric args only (Perfetto plots them)
                base["ph"] = "C"
                base["tid"] = 0
                base["args"] = {k: v for k, v in args.items()
                                if isinstance(v, (int, float))
                                and not isinstance(v, bool)}
            elif e.dur > 0:
                base["ph"] = "X"
                base["dur"] = round(e.dur * 1e6, 3)
            else:
                base["ph"] = "i"
                base["s"] = "t"  # thread-scoped instant
            evs.append(base)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"engine": self.engine,
                              "dropped_events": self.dropped}}

    def write(self, path: str) -> None:
        """``*.jsonl`` -> JSONL, anything else -> Chrome trace JSON."""
        with open(path, "w") as f:
            if str(path).endswith(".jsonl"):
                f.write(self.to_jsonl())
            else:
                json.dump(self.chrome_trace(), f)
                f.write("\n")
