"""Continuous-batching serving for approximate-multiplier inference.

The paper's deployment story is inference-only — a trained network mapped
onto an approximate MAC array with the control-variate correction — so
serving is the product surface of this reproduction.  This package turns
the one-shot ``prefill`` / ``decode_step`` model API into an engine that
serves heterogeneous request traffic (short chat turns and long documents
in the same batch) for every multiplier mode and policy.

Architecture
============

::

    submit() ──> AdmissionController ──> RequestQueue (priority+FIFO)
                                              │ admit into free slots
                                              v
    ┌──────────────────────── engine iteration ───────────────────────┐
    │  SlotScheduler: one fixed-shape batch per step                  │
    │    PREFILL (slots, chunk) — next prompt chunk of every          │
    │        prefilling request (chunked prefill, several at once)    │
    │    DECODE  (slots, 1)    — last token of every decoding request │
    │                     │                                           │
    │                     v                                           │
    │  jitted ModelApi.decode_slots over the pooled SlotPool cache    │
    │    (slots, heads, max_len, dim) K/V (or MLA latent / RWKV       │
    │    state) + per-slot write cursors; rows advance by n_valid     │
    │                     │                                           │
    │                     v                                           │
    │  postprocess: greedy token per finished row -> stream via       │
    │  on_token, evict finished slots, EngineMetrics accounting       │
    └─────────────────────────────────────────────────────────────────┘

Design invariants:

  * **Two compiled shapes, ever.**  Every iteration is either the
    ``(slots, 1)`` decode shape or the ``(slots, prefill_chunk)`` prefill
    shape, so the jitted approximate+CV step compiles exactly twice and the
    engine never stalls on mid-traffic recompilation.
  * **Per-slot cursors, masked attention.**  Each slot has its own write
    cursor; attention masks keys at ``j > position``, so stale entries from
    a slot's previous occupant are never visible and eviction is O(1).
  * **Token-identical to the sequential path.**  Greedy outputs equal the
    per-request ``prefill`` + ``decode_step`` baseline for float, exact
    int8, and approximate+CV parameters (tests/test_serving_engine.py).
  * **Numerics live in the parameters.**  The engine is mode-agnostic;
    ``build_serving_params`` decides float vs int8 vs approximate+CV.

Speculative decode (``EngineConfig.speculative_k``,
:mod:`repro.serving.speculative`) exploits the numerics-in-parameters
design directly: the SAME weights packed under an approximate spec draft
k greedy tokens per slot on the thin shape, one chunk-shaped exact call
verifies them, and only verifier tokens are emitted — bit-identical
output, zero extra parameter memory, and the acceptance rate doubles as
a live draft-quality readout for the CV knob.

KV memory models (``EngineConfig.kv_layout``):

  * ``"contiguous"`` — every slot owns a ``max_len`` KV stripe
    (:class:`~repro.serving.kv_pool.SlotPool`); simple, fragmentation-free,
    capacity-rigid.
  * ``"paged"`` — slots map logical positions onto refcounted fixed-size
    blocks from a shared pool (:mod:`repro.serving.paged`): heterogeneous
    lengths stop costing ``max_len`` each, admission blocks on free
    BLOCKS, and a content-hash prefix cache lets requests sharing a system
    prompt attach to already-filled blocks copy-on-write and skip that
    prefill.  Token-identical to the contiguous path by construction (the
    step gathers blocks into the same contiguous view).

The robustness layer (:mod:`repro.serving.governor` +
:mod:`repro.quant.faults`) makes the paper's accuracy bound an *enforced*
SLO: the error probe's running variance estimate drives a governor that
walks a degradation ladder of NumericsSpecs (hot-swapping the live pack),
engine-side NaN/divergence detection quarantines corrupted rows — KV
cursor rollback + exact-pack replay, so no corrupted token is ever
emitted — and per-request deadlines bound queue and serving latency
(finish_reason ``"deadline"``).  See docs/serving.md "Failure modes &
graceful degradation".

Fleet serving (:mod:`repro.serving.fleet`) makes an engine a *replica
behind a router*: ``TierConfig`` groups N replicas packing the same
checkpoint under one per-tier NumericsSpec (one float copy, one pack per
tier), and ``FleetRouter`` places latency-sensitive traffic on exact
tiers and bulk traffic on approximate ones (queue-depth/TTFT-aware, with
bulk->exact overflow spill), shares prefix-cache blocks across a tier's
replicas content-addressedly, and aggregates per-tier + fleet snapshots
over ``EngineMetrics.merge``.  The router drives each engine only
through its replica-handle surface (submit / step / drain / load /
snapshot / prefix export+import / tracer — plain data at the boundary,
so it could later sit on a socket).

Follow-ons tracked in ROADMAP.md: ring-buffer and SSM slot state (hymba),
paged-gather Pallas kernel, multi-host (cross-socket) replica handles.
"""

from repro.serving.engine import ServingEngine
from repro.serving.fleet import (FleetReplica, FleetRouter, TierConfig,
                                 build_fleet)
from repro.serving.governor import (GovernorConfig, GovernorDecision,
                                    NumericsGovernor)
from repro.serving.kv_pool import SlotPool
from repro.serving.metrics import EngineMetrics
from repro.serving.paged import (BlockAllocator, BlockTable, PagedKVPool,
                                 PrefixCache)
from repro.serving.request import (AdmissionController, Request, RequestQueue,
                                   RequestState)
from repro.serving.scheduler import ScheduledBatch, SlotScheduler
from repro.serving.speculative import SpecRound, SpecRow, plan_round
from repro.serving.telemetry import SPAN_KINDS, SpanEvent, SpanTracer

__all__ = [
    "SPAN_KINDS",
    "SpanEvent",
    "SpanTracer",
    "ServingEngine",
    "FleetReplica",
    "FleetRouter",
    "TierConfig",
    "build_fleet",
    "GovernorConfig",
    "GovernorDecision",
    "NumericsGovernor",
    "SlotPool",
    "BlockAllocator",
    "BlockTable",
    "PagedKVPool",
    "PrefixCache",
    "EngineMetrics",
    "AdmissionController",
    "Request",
    "RequestQueue",
    "RequestState",
    "ScheduledBatch",
    "SlotScheduler",
    "SpecRound",
    "SpecRow",
    "plan_round",
]
