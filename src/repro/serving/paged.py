"""Paged KV cache: block-granular allocation with copy-on-write prefix reuse.

The contiguous :class:`~repro.serving.kv_pool.SlotPool` gives every slot a
``max_len`` KV stripe, so a 16-token chat turn costs the same HBM as a
256-token document and identical system prompts are re-prefilled per
request.  This module replaces that memory model with a vLLM-style paged
one while keeping the engine's contracts (fixed-shape jitted steps,
greedy-token identity with the sequential baseline):

* :class:`BlockAllocator` — refcounted free-list over a global pool of
  fixed-size KV blocks.  Physical block 0 is the reserved NULL block that
  padding block-table entries point at; it is never allocated.
* :class:`BlockTable` — one request's map from logical block index to
  physical block id, plus a small reserve of pre-allocated ids that
  copy-on-write draws from (so a COW can never fail mid-flight).
* :class:`PrefixCache` — content-hash (sha256 chain over full prompt
  blocks) -> physical block id, LRU-evicted under pool pressure.  A new
  request attaches to every cached full block of its prompt copy-on-write
  and skips that prefill entirely.
* :class:`PagedKVPool` — the engine-facing manager: same surface as
  ``SlotPool`` (``acquire_for`` / ``release`` / ``update`` / ``cache``)
  plus block tables, host-side cursor mirrors, COW write barriers, and
  block-level utilization stats.

Copy-on-write rules
===================

Shared blocks are immutable: every sharer's cursor starts past the shared
prefix, so steady-state decode never writes them.  The one place a write
can target a shared block is the *matched-tokens cap*: at least one prompt
token must be recomputed to produce first-token logits, so a prompt that
is FULLY cached attaches all its blocks but starts its cursor one token
early — the re-prefill of that last token writes into the final shared
block.  ``ensure_writable`` (called host-side for every row before each
dispatch) detects the refcount > 1 write, swaps in a block from the
request's reserve, and records a (src, dst) pair that ``flush_copies``
materializes with one fixed-shape jitted copy before the step.  The
original block stays live for the cache and any other sharers.

Capacity is reserved UP FRONT: ``acquire_for`` allocates every block the
request could need over its lifetime (``ceil((prompt+gen)/block) -
shared + cow_reserve``), so an admitted request can never deadlock the
engine waiting for blocks; the cost — generation-budget blocks sit
allocated-but-unwritten — is exactly what the fragmentation metric
reports.  Admission therefore blocks on free BLOCKS, not free slots.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig
from repro.models.registry import ModelApi

_CACHE_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                 "int8": jnp.int8}

#: physical block id the padding entries of every block table point at;
#: never allocated, so stale gathers from it are masked and stale
#: scatters to it rewrite its own unchanged (zero) content
NULL_BLOCK = 0


def block_hashes(tokens, block_size: int) -> list[bytes]:
    """Chain hash over the FULL blocks of a token sequence.

    ``out[i]`` commits to tokens ``[0, (i+1)*block_size)`` — a block's
    hash depends on its whole prefix, so equal hashes mean equal prefill
    state.  sha256 keeps collisions out of the correctness budget (a
    python-hash chain would make cache hits probabilistic)."""
    out: list[bytes] = []
    h = hashlib.sha256(b"kv-prefix-v1:%d" % block_size).digest()
    for i in range(len(tokens) // block_size):
        blk = np.asarray(tokens[i * block_size:(i + 1) * block_size],
                         np.int64).tobytes()
        h = hashlib.sha256(h + blk).digest()
        out.append(h)
    return out


class BlockAllocator:
    """Refcounted free-list over physical KV blocks ``1..num_blocks-1``."""

    def __init__(self, num_blocks: int) -> None:
        if num_blocks < 2:
            raise ValueError("need at least 1 usable block + the NULL block")
        self.num_blocks = num_blocks
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))  # pop -> 1
        self._ref: dict[int, int] = {}
        self.peak_used = 0

    def alloc(self) -> int:
        """Claim a free block (refcount 1).  Callers check ``n_free``."""
        if not self._free:
            raise RuntimeError("block pool exhausted (caller must reserve)")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return bid

    def incref(self, bid: int) -> None:
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            del self._ref[bid]
            self._free.append(bid)
            return True
        return False

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._ref)


class BlockTable:
    """One request's logical-block -> physical-block map.

    ``blocks[i]`` backs logical token positions ``[i*bs, (i+1)*bs)``.
    ``reserve`` holds pre-allocated ids for copy-on-write swaps; both are
    owned (one refcount each) until :meth:`PagedKVPool.release`."""

    def __init__(self, blocks: list[int], reserve: list[int]) -> None:
        self.blocks = blocks
        self.reserve = reserve

    def owned(self) -> list[int]:
        return self.blocks + self.reserve


class PrefixCache:
    """Content-hash -> physical block id for FULL, frozen prompt blocks.

    Holds one refcount per entry so cached blocks survive their writer's
    release; LRU order is refreshed on every hit and eviction walks from
    the cold end.  Entries are keyed by the chain hash, so a hit at block
    ``i`` guarantees the whole prefix ``[0, (i+1)*bs)`` matches."""

    def __init__(self) -> None:
        self._entries: OrderedDict[bytes, int] = OrderedDict()

    def match(self, hashes: list[bytes]) -> list[int]:
        """Longest cached prefix of ``hashes``.  Pure lookup — recency is
        refreshed by :meth:`touch` only when the caller actually attaches
        (a capacity-stalled admission retrying every engine step must not
        skew the LRU order with its failed attempts)."""
        bids: list[int] = []
        for h in hashes:
            bid = self._entries.get(h)
            if bid is None:
                break
            bids.append(bid)
        return bids

    def touch(self, hashes: list[bytes]) -> None:
        """Refresh recency of the entries a request attached to."""
        for h in hashes:
            if h in self._entries:
                self._entries.move_to_end(h)

    def register(self, h: bytes, bid: int, allocator: BlockAllocator) -> bool:
        """Publish a frozen full block; the cache takes its own reference.
        Re-registering a known hash only refreshes its LRU position."""
        if h in self._entries:
            self._entries.move_to_end(h)
            return False
        allocator.incref(bid)
        self._entries[h] = bid
        return True

    def items(self) -> list[tuple[bytes, int]]:
        """(chain hash, physical block id) pairs, LRU -> MRU.  The export
        path reads this; a copy, so callers cannot skew recency."""
        return list(self._entries.items())

    def __contains__(self, h: bytes) -> bool:
        return h in self._entries

    def evict_lru(self, allocator: BlockAllocator) -> bool:
        """Reclaim one block by dropping the coldest FREEABLE entry — one
        whose block only the cache still references.  Entries whose blocks
        live requests hold are skipped: evicting them frees nothing and
        would only destroy reuse (a transient capacity stall must not
        drain the whole cache).  Returns False when nothing is freeable."""
        victim = next((h for h, bid in self._entries.items()  # LRU -> MRU
                       if allocator.refcount(bid) == 1), None)
        if victim is None:
            return False
        allocator.decref(self._entries.pop(victim))
        return True

    def __len__(self) -> int:
        return len(self._entries)


class PagedKVPool:
    """Engine-facing paged KV manager (drop-in for ``SlotPool``).

    The jitted step reads ``pool.cache`` (block-pool pytree) together with
    ``block_tables_array()``; the engine calls, per iteration:
    ``ensure_writable`` for every scheduled row, ``flush_copies``, the
    step, then ``advance`` with the batch's ``n_valid``.
    """

    def __init__(self, api: ModelApi, ecfg: EngineConfig) -> None:
        if not api.supports_paged:
            raise NotImplementedError(
                f"{api.cfg.name}: paged KV layout needs an attention-style "
                "KV sequence (recurrent per-slot state has nothing to page)")
        self.slots = ecfg.slots
        self.max_len = ecfg.max_len
        self.block_size = ecfg.kv_block_size
        if self.block_size < 1:
            raise ValueError("kv_block_size must be >= 1")
        self.blocks_per_slot = -(-ecfg.max_len // self.block_size)
        usable = ecfg.kv_blocks or ecfg.slots * self.blocks_per_slot
        self.cache = api.init_paged_cache(usable + 1, self.block_size,
                                          ecfg.slots,
                                          _CACHE_DTYPES[ecfg.cache_dtype])
        self.allocator = BlockAllocator(usable + 1)
        self.prefix = PrefixCache() if ecfg.prefix_cache else None
        self._block_keys = [k for k in self.cache if k != "lengths"]
        self._free_slots: list[int] = list(range(ecfg.slots - 1, -1, -1))
        self._owner: dict[int, int] = {}
        self._tables: dict[int, BlockTable] = {}
        self._cursors = np.zeros(ecfg.slots, np.int64)  # host mirror
        self._hashes: dict[int, list[bytes]] = {}  # slot -> prompt chain
        self._registered: dict[int, int] = {}  # slot -> full blocks published
        self._pending_copies: list[tuple[int, int]] = []
        # one fixed-shape jitted COW copy: scalar src/dst are traced, so
        # every copy reuses the single compiled executable
        self._copy_fn = jax.jit(self._copy_block)
        # fixed-shape jitted block write for imported prefix content: the
        # content leaves always have one block's shape, dst is traced
        self._write_fn = jax.jit(self._write_block)
        # cumulative observability counters (engine snapshots them)
        self.cow_copies = 0
        self.prefix_evictions = 0
        self.prefix_imports = 0

    def _copy_block(self, cache: dict, src, dst) -> dict:
        out = dict(cache)
        for k in self._block_keys:
            out[k] = cache[k].at[:, dst].set(cache[k][:, src])
        return out

    def _write_block(self, cache: dict, dst, content: dict) -> dict:
        out = dict(cache)
        for k in self._block_keys:
            out[k] = cache[k].at[:, dst].set(content[k])
        return out

    # -- allocation ----------------------------------------------------------

    def _make_room(self, n: int) -> bool:
        """Free-list pressure valve: evict cold prefix-cache entries until
        ``n`` blocks are free (or nothing evictable remains)."""
        while self.allocator.n_free < n:
            if self.prefix is None or not self.prefix.evict_lru(self.allocator):
                break
            self.prefix_evictions += 1
        return self.allocator.n_free >= n

    def acquire_for(self, req) -> int | None:
        """Admit one request: match its prompt against the prefix cache,
        then reserve EVERY block its lifetime can need.  Returns the slot,
        or None when slots or blocks are exhausted (the request stays
        queued — a "no capacity" stall, not a rejection).

        Side effects on success: ``req.prefix_hit_tokens`` records how
        much prefill is skipped, and the device cursor starts there.  The
        match is capped at ``prompt_len - 1`` so at least one prompt token
        is recomputed for its logits; when the cap lands mid-block the
        shared tail block is attached anyway and one reserve block is
        added for the copy-on-write its re-prefill will trigger."""
        if not self._free_slots:
            return None
        bs = self.block_size
        plen, gen = len(req.prompt), req.max_new_tokens
        need_total = -(-(plen + gen) // bs)
        if need_total > self.blocks_total:
            # can NEVER be placed; the admission controller screens this
            # out, but a direct caller must not be able to wedge the pool
            raise ValueError(
                f"request {req.rid} needs {need_total} blocks; the pool "
                f"holds {self.blocks_total}")
        # the chain hash is a pure function of the prompt — memoized on the
        # request so a capacity-stalled admission retrying every engine
        # step does not rehash the whole prompt each time
        hashes = [] if self.prefix is None else req.block_hashes
        if hashes is None:
            hashes = req.block_hashes = block_hashes(req.prompt, bs)
        matched = self.prefix.match(hashes) if self.prefix is not None else []
        matched_tokens = min(len(matched) * bs, plen - 1)
        cow_reserve = 1 if matched_tokens < len(matched) * bs else 0
        fresh_needed = need_total - len(matched) + cow_reserve
        # hold the shared blocks BEFORE making room: eviction under
        # pressure must not free what we are about to attach to
        for bid in matched:
            self.allocator.incref(bid)
        if not self._make_room(fresh_needed):
            for bid in matched:
                self.allocator.decref(bid)
            return None
        fresh = [self.allocator.alloc() for _ in range(fresh_needed)]
        n_tail = need_total - len(matched)
        table = BlockTable(matched + fresh[:n_tail], fresh[n_tail:])
        slot = self._free_slots.pop()
        if self.prefix is not None and matched:
            self.prefix.touch(hashes[:len(matched)])  # recency on attach
        self._owner[slot] = req.rid
        self._tables[slot] = table
        self._cursors[slot] = matched_tokens
        self._hashes[slot] = hashes  # [] when the prefix cache is disabled
        self._registered[slot] = len(matched)
        self.cache["lengths"] = (
            self.cache["lengths"].at[slot].set(matched_tokens))
        req.prefix_hit_tokens = matched_tokens
        return slot

    def release(self, slot: int) -> None:
        """Drop the request's references; blocks survive while the prefix
        cache (or another sharer) still holds them."""
        for bid in self._tables[slot].owned():
            self.allocator.decref(bid)
        del self._tables[slot], self._owner[slot]
        self._hashes.pop(slot, None)
        self._registered.pop(slot, None)
        self._free_slots.append(slot)

    # -- per-step write barrier (copy-on-write) ------------------------------

    def ensure_writable(self, slot: int, n_tokens: int) -> None:
        """Host-side COW barrier: every block the next ``n_tokens``-token
        write for ``slot`` touches must be uniquely owned before dispatch."""
        if n_tokens <= 0:
            return
        bs = self.block_size
        cur = int(self._cursors[slot])
        table = self._tables[slot]
        for lb in range(cur // bs, (cur + n_tokens - 1) // bs + 1):
            bid = table.blocks[lb]
            if self.allocator.refcount(bid) > 1:
                assert table.reserve, (
                    "COW without a reserve block: acquire_for accounting bug")
                dst = table.reserve.pop()
                self._pending_copies.append((bid, dst))
                self.allocator.decref(bid)
                table.blocks[lb] = dst
                self.cow_copies += 1

    def flush_copies(self) -> None:
        """Materialize pending COW copies (one fixed-shape jitted call per
        pair) so the step sees uniquely-owned, content-identical blocks."""
        for src, dst in self._pending_copies:
            self.cache = self._copy_fn(self.cache, jnp.int32(src),
                                       jnp.int32(dst))
        self._pending_copies.clear()

    def advance(self, n_valid: np.ndarray) -> None:
        """Mirror the device cursor advance after a dispatched step."""
        self._cursors += np.asarray(n_valid, np.int64)

    # -- prefix publication --------------------------------------------------

    def register_prefix(self, slot: int, prompt_len: int,
                        prefilled: int) -> int:
        """Publish every newly FULL prompt block of ``slot`` to the prefix
        cache (called as chunked prefill advances, so concurrent requests
        hit blocks while their writer is still prefilling).  Only blocks
        entirely covered by the prompt are published — the tail block also
        receives generated tokens and is never shareable."""
        if self.prefix is None:
            return 0
        n_full = min(prefilled, prompt_len) // self.block_size
        table, hashes = self._tables[slot], self._hashes[slot]
        published = 0
        for lb in range(self._registered.get(slot, 0), n_full):
            published += self.prefix.register(hashes[lb], table.blocks[lb],
                                              self.allocator)
        self._registered[slot] = max(self._registered.get(slot, 0), n_full)
        return published

    # -- cross-pool prefix sharing -------------------------------------------

    def export_prefix_entries(self) -> list[tuple[bytes, dict]]:
        """Snapshot every prefix-cache entry as (chain hash, block content).

        Content is the per-layer KV slice of the entry's physical block,
        pulled to host numpy so the pair is self-contained and
        serializable (the replica boundary could sit on a socket).  The
        chain hash commits to the entire token prefix AND the block size
        (the hash seed), so an importer with the same model/cache config
        can adopt the block sight unseen: equal hash means equal prefill
        state.  Registered blocks are frozen full prompt blocks, so the
        snapshot never races an in-flight write."""
        if self.prefix is None:
            return []
        return [(h, {k: np.asarray(self.cache[k][:, bid])
                     for k in self._block_keys})
                for h, bid in self.prefix.items()]

    def import_prefix_entries(self, entries) -> int:
        """Adopt exported entries from another pool (cross-replica prefix
        sharing).  Each new entry is written into a freshly allocated
        block and published under its chain hash, after which local
        prompts attach to it exactly as if a local request had prefilled
        it.  Returns the number of blocks imported.

        An imported block ends at refcount exactly 1 — held by the prefix
        cache alone — so it is LRU-evictable like any locally published
        entry.  Hashes already cached are skipped (no content rewrite;
        recency untouched), and when the pool cannot make room even after
        eviction the remainder is dropped: sharing is an optimization,
        never a correctness event."""
        if self.prefix is None:
            return 0
        imported = 0
        for h, content in entries:
            if h in self.prefix:
                continue
            if not self._make_room(1):
                break
            bid = self.allocator.alloc()
            self.cache = self._write_fn(
                self.cache, jnp.int32(bid),
                {k: jnp.asarray(v) for k, v in content.items()})
            self.prefix.register(h, bid, self.allocator)  # the cache's ref
            self.allocator.decref(bid)  # drop the alloc ref: cache-owned
            imported += 1
        self.prefix_imports += imported
        return imported

    # -- state ---------------------------------------------------------------

    def update(self, new_cache: dict) -> None:
        self.cache = new_cache

    def lengths(self) -> np.ndarray:
        return np.asarray(self.cache["lengths"])

    def set_lengths(self, new_lengths: np.ndarray) -> None:
        """Overwrite device cursors AND the host mirror (speculative-decode
        rollback).  Under the paged layout a rollback is purely a cursor
        move: block tables are position-stable, rejected speculative K/V
        sits in blocks the request already owns (the round's COW barrier
        ran before drafting), and entries past the cursor are masked until
        overwritten — so no block is freed or copied on rollback, even
        when the cursor retreats across a block boundary."""
        from repro.models.lm import rollback_slots

        self.cache = rollback_slots(self.cache, new_lengths)
        self._cursors[:] = np.asarray(new_lengths, np.int64)

    def block_tables_array(self) -> np.ndarray:
        """(slots, blocks_per_slot) int32 for the jitted step; idle slots
        and the unallocated tail of short tables point at NULL_BLOCK."""
        bt = np.full((self.slots, self.blocks_per_slot), NULL_BLOCK, np.int32)
        for slot, table in self._tables.items():
            bt[slot, :len(table.blocks)] = table.blocks
        return bt

    # -- observability -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.slots - len(self._free_slots)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.slots

    def owner(self, slot: int) -> int | None:
        return self._owner.get(slot)

    @property
    def blocks_total(self) -> int:
        return self.allocator.num_blocks - 1

    def reset_peak_blocks(self) -> None:
        """Re-arm the peak-blocks watermark at the current usage (called by
        ``ServingEngine.reset_metrics`` so ``peak_blocks_in_use`` covers
        the same measurement window as the other snapshot counters)."""
        self.allocator.peak_used = self.allocator.n_used

    def per_block_bytes(self) -> int:
        """HBM cost of one block across every layer's KV leaves."""
        return sum(int(v.size) * v.dtype.itemsize // self.allocator.num_blocks
                   for k, v in self.cache.items() if k != "lengths")

    def block_stats(self) -> dict:
        """Block-level utilization and fragmentation, exactly.

        ``block_util`` is in-use blocks (active tables + reserves + prefix
        cache) over the usable pool.  ``block_frag`` is the
        allocated-but-unwritten fraction of ACTIVE requests' blocks —
        up-front generation-budget reservation made visible; shared blocks
        are counted once at their fullest view."""
        filled: dict[int, int] = {}
        bs = self.block_size
        for slot, table in self._tables.items():
            cur = int(self._cursors[slot])
            for lb, bid in enumerate(table.blocks):
                f = min(max(cur - lb * bs, 0), bs)
                filled[bid] = max(filled.get(bid, 0), f)
            for bid in table.reserve:
                filled.setdefault(bid, 0)
        active_blocks = len(filled)
        written = sum(filled.values())
        return {
            "blocks_total": self.blocks_total,
            "blocks_in_use": self.allocator.n_used,
            "peak_blocks_in_use": self.allocator.peak_used,
            "block_util": self.allocator.n_used / self.blocks_total,
            "block_frag": (1.0 - written / (active_blocks * bs)
                           if active_blocks else 0.0),
            "prefix_cache_entries": len(self.prefix) if self.prefix else 0,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.prefix_evictions,
        }
