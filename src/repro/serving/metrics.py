"""Per-engine serving metrics.

Counters are recorded on the host around each engine iteration; nothing
here touches device state.  ``snapshot()`` derives the headline serving
numbers: decode tokens/s, end-to-end tokens/s, time-to-first-token
(mean/p50/max), inter-token stall (p50/p95/max over per-request gaps
between consecutive generated tokens — the decode-stall signal the mixed
scheduler exists to shrink), mean queue depth, and mean slot occupancy.
Under the paged KV layout, block-level counters ride along: utilization
and fragmentation (slot occupancy alone overstates utilization when
lengths are heterogeneous), prefix-cache hits and skipped prefill
tokens, COW copies, prefix evictions, and ``no_capacity_stalls`` —
iterations where queued work waited on pool capacity, which queue-full
rejection counts used to hide.  Speculative decode
(``repro.serving.speculative``) adds draft/accept counters: the
acceptance rate — accepted draft tokens over drafted — is the
argmax-level draft-quality signal for the approximate spec, surfaced
per window, in the snapshot (keyed by draft spec), and across
:meth:`EngineMetrics.merge`.

Three observability surfaces beyond the end-of-run aggregate:

  * **Bounded latency samples.**  Per-request ttft/itl/latency samples go
    through reservoir sampling (:class:`Reservoir`, cap 4096): counts,
    sums, and maxima stay exact forever, percentiles come from a uniform
    sample, and host memory stops growing with trace length.  The
    snapshot surfaces ``*_samples`` (total observed) and
    ``*_samples_capped`` (observed minus retained).
  * **Windowed time-series.**  With ``window_s > 0`` every
    ``record_step`` rolls an interval accumulator; once a window elapses
    a sample dict (window gen tok/s, mean queue depth/occupancy, stall
    and step deltas, block util/frag) is appended to ``timeseries`` (a
    bounded ring) and handed to ``on_window_sample`` (the engine bridges
    it into the span tracer as Chrome counter events).
  * **Fleet merge.**  :meth:`EngineMetrics.merge` combines snapshot
    dicts across engines using sufficient statistics — counters sum,
    rates recompute as (summed tokens / max elapsed), means weight by
    their carried sample counts, error-probe moments combine with Chan's
    parallel variance formula — so ``merge`` is associative and a merged
    snapshot can itself be merged again (the fleet-metrics primitive).

The throughput clock starts lazily at the FIRST served batch (the engine
arms it just before dispatching; ``record_step`` arms it as a fallback),
not at construction: engines compile and warm up between being built and
serving their first batch, and charging that wall time to the denominator
deflates ``gen_tok_per_s`` for short traces.  ``reset_metrics()`` (a fresh
instance) therefore re-arms the lazy clock too.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import random
import time
from typing import Callable

#: default reservoir capacity for per-request latency samples
RESERVOIR_CAP = 4096
#: windowed time-series ring capacity (samples); oldest dropped
TIMESERIES_CAP = 4096


def _percentile(xs, q: float) -> float:
    """Linear-interpolation percentile (numpy's default method).

    Nearest-rank rounding misreports tail percentiles on small samples —
    e.g. p95 of 10 samples rounds to the 9th order statistic, identical
    to p89 — so interpolate between the two bracketing order statistics
    instead.
    """
    xs = list(xs)
    if not xs:
        return 0.0
    ys = sorted(xs)
    pos = q * (len(ys) - 1)
    lo = min(int(math.floor(pos)), len(ys) - 1)
    hi = min(lo + 1, len(ys) - 1)
    frac = pos - lo
    return ys[lo] + (ys[hi] - ys[lo]) * frac


class Reservoir:
    """Bounded uniform sample of a stream with exact n/sum/max.

    Algorithm R with a deterministic per-instance RNG (reproducible
    snapshots).  Means and maxima are computed from exact running
    aggregates — only percentiles read the (uniform) reservoir — so
    capping never biases the headline numbers.
    """

    __slots__ = ("cap", "n", "total", "_max", "samples", "_rng")

    def __init__(self, cap: int = RESERVOIR_CAP, seed: int = 0x5EED) -> None:
        if cap < 1:
            raise ValueError(f"reservoir cap must be >= 1, got {cap}")
        self.cap = cap
        self.n = 0  # total observed (exact)
        self.total = 0.0  # running sum (exact)
        self._max = float("-inf")
        self.samples: list[float] = []
        self._rng = random.Random(seed)

    def push(self, x: float) -> None:
        x = float(x)
        self.n += 1
        self.total += x
        if x > self._max:
            self._max = x
        if len(self.samples) < self.cap:
            self.samples.append(x)
        else:
            j = self._rng.randrange(self.n)
            if j < self.cap:
                self.samples[j] = x

    def __len__(self) -> int:
        return self.n

    def __bool__(self) -> bool:
        return self.n > 0

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    @property
    def max(self) -> float:
        return self._max if self.n else 0.0

    @property
    def capped(self) -> int:
        """Observations not retained in the reservoir."""
        return self.n - len(self.samples)

    def percentile(self, q: float) -> float:
        return _percentile(self.samples, q)


def _merge_moments(a: tuple[int, float, float],
                   b: tuple[int, float, float]) -> tuple[int, float, float]:
    """Chan's parallel combine of (n, mean, variance) aggregates."""
    na, ma, va = a
    nb, mb, vb = b
    if na == 0:
        return b
    if nb == 0:
        return a
    n = na + nb
    d = mb - ma
    mean = ma + d * nb / n
    m2 = va * na + vb * nb + d * d * na * nb / n
    return n, mean, m2 / n


def merge_layer_moments(*maps: dict) -> dict:
    """Dict-union Chan merge of layer-keyed ``(n, mean, var)`` maps.

    The shared primitive behind per-layer probe aggregation: the running
    engine totals, the per-window accumulators, the governor's per-layer
    SLO windows, and the fleet merge all combine layer moment maps with
    this.  Associative and layout-independent: merging ``(a, b)`` then
    ``c`` equals merging ``a`` then ``(b, c)``, and the key union never
    depends on which engine saw which layer first.
    """
    out: dict = {}
    for m in maps:
        for path, mom in m.items():
            out[path] = _merge_moments(out.get(path, (0, 0.0, 0.0)),
                                       tuple(mom))
    return out


def _sig(x: float, digits: int = 6) -> float:
    """Round to significant digits (err variances span many decades;
    fixed decimal rounding flushes the small ones to zero)."""
    return float(f"{x:.{digits}g}")


@dataclasses.dataclass
class EngineMetrics:
    #: set by the first record_step (lazy); None while nothing was served
    t_start: float | None = None

    #: name of the NumericsSpec the served parameters were packed under
    #: (None = unknown/float); surfaced in snapshot() for fleet audits
    numerics: str | None = None

    #: whether the engine's slot count fits the kernel block picker's
    #: decode-specialized tiles (repro.kernels.ops.DECODE_M_MAX): one-token
    #: decode steps then run thin-M, single-K-step kernel launches
    decode_specialized: bool | None = None

    #: KV memory model the engine serves under ("contiguous" | "paged")
    kv_layout: str = "contiguous"

    #: windowed time-series interval in seconds (0 disables the roller)
    window_s: float = 0.0
    #: called with each emitted window sample (the engine bridges samples
    #: into the span tracer); excluded from repr/compare
    on_window_sample: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    prompt_tokens: int = 0
    generated_tokens: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    mixed_steps: int = 0  # chunk-shaped batches carrying decode rows

    #: speculative decode config mirror: the engine's draft length
    #: (0 = speculation off) and the NumericsSpec name its draft
    #: parameters were packed under (the acceptance-rate key)
    speculative_k: int = 0
    draft_numerics: str | None = None
    spec_rounds: int = 0  # engine iterations that ran a draft phase
    draft_calls: int = 0  # thin approximate-parameter dispatches
    drafted_tokens: int = 0  # draft tokens proposed across all rounds
    accepted_draft_tokens: int = 0  # drafts the exact verifier agreed with

    submitted: int = 0
    rejected: int = 0
    evicted: int = 0  # queued requests re-rejected for higher-priority work
    finished: int = 0

    #: engine iterations where queued work could not be admitted because
    #: the pool lacked capacity (free slots, or — paged — free blocks).
    #: Distinct from queue-full REJECTION: a stall delays work, a
    #: rejection drops it; before this counter the two were
    #: indistinguishable in the snapshot.
    no_capacity_stalls: int = 0

    #: prefix-cache reuse (paged layout): requests admitted onto cached
    #: blocks, and the total prompt tokens whose prefill that skipped
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0
    #: prefix-cache blocks adopted from another replica's pool
    #: (cross-replica sharing, repro.serving.fleet)
    prefix_imports: int = 0

    ttfts: Reservoir = dataclasses.field(default_factory=Reservoir)
    #: per-request gaps between consecutive generated tokens (seconds)
    itls: Reservoir = dataclasses.field(default_factory=Reservoir)
    latencies: Reservoir = dataclasses.field(default_factory=Reservoir)

    _occupancy_sum: float = 0.0
    _queue_depth_sum: float = 0.0
    _samples: int = 0

    # block-level accounting (paged layout; None-ish for contiguous).
    # Slot occupancy OVERSTATES utilization under heterogeneous lengths —
    # a slot holding a 16-token chat counts like one holding a 256-token
    # document — so block utilization/fragmentation is reported alongside.
    _block_util_sum: float = 0.0
    _block_frag_sum: float = 0.0
    _block_samples: int = 0
    _last_block_stats: dict | None = None

    # windowed time-series state (window_s > 0)
    timeseries: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=TIMESERIES_CAP))
    timeseries_dropped: int = 0
    _win_t0: float | None = None
    _win_base: dict | None = None

    # robustness counters (repro.serving.governor / repro.quant.faults):
    # SLO-governor pack switches, injected/detected faults, quarantined
    # rows replayed on the exact pack, deadline expiries, and submit-loop
    # retries after queue-full rejections
    governor_switches: int = 0
    governor_escalations: int = 0
    governor_relaxes: int = 0
    faults_injected: int = 0
    faults_detected: int = 0
    quarantines: int = 0
    quarantine_replays: int = 0
    requests_retried: int = 0
    requests_deadline_expired: int = 0

    # approximation-error probe aggregation (repro.quant.error_probe):
    # per-layer and logits-level (n, mean, var) of approximate-vs-exact
    # output deltas, combined across probe runs with Chan's formula
    probe_runs: int = 0
    _probe_layers: dict = dataclasses.field(default_factory=dict)
    _probe_logits: tuple = (0, 0.0, 0.0)

    # per-WINDOW probe accumulators: moments are not diffable counters
    # (a base-vs-current subtraction is meaningless for a variance), so
    # the window roller keeps fresh accumulators reset at every roll
    # instead of riding _window_counters
    _win_probe_runs: int = 0
    _win_probe_layers: dict = dataclasses.field(default_factory=dict)
    _win_probe_logits: tuple = (0, 0.0, 0.0)

    # modeled power attribution: per-numerics-label layer cost profiles
    # ({path: {mac_per_token, saving_pct}}, derived from the live packed
    # params by the engine) joined against the token mix actually served
    # under each label — so a governor hot-swap mid-run splits the
    # attribution between rungs instead of crediting the final pack
    power_profiles: dict = dataclasses.field(default_factory=dict)
    _tokens_by_numerics: dict = dataclasses.field(default_factory=dict)

    # A/B shadow serving (repro.serving.shadow): sampled-request replay
    # of a second pack; counters + Chan-merged logit-delta moments
    shadow_numerics: str | None = None
    shadow_sampled: int = 0
    shadow_tokens: int = 0
    shadow_token_matches: int = 0
    _shadow_logits: tuple = (0, 0.0, 0.0)
    _shadow_max_abs: float = 0.0

    # -- recording -----------------------------------------------------------

    def start_clock(self) -> None:
        """Arm the throughput clock (idempotent).  The engine calls this
        just before dispatching its first batch, so that step's wall time
        is inside the measured window; ``record_step`` also arms it as a
        fallback for direct users of the metrics object."""
        if self.t_start is None:
            self.t_start = time.time()

    def record_step(self, kind: str, occupancy: float, queue_depth: int,
                    prompt_tokens: int = 0, generated_tokens: int = 0,
                    block_stats: dict | None = None, drafted: int = 0,
                    accepted: int = 0, draft_calls: int = 0) -> None:
        self.start_clock()
        if kind == "prefill":
            self.prefill_steps += 1
        elif kind == "mixed":
            self.mixed_steps += 1
        elif kind == "spec":
            # one speculative round = draft_calls thin approximate
            # dispatches + one chunk-shaped exact verify dispatch
            self.spec_rounds += 1
        else:
            self.decode_steps += 1
        self.drafted_tokens += drafted
        self.accepted_draft_tokens += accepted
        self.draft_calls += draft_calls
        self.prompt_tokens += prompt_tokens
        self.generated_tokens += generated_tokens
        if prompt_tokens or generated_tokens:
            # attribute served tokens to the numerics label active NOW —
            # the join key for modeled power attribution
            label = self.numerics or "unknown"
            self._tokens_by_numerics[label] = (
                self._tokens_by_numerics.get(label, 0)
                + prompt_tokens + generated_tokens)
        self._occupancy_sum += occupancy
        self._queue_depth_sum += queue_depth
        self._samples += 1
        if block_stats is not None:
            self._block_util_sum += block_stats["block_util"]
            self._block_frag_sum += block_stats["block_frag"]
            self._block_samples += 1
            self._last_block_stats = block_stats
        if self.window_s > 0:
            self._maybe_roll()

    def record_first_token(self, req) -> None:
        if req.ttft is not None:
            self.ttfts.push(req.ttft)

    def record_itl(self, gap: float | None) -> None:
        """One inter-token gap (``Request.emit``'s return; None = first
        token of a request, which has no gap)."""
        if gap is not None:
            self.itls.push(gap)

    def record_finish(self, req) -> None:
        self.finished += 1
        if req.t_finish is not None:
            self.latencies.push(req.t_finish - req.t_submit)

    # -- windowed time-series ------------------------------------------------

    def _window_counters(self) -> dict:
        return {"generated_tokens": self.generated_tokens,
                "prompt_tokens": self.prompt_tokens,
                "no_capacity_stalls": self.no_capacity_stalls,
                "prefill_steps": self.prefill_steps,
                "decode_steps": self.decode_steps,
                "mixed_steps": self.mixed_steps,
                "spec_rounds": self.spec_rounds,
                "draft_calls": self.draft_calls,
                "drafted_tokens": self.drafted_tokens,
                "accepted_draft_tokens": self.accepted_draft_tokens,
                "governor_switches": self.governor_switches,
                "faults_detected": self.faults_detected,
                "quarantines": self.quarantines,
                "shadow_sampled": self.shadow_sampled,
                "shadow_tokens": self.shadow_tokens,
                "shadow_token_matches": self.shadow_token_matches,
                # per-label token counters (flattened; labels can appear
                # mid-run on a governor switch, hence base.get below)
                **{f"_tok/{k}": v
                   for k, v in self._tokens_by_numerics.items()},
                "_occupancy_sum": self._occupancy_sum,
                "_queue_depth_sum": self._queue_depth_sum,
                "_samples": self._samples,
                "_block_util_sum": self._block_util_sum,
                "_block_frag_sum": self._block_frag_sum,
                "_block_samples": self._block_samples}

    def _maybe_roll(self) -> None:
        now = time.time()
        if self._win_t0 is None:
            self._win_t0 = now
            self._win_base = self._window_counters()
            return
        dur = now - self._win_t0
        if dur < self.window_s:
            return
        cur, base = self._window_counters(), self._win_base
        d = {k: cur[k] - base.get(k, 0) for k in cur}
        steps = d["_samples"]
        sample = {
            "t": round(now - (self.t_start or now), 4),
            "dur_s": round(dur, 4),
            "gen_tok_per_s": round(d["generated_tokens"] / dur, 2),
            "prompt_tok_per_s": round(d["prompt_tokens"] / dur, 2),
            "steps": steps,
            "prefill_steps": d["prefill_steps"],
            "decode_steps": d["decode_steps"],
            "mixed_steps": d["mixed_steps"],
            "no_capacity_stalls": d["no_capacity_stalls"],
            "mean_queue_depth": round(d["_queue_depth_sum"] / steps, 2)
            if steps else 0.0,
            "mean_slot_occupancy": round(d["_occupancy_sum"] / steps, 3)
            if steps else 0.0,
        }
        if d["_block_samples"]:
            sample["mean_block_utilization"] = round(
                d["_block_util_sum"] / d["_block_samples"], 3)
            sample["mean_block_fragmentation"] = round(
                d["_block_frag_sum"] / d["_block_samples"], 3)
        if self.speculative_k:
            # per-window acceptance: the live draft-quality signal (a CV
            # toggle or quality drift shows up here before it shows up in
            # the end-of-run aggregate)
            sample["spec_rounds"] = d["spec_rounds"]
            sample["drafted_tokens"] = d["drafted_tokens"]
            sample["accepted_draft_tokens"] = d["accepted_draft_tokens"]
            sample["acceptance_rate"] = (
                round(d["accepted_draft_tokens"] / d["drafted_tokens"], 4)
                if d["drafted_tokens"] else None)
        if self.governor_switches or self.faults_detected or self.quarantines:
            # robustness deltas appear once any governor/fault activity
            # exists (keeps pre-governor sample schemas unchanged)
            sample["governor_switches"] = d["governor_switches"]
            sample["faults_detected"] = d["faults_detected"]
            sample["quarantines"] = d["quarantines"]
        if self._win_probe_runs:
            # layer-resolved err-var for THIS window (fresh accumulators,
            # not a lifetime average): the per-layer time-series the
            # dashboard heatmap and the governor's layer SLOs consume.
            # probe_layers is a nested dict — it survives JSONL traces;
            # the Chrome counter export keeps only the numeric scalars.
            _, _, lvar = self._win_probe_logits
            lvars = {p: v for p, (_, _, v) in self._win_probe_layers.items()}
            sample["probe_runs"] = self._win_probe_runs
            sample["probe_logits_err_var"] = _sig(lvar)
            if lvars:
                worst = max(lvars, key=lvars.get)
                sample["probe_max_layer_err_var"] = _sig(lvars[worst])
                sample["probe_worst_layer"] = worst
                sample["probe_layers"] = {p: _sig(v)
                                          for p, v in sorted(lvars.items())}
        if self.shadow_numerics is not None:
            sample["shadow_sampled"] = d["shadow_sampled"]
            sample["shadow_tokens"] = d["shadow_tokens"]
            sample["shadow_token_match_rate"] = (
                round(d["shadow_token_matches"] / d["shadow_tokens"], 4)
                if d["shadow_tokens"] else None)
        if self.power_profiles:
            # this window's modeled power: token mix served per numerics
            # label x that label's per-layer MAC cost/saving profile
            mix = {k[len("_tok/"):]: d[k] for k in d
                   if k.startswith("_tok/") and d[k]}
            units = saved = 0.0
            for label, toks in mix.items():
                for ent in (self.power_profiles.get(label) or {}).values():
                    u = toks * ent["mac_per_token"]
                    units += u
                    saved += u * ent["saving_pct"] / 100.0
            sample["tokens_by_numerics"] = mix
            sample["modeled_mac_units"] = round(units, 1)
            sample["modeled_mac_units_saved"] = round(saved, 1)
            sample["modeled_power_saving_pct"] = (
                round(100.0 * saved / units, 3) if units else 0.0)
        self._win_probe_runs = 0
        self._win_probe_layers = {}
        self._win_probe_logits = (0, 0.0, 0.0)
        if len(self.timeseries) == self.timeseries.maxlen:
            self.timeseries_dropped += 1
        self.timeseries.append(sample)
        self._win_t0 = now
        self._win_base = cur
        if self.on_window_sample is not None:
            self.on_window_sample(sample)

    # -- approximation-error probe -------------------------------------------

    def record_probe(self, report: dict) -> None:
        """Fold one :class:`~repro.quant.error_probe.ErrorProbe` report
        (per-layer + logits ``{n, mean, var}`` of approx-vs-exact output
        deltas) into the running per-layer moments."""
        self.probe_runs += 1
        self._win_probe_runs += 1
        for path, st in report.get("layers", {}).items():
            mom = (st["n"], st["mean"], st["var"])
            self._probe_layers[path] = _merge_moments(
                self._probe_layers.get(path, (0, 0.0, 0.0)), mom)
            self._win_probe_layers[path] = _merge_moments(
                self._win_probe_layers.get(path, (0, 0.0, 0.0)), mom)
        lg = report.get("logits")
        if lg is not None:
            mom = (lg["n"], lg["mean"], lg["var"])
            self._probe_logits = _merge_moments(self._probe_logits, mom)
            self._win_probe_logits = _merge_moments(
                self._win_probe_logits, mom)

    def _probe_snapshot(self) -> dict | None:
        if not self.probe_runs and not self._probe_layers:
            return None
        layers = {path: {"n": n, "err_mean": mean, "err_var": var}
                  for path, (n, mean, var) in sorted(self._probe_layers.items())}
        lvars = [st["err_var"] for st in layers.values()]
        ln, lmean, lvar = self._probe_logits
        return {
            "runs": self.probe_runs,
            "numerics": self.numerics,
            "logits_err_n": ln,
            "logits_err_mean": lmean,
            "logits_err_var": lvar,
            "mean_layer_err_var": sum(lvars) / len(lvars) if lvars else None,
            "max_layer_err_var": max(lvars) if lvars else None,
            "layers": layers,
        }

    # -- A/B shadow serving --------------------------------------------------

    def record_shadow(self, rec: dict) -> None:
        """Fold one :class:`~repro.serving.shadow.ShadowRunner` replay
        record (``{tokens, matches, logits_err: {n, mean, var, max_abs}}``)
        into the running shadow counters."""
        self.shadow_sampled += 1
        self.shadow_tokens += rec.get("tokens", 0)
        self.shadow_token_matches += rec.get("matches", 0)
        le = rec.get("logits_err")
        if le:
            self._shadow_logits = _merge_moments(
                self._shadow_logits, (le["n"], le["mean"], le["var"]))
            self._shadow_max_abs = max(self._shadow_max_abs,
                                       le.get("max_abs", 0.0))

    def _shadow_snapshot(self) -> dict | None:
        if not self.shadow_sampled:
            return None
        n, mean, var = self._shadow_logits
        return {
            "numerics": self.shadow_numerics,
            "sampled_requests": self.shadow_sampled,
            "tokens": self.shadow_tokens,
            "token_matches": self.shadow_token_matches,
            "token_match_rate": (
                round(self.shadow_token_matches / self.shadow_tokens, 4)
                if self.shadow_tokens else None),
            "logits_err_n": n,
            "logits_err_mean": mean,
            "logits_err_var": var,
            "logits_err_max_abs": self._shadow_max_abs,
        }

    # -- modeled power attribution -------------------------------------------

    def set_power_profile(self, label: str, profile: dict) -> None:
        """Register a per-layer MAC cost/saving profile for one numerics
        label (``{path: {mac_per_token, saving_pct}}``; see
        :func:`repro.serving.engine.power_profile_from_params`).  The
        engine registers the active pack's profile at construction and
        again after every governor hot-swap."""
        self.power_profiles[label] = dict(profile)

    def _power_attribution(self) -> dict | None:
        """Join the served token mix against the registered profiles.

        ``mac_units`` are (tokens x MACs-per-token) — a relative energy
        proxy: multiply by the per-MAC energy of the exact 8x8 array to
        get mWh.  ``mac_units_saved`` applies each layer's cost-model
        saving, so the totals are traffic-weighted deltas, not the static
        plan percentages."""
        if not self.power_profiles:
            return None
        per_layer: dict[str, dict] = {}
        per_tier: dict[str, dict] = {}
        for label, toks in sorted(self._tokens_by_numerics.items()):
            prof = self.power_profiles.get(label) or {}
            t_units = t_saved = 0.0
            for path, ent in prof.items():
                units = toks * ent["mac_per_token"]
                saved = units * ent["saving_pct"] / 100.0
                t_units += units
                t_saved += saved
                lay = per_layer.setdefault(
                    path, {"mac_units": 0.0, "mac_units_saved": 0.0})
                lay["mac_units"] += units
                lay["mac_units_saved"] += saved
            per_tier[label] = {
                "tokens": toks,
                "mac_units": round(t_units, 1),
                "mac_units_saved": round(t_saved, 1),
                "power_saving_pct": (round(100.0 * t_saved / t_units, 3)
                                     if t_units else 0.0),
            }
        for lay in per_layer.values():
            lay["saving_pct"] = (
                round(100.0 * lay["mac_units_saved"] / lay["mac_units"], 3)
                if lay["mac_units"] else 0.0)
            lay["mac_units"] = round(lay["mac_units"], 1)
            lay["mac_units_saved"] = round(lay["mac_units_saved"], 1)
        units = sum(t["mac_units"] for t in per_tier.values())
        saved = sum(t["mac_units_saved"] for t in per_tier.values())
        return {
            "tokens_attributed": sum(self._tokens_by_numerics.values()),
            "tokens_by_numerics": dict(sorted(
                self._tokens_by_numerics.items())),
            "mac_units": round(units, 1),
            "mac_units_saved": round(saved, 1),
            "modeled_power_saving_pct": (round(100.0 * saved / units, 3)
                                         if units else 0.0),
            "per_tier": per_tier,
            "per_layer": dict(sorted(per_layer.items())),
        }

    # -- derived -------------------------------------------------------------

    def snapshot(self) -> dict:
        elapsed = (max(time.time() - self.t_start, 1e-9)
                   if self.t_start is not None else 0.0)
        total_tok = self.prompt_tokens + self.generated_tokens
        blk = self._last_block_stats or {}
        return {
            "engines": 1,
            "numerics": self.numerics,
            "decode_specialized": self.decode_specialized,
            "kv_layout": self.kv_layout,
            "elapsed_s": round(elapsed, 4),
            "requests_finished": self.finished,
            "requests_rejected": self.rejected,
            "requests_evicted": self.evicted,
            "no_capacity_stalls": self.no_capacity_stalls,
            "governor_switches": self.governor_switches,
            "governor_escalations": self.governor_escalations,
            "governor_relaxes": self.governor_relaxes,
            "faults_injected": self.faults_injected,
            "faults_detected": self.faults_detected,
            "quarantines": self.quarantines,
            "quarantine_replays": self.quarantine_replays,
            "requests_retried": self.requests_retried,
            "requests_deadline_expired": self.requests_deadline_expired,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_imports": self.prefix_imports,
            "mean_block_utilization": round(
                self._block_util_sum / self._block_samples, 3)
            if self._block_samples else None,
            "mean_block_fragmentation": round(
                self._block_frag_sum / self._block_samples, 3)
            if self._block_samples else None,
            "block_step_samples": self._block_samples,
            "peak_blocks_in_use": blk.get("peak_blocks_in_use"),
            "blocks_total": blk.get("blocks_total"),
            "prefix_cache_entries": blk.get("prefix_cache_entries"),
            "cow_copies": blk.get("cow_copies"),
            "prefix_evictions": blk.get("prefix_evictions"),
            "prompt_tokens": self.prompt_tokens,
            "generated_tokens": self.generated_tokens,
            "gen_tok_per_s": round(self.generated_tokens / elapsed, 2)
            if elapsed else 0.0,
            "total_tok_per_s": round(total_tok / elapsed, 2)
            if elapsed else 0.0,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "mixed_steps": self.mixed_steps,
            "step_samples": self._samples,
            "speculative_k": self.speculative_k or None,
            "draft_numerics": self.draft_numerics,
            "spec_rounds": self.spec_rounds,
            "draft_calls": self.draft_calls,
            "drafted_tokens": self.drafted_tokens,
            "accepted_draft_tokens": self.accepted_draft_tokens,
            "acceptance_rate": round(
                self.accepted_draft_tokens / self.drafted_tokens, 4)
            if self.drafted_tokens else None,
            "acceptance_by_draft_spec": (
                {self.draft_numerics or "unknown": {
                    "drafted": self.drafted_tokens,
                    "accepted": self.accepted_draft_tokens,
                    "acceptance_rate": round(
                        self.accepted_draft_tokens / self.drafted_tokens,
                        4)}}
                if self.drafted_tokens else None),
            "ttft_mean_s": round(self.ttfts.mean, 4) if self.ttfts else None,
            "ttft_p50_s": round(self.ttfts.percentile(0.5), 4)
            if self.ttfts else None,
            "ttft_max_s": round(self.ttfts.max, 4) if self.ttfts else None,
            "ttft_samples": len(self.ttfts),
            "ttft_samples_capped": self.ttfts.capped,
            "itl_p50_s": round(self.itls.percentile(0.5), 4)
            if self.itls else None,
            "itl_p95_s": round(self.itls.percentile(0.95), 4)
            if self.itls else None,
            "itl_max_s": round(self.itls.max, 4) if self.itls else None,
            "itl_samples": len(self.itls),
            "itl_samples_capped": self.itls.capped,
            "latency_mean_s": round(self.latencies.mean, 4)
            if self.latencies else None,
            "latency_samples": len(self.latencies),
            "latency_samples_capped": self.latencies.capped,
            "mean_slot_occupancy": round(self._occupancy_sum / self._samples, 3)
            if self._samples else 0.0,
            "mean_queue_depth": round(self._queue_depth_sum / self._samples, 2)
            if self._samples else 0.0,
            "metrics_window_s": self.window_s if self.window_s > 0 else None,
            "timeseries_samples": len(self.timeseries),
            "timeseries_dropped": self.timeseries_dropped,
            "error_probe": self._probe_snapshot(),
            "shadow": self._shadow_snapshot(),
            "power_attribution": self._power_attribution(),
        }

    # -- fleet merge ---------------------------------------------------------

    _SUM_KEYS = (
        "engines", "requests_finished", "requests_rejected",
        "requests_evicted", "no_capacity_stalls",
        "governor_switches", "governor_escalations", "governor_relaxes",
        "faults_injected", "faults_detected", "quarantines",
        "quarantine_replays", "requests_retried",
        "requests_deadline_expired", "prefix_hits",
        "prefix_hit_tokens", "prefix_imports",
        "prompt_tokens", "generated_tokens",
        "prefill_steps", "decode_steps", "mixed_steps", "step_samples",
        "spec_rounds", "draft_calls", "drafted_tokens",
        "accepted_draft_tokens",
        "block_step_samples", "ttft_samples", "ttft_samples_capped",
        "itl_samples", "itl_samples_capped", "latency_samples",
        "latency_samples_capped", "timeseries_samples", "timeseries_dropped",
        "peak_blocks_in_use", "blocks_total", "prefix_cache_entries",
        "cow_copies", "prefix_evictions",
    )
    _MAX_KEYS = ("elapsed_s", "ttft_max_s", "itl_max_s")
    #: value key -> its weight key (count-weighted means; percentiles are
    #: APPROXIMATED by the same weighting — exact fleet percentiles would
    #: need the raw reservoirs, which snapshots deliberately do not carry)
    _WEIGHTED_KEYS = (
        ("ttft_mean_s", "ttft_samples"),
        ("ttft_p50_s", "ttft_samples"),
        ("itl_p50_s", "itl_samples"),
        ("itl_p95_s", "itl_samples"),
        ("latency_mean_s", "latency_samples"),
        ("mean_slot_occupancy", "step_samples"),
        ("mean_queue_depth", "step_samples"),
        ("mean_block_utilization", "block_step_samples"),
        ("mean_block_fragmentation", "block_step_samples"),
    )
    _EQUAL_OR_MIXED = ("numerics", "kv_layout")

    @staticmethod
    def merge(snaps: list[dict]) -> dict:
        """Combine snapshot dicts across engines (associative).

        Counters sum; throughput recomputes as summed tokens over the
        MAX elapsed window (engines run concurrently — summing rates
        would double-count shared wall time only when windows coincide,
        and max is the conservative fleet denominator either way); means
        weight by their carried sample counts; error-probe moments merge
        with Chan's parallel formula.  A merged dict is itself a valid
        ``merge`` input, so pairwise and flat merges agree (up to float
        association)."""
        snaps = list(snaps)
        if not snaps:
            return {}
        out: dict = {}
        for k in EngineMetrics._SUM_KEYS:
            vals = [s.get(k) for s in snaps if s.get(k) is not None]
            out[k] = sum(vals) if vals else None
        for k in EngineMetrics._MAX_KEYS:
            vals = [s.get(k) for s in snaps if s.get(k) is not None]
            out[k] = max(vals) if vals else None
        for k, wk in EngineMetrics._WEIGHTED_KEYS:
            pairs = [(s.get(k), s.get(wk)) for s in snaps
                     if s.get(k) is not None and s.get(wk)]
            if not pairs:
                # no weighted contributor: single-engine merge must be an
                # exact no-op, so a sole snapshot's value (e.g. the 0.0 a
                # zero-sample snapshot reports) passes through verbatim
                out[k] = snaps[0].get(k) if len(snaps) == 1 else None
            elif len(pairs) == 1:
                # one contributor: pass through exactly (v * w / w is not
                # bit-identical to v for every float)
                out[k] = pairs[0][0]
            else:
                num = sum(v * w for v, w in pairs)
                den = sum(w for _, w in pairs)
                out[k] = num / den
        for k in EngineMetrics._EQUAL_OR_MIXED:
            vals = {s.get(k) for s in snaps}
            out[k] = vals.pop() if len(vals) == 1 else "mixed"
        for k in ("decode_specialized", "metrics_window_s", "speculative_k"):
            vals = {s.get(k) for s in snaps}
            out[k] = vals.pop() if len(vals) == 1 else None
        # draft spec label: single non-None value passes through, a
        # heterogeneous fleet reads "mixed" (the per-spec breakdown below
        # keeps the split auditable)
        dn = {s.get("draft_numerics") for s in snaps
              if s.get("draft_numerics") is not None}
        out["draft_numerics"] = (dn.pop() if len(dn) == 1
                                 else ("mixed" if dn else None))
        elapsed = out.get("elapsed_s") or 0.0
        gen = out.get("generated_tokens") or 0
        total = gen + (out.get("prompt_tokens") or 0)
        out["gen_tok_per_s"] = round(gen / elapsed, 2) if elapsed else 0.0
        out["total_tok_per_s"] = round(total / elapsed, 2) if elapsed else 0.0
        # acceptance recomputes from the summed counters (rates never
        # average); the per-spec map unions by key, summing its counters
        drafted = out.get("drafted_tokens") or 0
        out["acceptance_rate"] = (
            round((out.get("accepted_draft_tokens") or 0) / drafted, 4)
            if drafted else None)
        by_spec: dict = {}
        for s in snaps:
            for label, st in (s.get("acceptance_by_draft_spec") or {}).items():
                cur = by_spec.setdefault(label, {"drafted": 0, "accepted": 0})
                cur["drafted"] += st["drafted"]
                cur["accepted"] += st["accepted"]
        for st in by_spec.values():
            st["acceptance_rate"] = (round(st["accepted"] / st["drafted"], 4)
                                     if st["drafted"] else None)
        out["acceptance_by_draft_spec"] = by_spec or None
        # error-probe moments: dict-union layers, Chan-merge shared paths
        probes = [s["error_probe"] for s in snaps if s.get("error_probe")]
        if probes:
            layers: dict = {}
            logits = (0, 0.0, 0.0)
            for p in probes:
                for path, st in p.get("layers", {}).items():
                    layers[path] = _merge_moments(
                        layers.get(path, (0, 0.0, 0.0)),
                        (st["n"], st["err_mean"], st["err_var"]))
                logits = _merge_moments(
                    logits, (p["logits_err_n"], p["logits_err_mean"],
                             p["logits_err_var"]))
            lvars = [v for _, _, v in layers.values()]
            pnum = {s["error_probe"].get("numerics") for s in snaps
                    if s.get("error_probe")}
            out["error_probe"] = {
                "runs": sum(p["runs"] for p in probes),
                "numerics": pnum.pop() if len(pnum) == 1 else "mixed",
                "logits_err_n": logits[0],
                "logits_err_mean": logits[1],
                "logits_err_var": logits[2],
                "mean_layer_err_var": (sum(lvars) / len(lvars)
                                       if lvars else None),
                "max_layer_err_var": max(lvars) if lvars else None,
                "layers": {path: {"n": n, "err_mean": m, "err_var": v}
                           for path, (n, m, v) in sorted(layers.items())},
            }
        else:
            out["error_probe"] = None
        # A/B shadow: counters sum, logit-delta moments Chan-merge,
        # match rate recomputes from the summed counters
        shadows = [s["shadow"] for s in snaps if s.get("shadow")]
        if shadows:
            logits = (0, 0.0, 0.0)
            for sh in shadows:
                logits = _merge_moments(
                    logits, (sh["logits_err_n"], sh["logits_err_mean"],
                             sh["logits_err_var"]))
            toks = sum(sh["tokens"] for sh in shadows)
            matches = sum(sh["token_matches"] for sh in shadows)
            snum = {sh.get("numerics") for sh in shadows}
            out["shadow"] = {
                "numerics": snum.pop() if len(snum) == 1 else "mixed",
                "sampled_requests": sum(sh["sampled_requests"]
                                        for sh in shadows),
                "tokens": toks,
                "token_matches": matches,
                "token_match_rate": (round(matches / toks, 4)
                                     if toks else None),
                "logits_err_n": logits[0],
                "logits_err_mean": logits[1],
                "logits_err_var": logits[2],
                "logits_err_max_abs": max(sh["logits_err_max_abs"]
                                          for sh in shadows),
            }
        else:
            out["shadow"] = None
        # power attribution: mac-unit totals sum (they are extensive
        # quantities), percentages recompute from the summed units
        powers = [s["power_attribution"] for s in snaps
                  if s.get("power_attribution")]
        if powers:
            per_tier: dict = {}
            per_layer: dict = {}
            tok_mix: dict = {}
            for p in powers:
                for label, t in p.get("per_tier", {}).items():
                    cur = per_tier.setdefault(label, {
                        "tokens": 0, "mac_units": 0.0,
                        "mac_units_saved": 0.0})
                    cur["tokens"] += t["tokens"]
                    cur["mac_units"] += t["mac_units"]
                    cur["mac_units_saved"] += t["mac_units_saved"]
                for path, lay in p.get("per_layer", {}).items():
                    cur = per_layer.setdefault(path, {
                        "mac_units": 0.0, "mac_units_saved": 0.0})
                    cur["mac_units"] += lay["mac_units"]
                    cur["mac_units_saved"] += lay["mac_units_saved"]
                for label, t in p.get("tokens_by_numerics", {}).items():
                    tok_mix[label] = tok_mix.get(label, 0) + t
            for cur in list(per_tier.values()) + list(per_layer.values()):
                cur["mac_units"] = round(cur["mac_units"], 1)
                cur["mac_units_saved"] = round(cur["mac_units_saved"], 1)
                pct = (100.0 * cur["mac_units_saved"] / cur["mac_units"]
                       if cur["mac_units"] else 0.0)
                key = "power_saving_pct" if "tokens" in cur else "saving_pct"
                cur[key] = round(pct, 3)
            units = sum(t["mac_units"] for t in per_tier.values())
            saved = sum(t["mac_units_saved"] for t in per_tier.values())
            out["power_attribution"] = {
                "tokens_attributed": sum(p["tokens_attributed"]
                                         for p in powers),
                "tokens_by_numerics": dict(sorted(tok_mix.items())),
                "mac_units": round(units, 1),
                "mac_units_saved": round(saved, 1),
                "modeled_power_saving_pct": (
                    round(100.0 * saved / units, 3) if units else 0.0),
                "per_tier": dict(sorted(per_tier.items())),
                "per_layer": dict(sorted(per_layer.items())),
            }
        else:
            out["power_attribution"] = None
        return out
