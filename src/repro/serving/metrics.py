"""Per-engine serving metrics.

Counters are recorded on the host around each engine iteration; nothing
here touches device state.  ``snapshot()`` derives the headline serving
numbers: decode tokens/s, end-to-end tokens/s, time-to-first-token
(mean/p50/max), inter-token stall (p50/p95/max over per-request gaps
between consecutive generated tokens — the decode-stall signal the mixed
scheduler exists to shrink), mean queue depth, and mean slot occupancy.
Under the paged KV layout, block-level counters ride along: utilization
and fragmentation (slot occupancy alone overstates utilization when
lengths are heterogeneous), prefix-cache hits and skipped prefill
tokens, COW copies, prefix evictions, and ``no_capacity_stalls`` —
iterations where queued work waited on pool capacity, which queue-full
rejection counts used to hide.

The throughput clock starts lazily at the FIRST served batch (the engine
arms it just before dispatching; ``record_step`` arms it as a fallback),
not at construction: engines compile and warm up between being built and
serving their first batch, and charging that wall time to the denominator
deflates ``gen_tok_per_s`` for short traces.  ``reset_metrics()`` (a fresh
instance) therefore re-arms the lazy clock too.
"""

from __future__ import annotations

import dataclasses
import time


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q * (len(ys) - 1)))))
    return ys[i]


@dataclasses.dataclass
class EngineMetrics:
    #: set by the first record_step (lazy); None while nothing was served
    t_start: float | None = None

    #: name of the NumericsSpec the served parameters were packed under
    #: (None = unknown/float); surfaced in snapshot() for fleet audits
    numerics: str | None = None

    #: whether the engine's slot count fits the kernel block picker's
    #: decode-specialized tiles (repro.kernels.ops.DECODE_M_MAX): one-token
    #: decode steps then run thin-M, single-K-step kernel launches
    decode_specialized: bool | None = None

    #: KV memory model the engine serves under ("contiguous" | "paged")
    kv_layout: str = "contiguous"

    prompt_tokens: int = 0
    generated_tokens: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    mixed_steps: int = 0  # chunk-shaped batches carrying decode rows

    submitted: int = 0
    rejected: int = 0
    evicted: int = 0  # queued requests re-rejected for higher-priority work
    finished: int = 0

    #: engine iterations where queued work could not be admitted because
    #: the pool lacked capacity (free slots, or — paged — free blocks).
    #: Distinct from queue-full REJECTION: a stall delays work, a
    #: rejection drops it; before this counter the two were
    #: indistinguishable in the snapshot.
    no_capacity_stalls: int = 0

    #: prefix-cache reuse (paged layout): requests admitted onto cached
    #: blocks, and the total prompt tokens whose prefill that skipped
    prefix_hits: int = 0
    prefix_hit_tokens: int = 0

    ttfts: list[float] = dataclasses.field(default_factory=list)
    #: per-request gaps between consecutive generated tokens (seconds)
    itls: list[float] = dataclasses.field(default_factory=list)
    latencies: list[float] = dataclasses.field(default_factory=list)

    _occupancy_sum: float = 0.0
    _queue_depth_sum: float = 0.0
    _samples: int = 0

    # block-level accounting (paged layout; None-ish for contiguous).
    # Slot occupancy OVERSTATES utilization under heterogeneous lengths —
    # a slot holding a 16-token chat counts like one holding a 256-token
    # document — so block utilization/fragmentation is reported alongside.
    _block_util_sum: float = 0.0
    _block_frag_sum: float = 0.0
    _block_samples: int = 0
    _last_block_stats: dict | None = None

    # -- recording -----------------------------------------------------------

    def start_clock(self) -> None:
        """Arm the throughput clock (idempotent).  The engine calls this
        just before dispatching its first batch, so that step's wall time
        is inside the measured window; ``record_step`` also arms it as a
        fallback for direct users of the metrics object."""
        if self.t_start is None:
            self.t_start = time.time()

    def record_step(self, kind: str, occupancy: float, queue_depth: int,
                    prompt_tokens: int = 0, generated_tokens: int = 0,
                    block_stats: dict | None = None) -> None:
        self.start_clock()
        if kind == "prefill":
            self.prefill_steps += 1
        elif kind == "mixed":
            self.mixed_steps += 1
        else:
            self.decode_steps += 1
        self.prompt_tokens += prompt_tokens
        self.generated_tokens += generated_tokens
        self._occupancy_sum += occupancy
        self._queue_depth_sum += queue_depth
        self._samples += 1
        if block_stats is not None:
            self._block_util_sum += block_stats["block_util"]
            self._block_frag_sum += block_stats["block_frag"]
            self._block_samples += 1
            self._last_block_stats = block_stats

    def record_first_token(self, req) -> None:
        if req.ttft is not None:
            self.ttfts.append(req.ttft)

    def record_itl(self, gap: float | None) -> None:
        """One inter-token gap (``Request.emit``'s return; None = first
        token of a request, which has no gap)."""
        if gap is not None:
            self.itls.append(gap)

    def record_finish(self, req) -> None:
        self.finished += 1
        if req.t_finish is not None:
            self.latencies.append(req.t_finish - req.t_submit)

    # -- derived -------------------------------------------------------------

    def snapshot(self) -> dict:
        elapsed = (max(time.time() - self.t_start, 1e-9)
                   if self.t_start is not None else 0.0)
        total_tok = self.prompt_tokens + self.generated_tokens
        blk = self._last_block_stats or {}
        return {
            "numerics": self.numerics,
            "decode_specialized": self.decode_specialized,
            "kv_layout": self.kv_layout,
            "elapsed_s": round(elapsed, 4),
            "requests_finished": self.finished,
            "requests_rejected": self.rejected,
            "requests_evicted": self.evicted,
            "no_capacity_stalls": self.no_capacity_stalls,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "mean_block_utilization": round(
                self._block_util_sum / self._block_samples, 3)
            if self._block_samples else None,
            "mean_block_fragmentation": round(
                self._block_frag_sum / self._block_samples, 3)
            if self._block_samples else None,
            "peak_blocks_in_use": blk.get("peak_blocks_in_use"),
            "blocks_total": blk.get("blocks_total"),
            "prefix_cache_entries": blk.get("prefix_cache_entries"),
            "cow_copies": blk.get("cow_copies"),
            "prefix_evictions": blk.get("prefix_evictions"),
            "prompt_tokens": self.prompt_tokens,
            "generated_tokens": self.generated_tokens,
            "gen_tok_per_s": round(self.generated_tokens / elapsed, 2)
            if elapsed else 0.0,
            "total_tok_per_s": round(total_tok / elapsed, 2)
            if elapsed else 0.0,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "mixed_steps": self.mixed_steps,
            "ttft_mean_s": round(sum(self.ttfts) / len(self.ttfts), 4)
            if self.ttfts else None,
            "ttft_p50_s": round(_percentile(self.ttfts, 0.5), 4)
            if self.ttfts else None,
            "ttft_max_s": round(max(self.ttfts), 4) if self.ttfts else None,
            "itl_p50_s": round(_percentile(self.itls, 0.5), 4)
            if self.itls else None,
            "itl_p95_s": round(_percentile(self.itls, 0.95), 4)
            if self.itls else None,
            "itl_max_s": round(max(self.itls), 4) if self.itls else None,
            "latency_mean_s": round(sum(self.latencies) / len(self.latencies), 4)
            if self.latencies else None,
            "mean_slot_occupancy": round(self._occupancy_sum / self._samples, 3)
            if self._samples else 0.0,
            "mean_queue_depth": round(self._queue_depth_sum / self._samples, 2)
            if self._samples else 0.0,
        }
