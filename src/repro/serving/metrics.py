"""Per-engine serving metrics.

Counters are recorded on the host around each engine iteration; nothing
here touches device state.  ``snapshot()`` derives the headline serving
numbers: decode tokens/s, end-to-end tokens/s, time-to-first-token
(mean/p50/max), mean queue depth, and mean slot occupancy.
"""

from __future__ import annotations

import dataclasses
import time


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q * (len(ys) - 1)))))
    return ys[i]


@dataclasses.dataclass
class EngineMetrics:
    t_start: float = dataclasses.field(default_factory=time.time)

    #: name of the NumericsSpec the served parameters were packed under
    #: (None = unknown/float); surfaced in snapshot() for fleet audits
    numerics: str | None = None

    #: whether the engine's slot count fits the kernel block picker's
    #: decode-specialized tiles (repro.kernels.ops.DECODE_M_MAX): one-token
    #: decode steps then run thin-M, single-K-step kernel launches
    decode_specialized: bool | None = None

    prompt_tokens: int = 0
    generated_tokens: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0

    submitted: int = 0
    rejected: int = 0
    finished: int = 0

    ttfts: list[float] = dataclasses.field(default_factory=list)
    latencies: list[float] = dataclasses.field(default_factory=list)

    _occupancy_sum: float = 0.0
    _queue_depth_sum: float = 0.0
    _samples: int = 0

    # -- recording -----------------------------------------------------------

    def record_step(self, kind: str, occupancy: float, queue_depth: int,
                    prompt_tokens: int = 0, generated_tokens: int = 0) -> None:
        if kind == "prefill":
            self.prefill_steps += 1
        else:
            self.decode_steps += 1
        self.prompt_tokens += prompt_tokens
        self.generated_tokens += generated_tokens
        self._occupancy_sum += occupancy
        self._queue_depth_sum += queue_depth
        self._samples += 1

    def record_first_token(self, req) -> None:
        if req.ttft is not None:
            self.ttfts.append(req.ttft)

    def record_finish(self, req) -> None:
        self.finished += 1
        if req.t_finish is not None:
            self.latencies.append(req.t_finish - req.t_submit)

    # -- derived -------------------------------------------------------------

    def snapshot(self) -> dict:
        elapsed = max(time.time() - self.t_start, 1e-9)
        total_tok = self.prompt_tokens + self.generated_tokens
        return {
            "numerics": self.numerics,
            "decode_specialized": self.decode_specialized,
            "elapsed_s": round(elapsed, 4),
            "requests_finished": self.finished,
            "requests_rejected": self.rejected,
            "prompt_tokens": self.prompt_tokens,
            "generated_tokens": self.generated_tokens,
            "gen_tok_per_s": round(self.generated_tokens / elapsed, 2),
            "total_tok_per_s": round(total_tok / elapsed, 2),
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "ttft_mean_s": round(sum(self.ttfts) / len(self.ttfts), 4)
            if self.ttfts else None,
            "ttft_p50_s": round(_percentile(self.ttfts, 0.5), 4)
            if self.ttfts else None,
            "ttft_max_s": round(max(self.ttfts), 4) if self.ttfts else None,
            "latency_mean_s": round(sum(self.latencies) / len(self.latencies), 4)
            if self.latencies else None,
            "mean_slot_occupancy": round(self._occupancy_sum / self._samples, 3)
            if self._samples else 0.0,
            "mean_queue_depth": round(self._queue_depth_sum / self._samples, 2)
            if self._samples else 0.0,
        }
