"""Pooled, slot-indexed KV-cache manager.

One preallocated pytree holds the decode state for every slot — attention
archs get ``(slots, heads, max_len, head_dim)`` K/V buffers (or MLA latent
buffers) in the serving cache dtype with a per-slot write cursor
(``cache["lengths"]``); RWKV gets per-slot recurrent state.  Slots are
recycled: freeing is O(1) bookkeeping (the cursor reset masks stale
entries; the next occupant overwrites them chunk by chunk).

The pool owns the cache pytree functionally: the engine reads
``pool.cache``, runs the jitted step, and stores the result back with
:meth:`update`.  A slot owns a contiguous ``max_len`` stripe; the paged
(block-granular, prefix-sharing) alternative lives in
``repro.serving.paged`` behind ``EngineConfig.kv_layout``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi

_CACHE_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                 "int8": jnp.int8}


class SlotPool:
    """Fixed number of sequence slots over one pooled cache pytree."""

    def __init__(self, api: ModelApi, slots: int, max_len: int,
                 cache_dtype: str = "bfloat16") -> None:
        if not api.supports_slots:
            raise NotImplementedError(
                f"{api.cfg.name}: architecture not servable through the slot "
                "engine yet (ring-buffer / SSM slot state are ROADMAP items)")
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.cache = api.init_slot_cache(slots, max_len,
                                         _CACHE_DTYPES[cache_dtype])
        # attention caches mask stale entries by position, so slot recycling
        # is cursor-reset only; RECURRENT state (rwkv) has no mask — the
        # previous occupant's state must be zeroed on reassignment
        self._recurrent = bool(api.cfg.rwkv)
        # recurrent-state zeroing for one slot, fused: one jitted dispatch
        # updating every state leaf (slot index traced -> compiles once),
        # instead of one .at[:, slot].set(0) dispatch per leaf per admission
        self._zero_slot = jax.jit(
            lambda leaves, slot: jax.tree.map(
                lambda v: v.at[:, slot].set(0), leaves)) \
            if self._recurrent else None
        self._free: list[int] = list(range(slots - 1, -1, -1))  # pop -> slot 0 first
        self._owner: dict[int, int] = {}  # slot -> rid

    # -- allocation ----------------------------------------------------------

    def acquire(self, rid: int) -> int | None:
        """Claim a free slot for request ``rid`` (cursor reset to 0)."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._owner[slot] = rid
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)
        if self._recurrent:
            # collect the state keys first — never mutate the dict being
            # iterated — then zero every leaf in one fused update
            keys = [k for k in self.cache if k != "lengths"]  # (L, slots, ...)
            zeroed = self._zero_slot({k: self.cache[k] for k in keys},
                                     jnp.asarray(slot, jnp.int32))
            self.cache.update(zeroed)
        return slot

    def acquire_for(self, req) -> int | None:
        """Request-aware acquire (the scheduler's entry point; the paged
        pool overloads it with prefix matching and block reservation).
        The contiguous layout needs nothing beyond a free slot."""
        return self.acquire(req.rid)

    def release(self, slot: int) -> None:
        del self._owner[slot]
        self._free.append(slot)

    # -- state ---------------------------------------------------------------

    def update(self, new_cache: dict) -> None:
        """Store the cache pytree returned by the jitted step."""
        self.cache = new_cache

    def lengths(self) -> np.ndarray:
        """Host copy of the per-slot write cursors."""
        return np.asarray(self.cache["lengths"])

    def set_lengths(self, new_lengths: np.ndarray) -> None:
        """Overwrite every slot's write cursor (speculative-decode rollback).

        A cursor move is a sound rollback for attention-style caches:
        entries beyond a slot's cursor are never attended (the position
        mask) and are overwritten before they are read (``_slot_update``
        writes before attention), so rejected speculative K/V needs no
        erasing — only the cursor retreats.  Recurrent (RWKV) state has no
        cursor to move, which is why the engine refuses speculation there.
        """
        from repro.models.lm import rollback_slots

        self.cache = rollback_slots(self.cache, new_lengths)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.slots - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.slots

    def owner(self, slot: int) -> int | None:
        return self._owner.get(slot)
