"""The continuous-batching serving engine.

:class:`ServingEngine` ties the pieces together: submit() runs admission
control (with priority eviction from a full queue) and enqueues; step()
admits into free slots (``kv_layout="paged"`` additionally requires every
block a request can need to be reservable, and may skip cached shared-
prefix prefill entirely), asks the scheduler for one fixed-shape batch —
chunk-shaped with mixed prefill+decode rows when both kinds pend
(``EngineConfig.mixed_batches``), thin ``(slots, 1)`` otherwise — runs the
jitted slot step, and advances every participating request through one
unified per-row postprocess (streaming tokens to callbacks as they decode).

The same engine serves float, exact-int8, and approximate+CV packed
parameters — numerics live entirely in the parameter representation
(``repro.launch.serve.build_serving_params``), not in the engine.  The
engine records which NumericsSpec produced its parameters (``numerics=``,
normally the spec's name) and surfaces it through the metrics snapshot so
a fleet's per-engine numerics are auditable from monitoring alone.

Generation is greedy (argmax), matching the sequential
``prefill``/``decode_step`` baseline token for token — the equivalence
contract tested by tests/test_serving_engine.py.

With ``EngineConfig.speculative_k > 0`` and a second, APPROXIMATE
parameter set (``draft_params=``) the engine runs self-verifying
speculative decode (``repro.serving.speculative``): the approximate
parameters draft k greedy tokens per slot on the thin step, one
chunk-shaped exact call verifies them all, and only verifier tokens are
emitted — same bit-exact contract, fewer exact dispatches per token.
"""

from __future__ import annotations

import itertools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, EngineConfig
from repro.models import ModelApi, build_model
from repro.serving.kv_pool import SlotPool
from repro.serving.metrics import EngineMetrics
from repro.serving.request import (AdmissionController, Request, RequestQueue,
                                   RequestState)
from repro.serving.scheduler import ScheduledBatch, SlotScheduler
from repro.serving.telemetry import SpanTracer
from repro.serving import speculative


def _has_blocked_packs(params) -> bool:
    """True iff any packed leaf ships the offline-blocked Pallas layout
    (the only path the decode-specialized block picker applies to)."""
    from repro.core.approx_linear import QuantizedDense, QuantizedDenseGroup

    found = False

    def walk(node):
        nonlocal found
        if found:
            return
        if isinstance(node, (QuantizedDense, QuantizedDenseGroup)):
            found = found or node.blocked is not None
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return found


def power_profile_from_params(params, n_array: int = 64) -> dict:
    """Per-layer modeled MAC cost/saving profile of a packed parameter
    tree: ``{path: {mac_per_token, saving_pct}}``.

    ``mac_per_token`` is the layer's MAC count per served token (the
    product of its weight shape — leading scan/stack dims included, so a
    stacked layer counts every member).  ``saving_pct`` is the cost
    model's modeled array-power saving for the layer's policy (0 for
    exact/float layers).  Only linear layers are profiled — they are
    where the approximate multipliers live, and the quantity the paper's
    power model prices.  This is the ``PackPlan`` x ``cost_model`` join
    evaluated on the LIVE pack, so a governor hot-swap re-derives it from
    whatever is actually serving (see ``EngineMetrics.set_power_profile``).
    """
    from repro.core.approx_linear import (QuantizedDense,
                                          QuantizedDenseGroup,
                                          is_linear_params)
    from repro.core.cost_model import power_saving

    prof: dict[str, dict] = {}

    def add(path, shape, policy):
        saving = (power_saving(policy.mode, policy.m, n_array)
                  if policy is not None and policy.is_approx else 0.0)
        prof[path] = {"mac_per_token": float(np.prod(shape)),
                      "saving_pct": round(float(saving), 3)}

    def walk(node, path):
        if isinstance(node, (QuantizedDense, QuantizedDenseGroup)):
            add(path, node.pack.w_q.shape, node.policy)
        elif isinstance(node, dict):
            if is_linear_params(node):
                add(path, node["w"].shape, None)
                return
            for k, v in node.items():
                walk(v, f"{path}/{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}/{i}" if path else str(i))

    walk(params, "")
    return prof


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig = EngineConfig(),
                 mesh=None, api: ModelApi | None = None,
                 numerics: str | None = None,
                 draft_params=None, draft_numerics: str | None = None,
                 governor=None, pack_fn: Callable | None = None,
                 fault_injector=None, exact_params=None,
                 engine_id: str | None = None,
                 shadow_params=None,
                 shadow_numerics: str | None = None) -> None:
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.api = api or build_model(cfg)
        self.numerics = numerics  # active NumericsSpec name (None = unknown)
        #: stable identity for traces and fleet routing; defaults to the
        #: numerics label (the pre-fleet behavior, where one engine WAS
        #: the deployment and its spec named it)
        self.engine_id = engine_id or numerics or "engine"
        # speculative decode: ``params`` verifies (and serves prefill),
        # ``draft_params`` — the same weights packed under an approximate
        # spec — proposes.  Kept fully optional: without speculative_k the
        # engine never touches them.
        self.draft_params = draft_params
        self.draft_numerics = draft_numerics
        self._spec_k = int(ecfg.speculative_k)
        if self._spec_k:
            if draft_params is None:
                raise ValueError(
                    "speculative_k > 0 needs draft_params: the approximate-"
                    "spec packed parameters that draft for this engine "
                    "(same weights, different numerics — see "
                    "repro.launch.serve.build_serving_params)")
            if cfg.rwkv:
                # rollback is a cursor move over position-indexed K/V;
                # recurrent per-slot state cannot rewind a rejected draft
                raise NotImplementedError(
                    f"{cfg.name}: speculative decode needs a position-"
                    "indexed KV cache to roll back rejected drafts "
                    "(recurrent RWKV state cannot rewind)")
        if ecfg.kv_layout == "paged":
            from repro.serving.paged import PagedKVPool

            self.pool = PagedKVPool(self.api, ecfg)
        elif ecfg.kv_layout == "contiguous":
            self.pool = SlotPool(self.api, ecfg.slots, ecfg.max_len,
                                 ecfg.cache_dtype)
        else:
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r}; "
                             "valid choices: ['contiguous', 'paged']")
        self._paged = ecfg.kv_layout == "paged"
        # cumulative pool counters at the start of the metrics window
        self._block_baseline = (self.pool.block_stats() if self._paged
                                else None)
        self.queue = RequestQueue()
        # paged: admission also screens out jobs whose worst-case block
        # need exceeds the whole pool (they could never be placed and
        # would wedge the FIFO head in an eternal capacity stall)
        self.admission = AdmissionController(
            ecfg.max_queue, ecfg.max_len, ecfg.prefill_chunk,
            kv_block_size=ecfg.kv_block_size if self._paged else None,
            kv_blocks=self.pool.blocks_total if self._paged else None)
        self.scheduler = SlotScheduler(ecfg.slots, ecfg.prefill_chunk,
                                       ecfg.interleave, ecfg.mixed_batches)
        # decode steps are (slots, 1) token blocks: a slot count within the
        # kernel block picker's decode window means every continuous-decode
        # iteration runs the thin-M, single-K-step specialized tiles — but
        # only the Pallas blocked packs go through that picker, so the flag
        # is gated on the served parameters actually carrying blocked layouts
        from repro.kernels.ops import DECODE_M_MAX

        self.metrics = EngineMetrics(
            numerics=numerics,
            kv_layout=ecfg.kv_layout,
            decode_specialized=(ecfg.slots <= DECODE_M_MAX
                                and _has_blocked_packs(params)),
            window_s=ecfg.metrics_window_s,
            speculative_k=self._spec_k,
            draft_numerics=draft_numerics if self._spec_k else None)
        # request-span tracing: a bounded per-engine ring of typed events,
        # recorded at points the engine already touches each request
        self.tracer = (SpanTracer(capacity=ecfg.trace_buffer,
                                  engine=self.engine_id)
                       if ecfg.trace else None)
        self._bridge_window_samples()
        # approximation-error probe: every N steps, one scheduled row is
        # re-run eagerly through the exact-int8 path (repro.quant.error_probe)
        self._probe = None
        self._steps = 0
        if ecfg.error_probe_every > 0:
            from repro.quant.error_probe import ErrorProbe

            self._probe = ErrorProbe(self.api.decode_slots, mesh=mesh,
                                     paged=self._paged)
        # -- robustness layer (repro.serving.governor / repro.quant.faults) --
        # ``governor``: a NumericsGovernor walking the degradation ladder on
        # SLO breaches; ``pack_fn(spec_or_none) -> params`` builds the pack
        # for a rung on first use (cached per rung name).  ``fault_injector``
        # corrupts deterministically for testing; ``exact_params`` (optional)
        # is the pack quarantine replays run on (defaults to the live pack —
        # correct when the live pack IS exact, e.g. int8 serving).
        self.governor = governor
        self._pack_fn = pack_fn
        self._injector = fault_injector
        self._exact_params = exact_params
        self._detect = fault_injector is not None or ecfg.detect_faults
        self._rung_packs: dict = {}
        #: structural record of quarantine replays: {rid, slot, step, token}
        self.quarantine_log: list[dict] = []
        if governor is not None:
            if pack_fn is None:
                raise ValueError(
                    "a governor needs pack_fn: called with a rung's "
                    "NumericsSpec (or None for float) to build the pack it "
                    "hot-swaps in — see repro.launch.serve for the "
                    "build_serving_params closure")
            if ecfg.error_probe_every <= 0:
                raise ValueError(
                    "the governor consumes the error probe; set "
                    "EngineConfig.error_probe_every > 0")
            if self._spec_k:
                raise ValueError(
                    "governor + speculative decode is unsupported: "
                    "speculation already pins every emitted token to the "
                    "exact pack, so there is no approximate emission for "
                    "an SLO to govern")
            # the live params ARE the starting rung's pack
            self._rung_packs[governor.rung.name] = params
        if fault_injector is not None and self._spec_k:
            raise ValueError(
                "fault injection targets the plain serving path; the "
                "speculative path's emissions are exact-verified already")
        # -- A/B shadow serving (repro.serving.shadow) -----------------------
        # a sampled fraction of FINISHED requests replays teacher-forced
        # through a second pack on this engine's ModelApi; the replay
        # happens at finish time inside step() and records a "shadow" span
        self._shadow = None
        self._finish_count = 0
        if ecfg.shadow_fraction > 0:
            if shadow_params is None:
                raise ValueError(
                    "shadow_fraction > 0 needs shadow_params: the second "
                    "NumericsSpec pack sampled requests replay through "
                    "(same weights, different numerics)")
            if self._spec_k:
                raise ValueError(
                    "shadow serving + speculative decode is unsupported: "
                    "the draft pack already occupies the second-pack slot")
            if governor is not None:
                raise ValueError(
                    "shadow serving + governor is unsupported: a mid-run "
                    "hot-swap would mix regimes inside one A/B verdict")
            from repro.serving.shadow import ShadowRunner

            self._shadow = ShadowRunner(
                self.api, ecfg, params, shadow_params,
                primary_label=numerics or "primary",
                shadow_label=shadow_numerics or "shadow", mesh=mesh)
            self.metrics.shadow_numerics = self._shadow.shadow_label
        # modeled power attribution: profile the live pack per numerics
        # label (cached — a governor escalate/relax cycle profiles each
        # rung once) and register it with the metrics joiner
        self._power_profiles: dict = {}
        self._register_power_profile()
        self.active: dict[int, Request] = {}
        self._rid = itertools.count()
        decode_slots = self.api.decode_slots
        # one jitted callable, two shapes ever: (slots, 1) and (slots, chunk).
        # The paged layout adds the fixed-shape block-table argument — its
        # CONTENT changes per admission, its shape never, so the invariant
        # holds per layout.
        if self._paged:
            self._step_fn = jax.jit(
                lambda p, t, c, nv, bt: decode_slots(p, t, c, nv, mesh=mesh,
                                                     block_tables=bt))
        else:
            self._step_fn = jax.jit(
                lambda p, t, c, nv: decode_slots(p, t, c, nv, mesh=mesh))

    def _bridge_window_samples(self) -> None:
        """Forward windowed metrics samples into the span trace as Chrome
        counter events (Perfetto renders them as time-series tracks)."""
        if self.tracer is not None and self.metrics.window_s > 0:
            # the sample's own "t" (wall-clock window stamp) must not shadow
            # record()'s monotonic t parameter — keep it as arg "window_t"
            self.metrics.on_window_sample = (
                lambda s: self.tracer.record(
                    "metrics_window",
                    **{("window_t" if k == "t" else k): v
                       for k, v in s.items()}))

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, priority: int = 0,
               eos_id: int | None = None,
               on_token: Callable | None = None,
               deadline_ms: float | None = None) -> Request:
        """Admission-checked enqueue; returns the Request (maybe REJECTED).

        A request returned as QUEUED can still become REJECTED later: a
        full queue evicts its worst member when a strictly-higher-priority
        request arrives.  Callers polling a single Request must treat
        ``state == REJECTED`` as terminal alongside ``finished``."""
        req = Request(rid=next(self._rid), prompt=[int(t) for t in prompt],
                      max_new_tokens=int(max_new_tokens), priority=priority,
                      eos_id=eos_id, on_token=on_token,
                      deadline_ms=deadline_ms)
        self.metrics.submitted += 1
        tr = self.tracer
        ok, reason, evicted = self.admission.admit(self.queue, req)
        if not ok:
            req.state = RequestState.REJECTED
            req.reject_reason = reason
            self.metrics.rejected += 1
            if tr is not None:
                tr.record("rejected", rid=req.rid, reason=reason)
            return req
        if evicted is not None:
            # queue was full of strictly lower-priority work: the worst
            # queued request is re-rejected to make room for this one
            evicted.state = RequestState.REJECTED
            evicted.reject_reason = (f"evicted from full queue by "
                                     f"higher-priority request {req.rid}")
            self.metrics.rejected += 1
            self.metrics.evicted += 1
            if tr is not None:
                tr.record("evicted", rid=evicted.rid, by=req.rid)
        self.queue.push(req)
        if tr is not None:
            tr.record("queued", rid=req.rid, t=req.t_queued_mono,
                      prompt_len=req.prompt_len, priority=req.priority)
        return req

    # -- engine loop ---------------------------------------------------------

    @property
    def idle(self) -> bool:
        return not self.active and not len(self.queue)

    def step(self) -> list[Request]:
        """One engine iteration; returns requests that finished in it
        (including queued requests evicted by an expired deadline — they
        are terminal without ever touching a slot)."""
        tr = self.tracer
        expired = self.scheduler.purge_expired(self.queue, self.metrics,
                                               tracer=tr)
        admitted = self.scheduler.admit(self.queue, self.pool, self.active,
                                        self.metrics, tracer=tr)
        for r in admitted:
            if r.prefix_hit_tokens:
                self.metrics.prefix_hits += 1
                self.metrics.prefix_hit_tokens += r.prefix_hit_tokens
                if tr is not None:
                    tr.record("prefix_hit", rid=r.rid,
                              hit_tokens=r.prefix_hit_tokens)
        if self._spec_k:
            # every turn goes through the speculative round — including
            # turns with zero spec rows — so plain decode rows always ride
            # chunk-shaped exact calls and the exact parameters never meet
            # the thin shape (two compiled shapes total, same as plain
            # serving: draft structure x thin + exact structure x chunk)
            rnd = speculative.plan_round(self.active, self._spec_k,
                                         self.ecfg.prefill_chunk)
            if rnd is None:
                return expired
            return expired + self._speculative_step(rnd)
        batch = self.scheduler.next_batch(self.active)
        if batch is None:
            return expired
        # arm the throughput clock BEFORE the dispatch: warmup between
        # construction and the first served batch stays excluded, but the
        # first measured step's own wall time is inside the window
        self.metrics.start_clock()
        t0 = time.perf_counter() if tr is not None else 0.0
        tables = None
        if self._paged:
            # copy-on-write barrier: every block this batch writes must be
            # uniquely owned before the jitted step sees the tables
            cow0 = self.pool.cow_copies if tr is not None else 0
            for slot, nv in enumerate(batch.n_valid):
                self.pool.ensure_writable(slot, int(nv))
            self.pool.flush_copies()
            if tr is not None and self.pool.cow_copies > cow0:
                tr.record("cow_copy", copies=self.pool.cow_copies - cow0)
            tables = self.pool.block_tables_array()
        cache_before = self.pool.cache
        logits, new_cache = self._dispatch(self.params, batch, tables)
        self.pool.update(new_cache)
        if self._paged:
            self.pool.advance(batch.n_valid)
        # fault injection (step surface): corrupt chosen rows' logits on
        # the host, modeling a transient corruption of the step's output;
        # the detector below must catch every one before emission
        if (self._injector is not None
                and self._injector.spec.surface == "step"
                and self._injector.fires(self._steps)):
            live = [r.slot for r in batch.rows
                    if batch.n_valid[r.slot] > 0]
            bad_rows = self._injector.plan_rows(self._steps, live)
            if bad_rows:
                logits = self._injector.corrupt_logits(self._steps, logits,
                                                       bad_rows)
                self.metrics.faults_injected += len(bad_rows)
        pp_batch, q_finished, q_emitted, q_prompt = (
            self._quarantine(batch, logits, tables) if self._detect
            else (batch, [], 0, 0))
        finished, emitted, prompt_toks = self._postprocess(pp_batch, logits)
        finished += q_finished
        emitted += q_emitted
        prompt_toks += q_prompt
        if tr is not None:
            t1 = time.perf_counter()
            for r, kind in zip(batch.rows, batch.row_kinds):
                tr.record("prefill_chunk" if kind == "prefill"
                          else "decode_step", rid=r.rid, t=t0, dur=t1 - t0,
                          slot=r.slot, n_valid=int(batch.n_valid[r.slot]))
            for r in finished:
                tr.record("finished", rid=r.rid, reason=r.finish_reason,
                          generated=len(r.generated))
        self.metrics.record_step(
            batch.kind, self.pool.occupancy, len(self.queue),
            prompt_tokens=prompt_toks, generated_tokens=emitted,
            block_stats=self._windowed_block_stats() if self._paged else None)
        self._steps += 1
        if (self._probe is not None
                and self._steps % self.ecfg.error_probe_every == 0):
            self._run_probe(batch, cache_before, tables)
        if self._shadow is not None and finished:
            self._run_shadow(finished)
        return expired + finished

    # -- A/B shadow serving (repro.serving.shadow) ---------------------------

    def _run_shadow(self, finished: list[Request]) -> None:
        """Replay sampled finished requests through the shadow pack.

        Sampling is deterministic (every Nth finished request with
        generated tokens), the replay is teacher-forced along the
        PRIMARY's emitted tokens, and each replay records a ``shadow``
        span whose duration is the replay's wall time — so stall
        attribution prices shadow cost like probe cost."""
        for r in finished:
            if not r.generated:
                continue
            self._finish_count += 1
            if not self._shadow.wants(self._finish_count):
                continue
            t0 = time.perf_counter()
            rec = self._shadow.replay(r.prompt, r.generated)
            t1 = time.perf_counter()
            self.metrics.record_shadow(rec)
            if self.tracer is not None:
                self.tracer.record(
                    "shadow", rid=r.rid, t=t0, dur=t1 - t0,
                    tokens=rec["tokens"], matches=rec["matches"],
                    logits_err_var=rec["logits_err"]["var"],
                    logits_err_max_abs=rec["logits_err"]["max_abs"])

    def shadow_verdict(self) -> dict | None:
        """The accumulated accuracy-vs-power A/B verdict (None when no
        shadow is configured or nothing was sampled yet)."""
        return self._shadow.verdict() if self._shadow is not None else None

    # -- fault detection & quarantine (repro.quant.faults) -------------------

    def _quarantine(self, batch: ScheduledBatch, logits,
                    tables) -> tuple[ScheduledBatch, list[Request], int, int]:
        """Detect corrupted rows in this step's logits; quarantine them.

        Detection reads each live row's consumed column (``n_valid - 1``)
        and flags non-finite or divergent values
        (:func:`repro.quant.faults.suspect_rows`).  A flagged row's KV
        cursor rolls back to its pre-step value (``set_lengths`` — a pure
        cursor move on both layouts, PR 7's rollback primitive) and the
        row REPLAYS through the exact pack with the injector never
        consulted, so the corrupted logits are discarded before any token
        is emitted.  Returns the cleaned batch (flagged rows removed) and
        the replay's ``(finished, emitted, prompt_tokens)``.
        """
        from repro.quant import faults

        nv = np.asarray(batch.n_valid)
        live = [(r, k) for r, k in zip(batch.rows, batch.row_kinds)
                if nv[r.slot] > 0]
        if not live:
            return batch, [], 0, 0
        lg = np.asarray(logits)
        cols = np.maximum(nv - 1, 0)
        picked = lg[np.arange(lg.shape[0]), cols]  # (slots, vocab)
        slots = np.array([r.slot for r, _ in live])
        mask = faults.suspect_rows(picked[slots])
        if not mask.any():
            return batch, [], 0, 0
        bad = [live[i] for i in np.nonzero(mask)[0]]
        bad_slots = {r.slot for r, _ in bad}
        tr = self.tracer
        self.metrics.faults_detected += len(bad)
        if tr is not None:
            for r, _ in bad:
                tr.record("fault_detected", rid=r.rid, slot=r.slot,
                          step=self._steps)
        if self.governor is not None:
            # a detected fault is an unbounded-variance observation: the
            # governor escalates immediately, no window arithmetic
            self._apply_decision(self.governor.note_fault())
        # roll the flagged slots' cursors back to their pre-step values
        # (post-step length = pre-step + n_valid on both layouts)
        cur = np.array(self.pool.lengths())  # lengths() can be a read-only
        for r, _ in bad:                     # view of the device array
            cur[r.slot] -= int(nv[r.slot])
        self.pool.set_lengths(cur)
        # replay ONLY the flagged rows on the exact pack; same batch shape,
        # so the jit cache grows by at most one (params structure) entry
        rep_nv = np.zeros_like(nv)
        for r, _ in bad:
            rep_nv[r.slot] = nv[r.slot]
        rep_batch = ScheduledBatch(batch.kind, batch.tokens, rep_nv,
                                   [r for r, _ in bad], [k for _, k in bad])
        rep_params = (self._exact_params if self._exact_params is not None
                      else self.params)
        rep_logits, rep_cache = self._dispatch(rep_params, rep_batch, tables)
        self.pool.update(rep_cache)
        if self._paged:
            self.pool.advance(rep_nv)
        self.metrics.quarantines += len(bad)
        self.metrics.quarantine_replays += len(bad)
        finished, emitted, prompt_toks = self._postprocess(rep_batch,
                                                           rep_logits)
        for r, _ in bad:
            tok = r.generated[-1] if r.generated else None
            self.quarantine_log.append({"rid": r.rid, "slot": r.slot,
                                        "step": self._steps, "token": tok})
            if tr is not None:
                tr.record("quarantine", rid=r.rid, slot=r.slot,
                          step=self._steps, replayed=int(rep_nv[r.slot]))
        clean_nv = np.array(nv, copy=True)
        clean_nv[list(bad_slots)] = 0
        clean = ScheduledBatch(
            batch.kind, batch.tokens, clean_nv,
            [r for r in batch.rows if r.slot not in bad_slots],
            [k for r, k in zip(batch.rows, batch.row_kinds)
             if r.slot not in bad_slots])
        return clean, finished, emitted, prompt_toks

    def _dispatch(self, params, batch: ScheduledBatch, tables):
        """Run the jitted slot step under the given parameter set.

        The parameters are a traced argument, so draft and exact packs
        share one callable and the jit cache keys on
        (parameter structure, token shape)."""
        if self._paged:
            return self._step_fn(params, jnp.asarray(batch.tokens),
                                 self.pool.cache, jnp.asarray(batch.n_valid),
                                 jnp.asarray(tables))
        return self._step_fn(params, jnp.asarray(batch.tokens),
                             self.pool.cache, jnp.asarray(batch.n_valid))

    # -- speculative rounds (repro.serving.speculative) ----------------------

    def _speculative_step(self, rnd) -> list[Request]:
        """One draft-and-verify round.

        Draft: up to ``rnd.max_k`` thin calls with the APPROXIMATE
        parameters, each feeding the previous argmax; rollback to the
        pre-draft cursors (pure cursor move — the draft K/V is masked and
        then overwritten).  Verify: ONE chunk-shaped call with the exact
        parameters whose verify rows re-run ``[last-token, drafts]`` with
        ``n_valid = k_eff + 1``; prefill chunks and budget-exhausted
        decode rows ride the same call.  Emission takes each row's longest
        agreeing prefix plus the verifier's correction token — every
        emitted token is an exact-model output, so the stream stays
        bit-identical to plain exact decode — and the final cursors land
        on exactly the accepted history."""
        tr = self.tracer
        self.metrics.start_clock()
        ch = self.ecfg.prefill_chunk
        tables = None
        if self._paged:
            # ONE copy-on-write barrier covers the whole round: prompt
            # chunks, draft writes [L, L+k) and verify writes [L, L+k] all
            # land in blocks made uniquely owned here, so the tables stay
            # valid across every dispatch below (rollback is a cursor move
            # — it never frees or remaps a block)
            cow0 = self.pool.cow_copies if tr is not None else 0
            for r in rnd.prefilling:
                self.pool.ensure_writable(
                    r.slot, min(ch, r.prompt_len - r.prefilled))
            for row in rnd.spec_rows:
                self.pool.ensure_writable(row.req.slot, row.k_eff + 1)
            for r in rnd.plain:
                self.pool.ensure_writable(r.slot, 1)
            self.pool.flush_copies()
            if tr is not None and self.pool.cow_copies > cow0:
                tr.record("cow_copy", copies=self.pool.cow_copies - cow0)
            tables = self.pool.block_tables_array()
        base = self.pool.lengths()

        # -- draft phase: thin calls, APPROXIMATE parameters ----------------
        t_d0 = time.perf_counter()
        max_k = rnd.max_k
        for i in range(max_k):
            db = self.scheduler.draft_batch(rnd, i)
            logits, new_cache = self._dispatch(self.draft_params, db, tables)
            self.pool.update(new_cache)
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            speculative.record_drafts(rnd, i, toks)
        t_d1 = time.perf_counter()
        if max_k:
            # the draft K/V above each base cursor is approximate junk:
            # retreat the cursors (repro.models.lm.rollback_slots) and let
            # the verify call overwrite those positions with exact K/V
            self.pool.set_lengths(base)

        # -- verify phase: ONE chunk-shaped call, EXACT parameters ----------
        vb = self.scheduler.verify_batch(rnd)
        t_v0 = time.perf_counter()
        cache_before = self.pool.cache
        logits, new_cache = self._dispatch(self.params, vb, tables)
        self.pool.update(new_cache)
        t_v1 = time.perf_counter()

        (finished, emitted, prompt_toks,
         drafted, accepted) = self._spec_postprocess(rnd, vb, logits)

        # final cursors: base + chunk (prefill rows), base + 1 (plain
        # decode rows), base + emitted (verify rows — the device advanced
        # k_eff + 1; rejected or stop-truncated positions roll back, their
        # stale exact K/V masked until overwritten next round).  This
        # replaces the plain path's pool.advance and keeps the paged host
        # mirror in sync; released slots re-zero their cursor on acquire.
        final = base.copy()
        for r, kind in zip(vb.rows, vb.row_kinds):
            if kind != "verify":
                final[r.slot] = base[r.slot] + int(vb.n_valid[r.slot])
        for row in rnd.spec_rows:
            final[row.req.slot] = base[row.req.slot] + row.emitted
        self.pool.set_lengths(final)

        if tr is not None:
            for r, kind in zip(vb.rows, vb.row_kinds):
                if kind == "verify":
                    continue
                tr.record("prefill_chunk" if kind == "prefill"
                          else "decode_step", rid=r.rid, t=t_v0,
                          dur=t_v1 - t_v0, slot=r.slot,
                          n_valid=int(vb.n_valid[r.slot]))
            for row in rnd.spec_rows:
                tr.record("draft", rid=row.req.rid, t=t_d0,
                          dur=t_d1 - t_d0, slot=row.req.slot, k=row.k_eff)
                tr.record("verify", rid=row.req.rid, t=t_v0,
                          dur=t_v1 - t_v0, slot=row.req.slot,
                          drafted=row.k_eff, accepted=row.accepted,
                          emitted=row.emitted)
            for r in finished:
                tr.record("finished", rid=r.rid, reason=r.finish_reason,
                          generated=len(r.generated))
        self.metrics.record_step(
            "spec" if rnd.spec_rows else ("mixed" if rnd.plain else "prefill"),
            self.pool.occupancy, len(self.queue),
            prompt_tokens=prompt_toks, generated_tokens=emitted,
            block_stats=self._windowed_block_stats() if self._paged else None,
            drafted=drafted, accepted=accepted, draft_calls=max_k)
        self._steps += 1
        if (self._probe is not None
                and self._steps % self.ecfg.error_probe_every == 0):
            # the probe re-runs a verify-batch row against the exact path;
            # under speculation the serving params for that call ARE exact,
            # so it reports the (near-zero) noise floor — still useful as a
            # liveness check, documented in docs/serving.md
            self._run_probe(vb, cache_before, tables)
        return finished

    def _spec_postprocess(self, rnd, vb: ScheduledBatch,
                          logits) -> tuple[list[Request], int, int, int, int]:
        """Per-row advance for a speculative round's verify call.

        Prefill and plain-decode rows behave exactly as in
        :meth:`_postprocess`; verify rows run longest-agreeing-prefix
        acceptance and emit their candidates one at a time through the
        normal stop checks — eos/length can only fire on an EMITTED
        verifier token, never on a drafted-but-rejected one (a rejected
        draft that happens to equal ``eos_id`` must not finish the
        request).  Returns ``(finished, generated_tokens, prompt_tokens,
        drafted, accepted)``; the acceptance counters use the agreement
        length, independent of stop-condition truncation."""
        finished: list[Request] = []
        emitted = prompt_toks = drafted = accepted = 0
        # verify rows consume up to k_eff + 1 columns each, so take the
        # argmax over the full (slots, C, V) block once; every row kind
        # then reads from the same host array
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for r, kind in zip(vb.rows, vb.row_kinds):
            if kind == "prefill":
                n = int(vb.n_valid[r.slot])
                r.prefilled += n
                prompt_toks += n
                if self._paged:
                    self.pool.register_prefix(r.slot, r.prompt_len,
                                              r.prefilled)
                if r.prefilled < r.prompt_len:
                    if r.deadline_expired:
                        r.finish_reason = "deadline"
                        self.metrics.requests_deadline_expired += 1
                        finished.append(self._finish(r))
                    continue
                r.state = RequestState.DECODE
                self._emit_row(r, int(toks[r.slot, n - 1]), finished,
                               first=True)
                emitted += 1
            elif kind == "decode":
                self._emit_row(r, int(toks[r.slot, 0]), finished,
                               first=False)
                emitted += 1
        for row in rnd.spec_rows:
            r = row.req
            candidates = speculative.accept(row, toks[r.slot])
            drafted += row.k_eff
            accepted += row.accepted
            for tok in candidates:
                self._emit_row(r, tok, finished, first=False)
                row.emitted += 1
                emitted += 1
                if r.state == RequestState.FINISHED:
                    break  # accepted-but-past-stop candidates are dropped
        return finished, emitted, prompt_toks, drafted, accepted

    def _run_probe(self, batch: ScheduledBatch, cache_before,
                   tables) -> None:
        """One approximation-error probe against the batch the engine just
        served: the pre-step cache reference reproduces the row's forward
        (JAX arrays are immutable, so holding it is free).

        A dense-surface fault injector arms its thread-local hook around
        the probe's observe forward — a degraded MAC array corrupts what
        the probe measures, which is exactly how the governor sees it —
        and the report feeds the governor's running SLO estimate."""
        t0 = time.perf_counter()
        inj = self._injector
        if inj is not None and inj.spec.surface == "dense":
            log0 = len(inj.log)
            with inj.armed(self._steps):
                report = self._probe.run(self.params, batch.tokens,
                                         batch.n_valid, cache_before,
                                         block_tables=tables)
            self.metrics.faults_injected += len(inj.log) - log0
        else:
            report = self._probe.run(self.params, batch.tokens,
                                     batch.n_valid, cache_before,
                                     block_tables=tables)
        t1 = time.perf_counter()
        if report is None:
            return
        rid = next((r.rid for r in batch.rows if r.slot == report["row"]),
                   None)
        self.metrics.record_probe(report)
        if self.tracer is not None:
            # the span's duration is the eager probe forward's wall time:
            # the decode gap it opens inside the step loop is then
            # attributable to the probe instead of scheduler idle
            lvars = {p: st["var"] for p, st in report["layers"].items()}
            extra = {}
            if lvars:
                worst = max(lvars, key=lvars.get)
                extra = {"max_layer_err_var": lvars[worst],
                         "worst_layer": worst}
            self.tracer.record(
                "probe", rid=rid, t=t0, dur=t1 - t0,
                logits_err_var=report["logits"]["var"],
                logits_err_max_abs=report["logits"]["max_abs"],
                mean_layer_err_var=(sum(lvars.values()) / len(lvars)
                                    if lvars else 0.0), **extra)
        if self.governor is not None:
            self._apply_decision(self.governor.observe_probe(report))

    # -- governor execution (repro.serving.governor) -------------------------

    def _apply_decision(self, decision) -> None:
        """Execute one governor ladder move: hot-swap the live pack.

        Rung packs build lazily through ``pack_fn`` and cache per rung
        name, so an escalate/relax cycle packs each rung once.  The swap
        is a Python attribute assignment — the next dispatch traces the
        new parameter structure (one extra jit cache entry per rung, both
        batch shapes), every request's KV carries over untouched."""
        if decision is None:
            return
        rung = self.governor.rung
        pack = self._rung_packs.get(rung.name)
        if pack is None:
            pack = self._pack_fn(rung.spec)
            self._rung_packs[rung.name] = pack
        self.params = pack
        self.numerics = rung.name
        self.metrics.numerics = rung.name
        # the new rung's tokens attribute to ITS power profile from here on
        self._register_power_profile()
        self.metrics.governor_switches += 1
        if decision.action == "escalate":
            self.metrics.governor_escalations += 1
        else:
            self.metrics.governor_relaxes += 1
        if self.tracer is not None:
            self.tracer.record("governor_switch", step=self._steps,
                               **decision.to_dict())

    def _register_power_profile(self) -> None:
        """Profile the LIVE pack (cached per numerics label) and register
        it with the metrics power-attribution joiner."""
        label = self.numerics or "unknown"
        prof = self._power_profiles.get(label)
        if prof is None:
            prof = power_profile_from_params(self.params)
            self._power_profiles[label] = prof
        self.metrics.set_power_profile(label, prof)

    def _windowed_block_stats(self) -> dict:
        """Pool block stats with the cumulative counters rebased to the
        current metrics window, so one snapshot never mixes pool-lifetime
        numbers (cow_copies, prefix_evictions) with window-scoped ones."""
        stats = self.pool.block_stats()
        base = self._block_baseline
        return {**stats,
                "cow_copies": stats["cow_copies"] - base["cow_copies"],
                "prefix_evictions": (stats["prefix_evictions"]
                                     - base["prefix_evictions"])}

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Drive until idle (or ``max_steps``); returns finished requests."""
        finished: list[Request] = []
        steps = 0
        while not self.idle:
            finished.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return finished

    # -- replica handle ------------------------------------------------------
    # The surface a FleetRouter (repro.serving.fleet) drives a replica
    # through: submit / step / drain / load / snapshot / prefix sharing /
    # tracer.  Everything crossing it is plain Python data (token lists,
    # dicts, host numpy), so the same boundary could sit on a socket.

    def drain(self, max_steps: int | None = None) -> list[Request]:
        """Replica-handle verb for :meth:`run`: serve until idle."""
        return self.run(max_steps)

    def load(self) -> dict:
        """Routing-facing load signal: queue depth and slot pressure now,
        plus the replica's observed mean TTFT (None until one finishes).
        Cheap host bookkeeping only — the router polls this per submit."""
        backlog = self.scheduler.backlog(self.queue, self.active)
        ttfts = self.metrics.ttfts
        return {**backlog, "slots": self.ecfg.slots,
                "slots_free": self.pool.n_free,
                "ttft_mean_s": ttfts.mean if len(ttfts) else None}

    def snapshot(self) -> dict:
        """The metrics snapshot, as a plain dict (the handle boundary's
        observability payload; feeds ``EngineMetrics.merge``)."""
        return self.metrics.snapshot()

    def export_prefix(self) -> list[tuple[bytes, dict]]:
        """Export this replica's prefix-cache entries for adoption by a
        colder replica (paged layout; [] otherwise — nothing to share)."""
        if not self._paged:
            return []
        return self.pool.export_prefix_entries()

    def import_prefix(self, entries) -> int:
        """Adopt prefix entries exported by another replica; returns the
        number of blocks imported (0 on the contiguous layout)."""
        if not self._paged or not entries:
            return 0
        imported = self.pool.import_prefix_entries(entries)
        if imported:
            self.metrics.prefix_imports += imported
            if self.tracer is not None:
                self.tracer.record("prefix_import", blocks=imported)
        return imported

    def compile_count(self) -> int:
        """Number of shapes the jitted slot step has compiled for."""
        return self._step_fn._cache_size()

    def reset_metrics(self) -> None:
        """Fresh counters (e.g. after warmup) without losing the numerics
        label the engine was built with.  The paged pool's cumulative
        counters (COW copies, prefix evictions, peak blocks) are rebased
        so the next snapshot covers one consistent window."""
        self.metrics = EngineMetrics(
            numerics=self.numerics,
            kv_layout=self.ecfg.kv_layout,
            decode_specialized=self.metrics.decode_specialized,
            window_s=self.ecfg.metrics_window_s,
            speculative_k=self._spec_k,
            draft_numerics=self.draft_numerics if self._spec_k else None,
            shadow_numerics=(self._shadow.shadow_label
                             if self._shadow is not None else None))
        self._bridge_window_samples()
        for label, prof in self._power_profiles.items():
            self.metrics.set_power_profile(label, prof)
        if self._paged:
            self.pool.reset_peak_blocks()
            self._block_baseline = self.pool.block_stats()

    # -- postprocessing ------------------------------------------------------

    def _postprocess(self, batch: ScheduledBatch,
                     logits) -> tuple[list[Request], int, int]:
        """Unified per-row advance for every batch kind.

        Each row's next token lives at logits column ``n_valid[slot] - 1``
        (a decode row's single column, or a prompt chunk's last real
        column).  Decode rows always emit; prefill rows emit only on the
        chunk that completes their prompt.  Returns
        ``(finished, generated_tokens, prompt_tokens)`` — per-row
        attribution, so mixed batches account both kinds at once.
        """
        finished, emitted, prompt_toks = [], 0, 0
        emitting = any(
            kind == "decode"
            or r.prefilled + int(batch.n_valid[r.slot]) >= r.prompt_len
            for r, kind in zip(batch.rows, batch.row_kinds))
        toks = None
        if emitting:
            # gather each row's one needed column (n_valid-1) BEFORE the
            # argmax, then ship a (slots,) int array — not an argmax over
            # all C columns of (slots, C, V) in the hot serving loop
            cols = jnp.asarray(np.maximum(batch.n_valid - 1, 0))
            picked = jnp.take_along_axis(logits, cols[:, None, None], axis=1)
            toks = np.asarray(jnp.argmax(picked[:, 0], axis=-1))
        for r, kind in zip(batch.rows, batch.row_kinds):
            if kind == "prefill":
                n = int(batch.n_valid[r.slot])
                r.prefilled += n
                prompt_toks += n
                if self._paged:
                    # publish newly FULL prompt blocks as they fill, so
                    # concurrent requests share them before this one ends
                    self.pool.register_prefix(r.slot, r.prompt_len,
                                              r.prefilled)
                if r.prefilled < r.prompt_len:
                    if r.deadline_expired:
                        # blown mid-prompt: no first token can meet the
                        # SLO — stop before spending more prefill compute
                        r.finish_reason = "deadline"
                        self.metrics.requests_deadline_expired += 1
                        finished.append(self._finish(r))
                    continue
                # prompt complete: its last token's logits seed generation
                r.state = RequestState.DECODE
                self._emit_row(r, int(toks[r.slot]), finished, first=True)
            else:
                self._emit_row(r, int(toks[r.slot]), finished, first=False)
            emitted += 1
        return finished, emitted, prompt_toks

    def _emit_row(self, r: Request, tok: int, finished: list[Request],
                  first: bool) -> None:
        gap = r.emit(tok)
        if first:
            self.metrics.record_first_token(r)
        self.metrics.record_itl(gap)
        if self._done(r, tok):
            finished.append(self._finish(r))

    def _done(self, r: Request, tok: int) -> bool:
        """Stop check; records ``finish_reason`` at the moment it fires.
        Precedence: deadline > length > eos.  A blown deadline is the
        request's SLO verdict regardless of what the token says; within
        budget, the length stop takes precedence over an ``eos_id``
        coincidence on the budget's last step (as before)."""
        if r.deadline_expired:
            r.finish_reason = "deadline"
            self.metrics.requests_deadline_expired += 1
            return True
        if len(r.generated) >= r.max_new_tokens:
            r.finish_reason = "length"
            return True
        if r.eos_id is not None and tok == r.eos_id:
            r.finish_reason = "eos"
            return True
        return False

    def _finish(self, r: Request) -> Request:
        r.state = RequestState.FINISHED
        r.t_finish = time.time()
        self.pool.release(r.slot)
        del self.active[r.slot]
        self.metrics.record_finish(r)
        return r
