"""The continuous-batching serving engine.

:class:`ServingEngine` ties the pieces together: submit() runs admission
control and enqueues; step() admits into free slots, asks the scheduler for
one fixed-shape batch, runs the jitted slot step, and advances every
participating request (streaming tokens to callbacks as they decode).

The same engine serves float, exact-int8, and approximate+CV packed
parameters — numerics live entirely in the parameter representation
(``repro.launch.serve.build_serving_params``), not in the engine.  The
engine records which NumericsSpec produced its parameters (``numerics=``,
normally the spec's name) and surfaces it through the metrics snapshot so
a fleet's per-engine numerics are auditable from monitoring alone.

Generation is greedy (argmax), matching the sequential
``prefill``/``decode_step`` baseline token for token — the equivalence
contract tested by tests/test_serving_engine.py.
"""

from __future__ import annotations

import itertools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, EngineConfig
from repro.models import ModelApi, build_model
from repro.serving.kv_pool import SlotPool
from repro.serving.metrics import EngineMetrics
from repro.serving.request import (AdmissionController, Request, RequestQueue,
                                   RequestState)
from repro.serving.scheduler import ScheduledBatch, SlotScheduler


def _has_blocked_packs(params) -> bool:
    """True iff any packed leaf ships the offline-blocked Pallas layout
    (the only path the decode-specialized block picker applies to)."""
    from repro.core.approx_linear import QuantizedDense, QuantizedDenseGroup

    found = False

    def walk(node):
        nonlocal found
        if found:
            return
        if isinstance(node, (QuantizedDense, QuantizedDenseGroup)):
            found = found or node.blocked is not None
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return found


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig = EngineConfig(),
                 mesh=None, api: ModelApi | None = None,
                 numerics: str | None = None) -> None:
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.api = api or build_model(cfg)
        self.numerics = numerics  # active NumericsSpec name (None = unknown)
        self.pool = SlotPool(self.api, ecfg.slots, ecfg.max_len, ecfg.cache_dtype)
        self.queue = RequestQueue()
        self.admission = AdmissionController(ecfg.max_queue, ecfg.max_len,
                                             ecfg.prefill_chunk)
        self.scheduler = SlotScheduler(ecfg.slots, ecfg.prefill_chunk,
                                       ecfg.interleave)
        # decode steps are (slots, 1) token blocks: a slot count within the
        # kernel block picker's decode window means every continuous-decode
        # iteration runs the thin-M, single-K-step specialized tiles — but
        # only the Pallas blocked packs go through that picker, so the flag
        # is gated on the served parameters actually carrying blocked layouts
        from repro.kernels.ops import DECODE_M_MAX

        self.metrics = EngineMetrics(
            numerics=numerics,
            decode_specialized=(ecfg.slots <= DECODE_M_MAX
                                and _has_blocked_packs(params)))
        self.active: dict[int, Request] = {}
        self._rid = itertools.count()
        decode_slots = self.api.decode_slots
        # one jitted callable, two shapes ever: (slots, 1) and (slots, chunk)
        self._step_fn = jax.jit(
            lambda p, t, c, nv: decode_slots(p, t, c, nv, mesh=mesh))

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, priority: int = 0,
               eos_id: int | None = None,
               on_token: Callable | None = None) -> Request:
        """Admission-checked enqueue; returns the Request (maybe REJECTED)."""
        req = Request(rid=next(self._rid), prompt=[int(t) for t in prompt],
                      max_new_tokens=int(max_new_tokens), priority=priority,
                      eos_id=eos_id, on_token=on_token)
        self.metrics.submitted += 1
        ok, reason = self.admission.check(self.queue, req)
        if not ok:
            req.state = RequestState.REJECTED
            req.reject_reason = reason
            self.metrics.rejected += 1
            return req
        self.queue.push(req)
        return req

    # -- engine loop ---------------------------------------------------------

    @property
    def idle(self) -> bool:
        return not self.active and not len(self.queue)

    def step(self) -> list[Request]:
        """One engine iteration; returns requests that finished in it."""
        self.scheduler.admit(self.queue, self.pool, self.active)
        batch = self.scheduler.next_batch(self.active)
        if batch is None:
            return []
        logits, new_cache = self._step_fn(
            self.params, jnp.asarray(batch.tokens), self.pool.cache,
            jnp.asarray(batch.n_valid))
        self.pool.update(new_cache)
        finished, emitted = (self._post_prefill(batch, logits)
                             if batch.kind == "prefill"
                             else self._post_decode(batch, logits))
        self.metrics.record_step(
            batch.kind, self.pool.occupancy, len(self.queue),
            prompt_tokens=int(batch.n_valid.sum()) if batch.kind == "prefill" else 0,
            generated_tokens=emitted)
        return finished

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Drive until idle (or ``max_steps``); returns finished requests."""
        finished: list[Request] = []
        steps = 0
        while not self.idle:
            finished.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return finished

    def compile_count(self) -> int:
        """Number of shapes the jitted slot step has compiled for."""
        return self._step_fn._cache_size()

    def reset_metrics(self) -> None:
        """Fresh counters (e.g. after warmup) without losing the numerics
        label the engine was built with."""
        self.metrics = EngineMetrics(
            numerics=self.numerics,
            decode_specialized=self.metrics.decode_specialized)

    # -- postprocessing ------------------------------------------------------

    def _post_prefill(self, batch: ScheduledBatch,
                      logits) -> tuple[list[Request], int]:
        finished, emitted = [], 0
        completing = any(r.prefilled + batch.n_valid[r.slot] >= r.prompt_len
                         for r in batch.rows)
        # argmax on device: ship a (slots, C) int array, not (slots, C, V)
        toks = np.asarray(jnp.argmax(logits, -1)) if completing else None
        for r in batch.rows:
            n = int(batch.n_valid[r.slot])
            r.prefilled += n
            if r.prefilled >= r.prompt_len:
                # prompt complete: its last token's logits seed generation
                tok = int(toks[r.slot, n - 1])
                r.emit(tok)
                emitted += 1
                self.metrics.record_first_token(r)
                r.state = RequestState.DECODE
                if self._done(r, tok):
                    finished.append(self._finish(r))
        return finished, emitted

    def _post_decode(self, batch: ScheduledBatch,
                     logits) -> tuple[list[Request], int]:
        finished = []
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for r in batch.rows:
            tok = int(toks[r.slot])
            r.emit(tok)
            if self._done(r, tok):
                finished.append(self._finish(r))
        return finished, len(batch.rows)

    def _done(self, r: Request, tok: int) -> bool:
        return (len(r.generated) >= r.max_new_tokens
                or (r.eos_id is not None and tok == r.eos_id))

    def _finish(self, r: Request) -> Request:
        import time

        r.state = RequestState.FINISHED
        r.t_finish = time.time()
        self.pool.release(r.slot)
        del self.active[r.slot]
        self.metrics.record_finish(r)
        return r
