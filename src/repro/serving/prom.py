"""Prometheus / OpenMetrics text exposition for metrics snapshots.

Dependency-free writer + parser pair over the plain-dict snapshots
``EngineMetrics.snapshot()`` / ``EngineMetrics.merge`` produce (engine or
fleet — a merged fleet snapshot exports exactly the same way).  The
writer flattens:

  * every numeric top-level snapshot field into a gauge
    ``repro_<field>`` (bools as 0/1, Nones skipped);
  * per-layer error-probe moments into
    ``repro_probe_layer_err_var{layer="..."}`` (+ ``_n``) — the series a
    Grafana heatmap reads;
  * the power attribution into per-tier and per-layer
    ``repro_power_*`` series;
  * the A/B shadow section into ``repro_shadow_*``.

Caller-supplied labels (e.g. ``{"engine": "int8-tier"}``) ride on every
series, so one scrape target can expose a whole fleet.  The parser is
the writer's inverse over the subset it emits — enough for the
round-trip tests and for CI to assert an export actually carries data —
not a general OpenMetrics implementation.

Run as a module to assert on an exported file (the CI smoke hook)::

    python -m repro.serving.prom metrics.prom --require repro_generated_tokens
"""

from __future__ import annotations

import re

__all__ = ["to_openmetrics", "parse_openmetrics", "metric_value"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _escape(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _unescape(v: str) -> str:
    return (v.replace("\\n", "\n").replace('\\"', '"')
            .replace("\\\\", "\\"))


def _name(*parts: str) -> str:
    return _NAME_RE.sub("_", "_".join(p.strip("_") for p in parts if p))


def _fmt(name: str, labels: dict, value) -> str:
    if isinstance(value, bool):
        value = int(value)
    lab = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(labels.items()))
    body = f"{{{lab}}}" if lab else ""
    return f"{name}{body} {value}"


def to_openmetrics(snapshot: dict, prefix: str = "repro",
                   labels: dict | None = None) -> str:
    """Render one snapshot dict as OpenMetrics text exposition."""
    base = dict(labels or {})
    lines: list[str] = []
    seen_types: set[str] = set()

    def emit(name: str, value, extra: dict | None = None) -> None:
        if value is None or isinstance(value, str):
            return
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} gauge")
        lines.append(_fmt(name, {**base, **(extra or {})}, value))

    for key, value in snapshot.items():
        if isinstance(value, (dict, list)):
            continue
        emit(_name(prefix, key), value)
    probe = snapshot.get("error_probe") or {}
    for key in ("runs", "logits_err_n", "logits_err_mean", "logits_err_var",
                "mean_layer_err_var", "max_layer_err_var"):
        emit(_name(prefix, "probe", key), probe.get(key))
    for path, st in (probe.get("layers") or {}).items():
        emit(_name(prefix, "probe_layer_err_var"), st.get("err_var"),
             {"layer": path})
        emit(_name(prefix, "probe_layer_err_n"), st.get("n"),
             {"layer": path})
    shadow = snapshot.get("shadow") or {}
    for key in ("sampled_requests", "tokens", "token_matches",
                "token_match_rate", "logits_err_var", "logits_err_max_abs"):
        emit(_name(prefix, "shadow", key), shadow.get(key))
    power = snapshot.get("power_attribution") or {}
    for key in ("tokens_attributed", "mac_units", "mac_units_saved",
                "modeled_power_saving_pct"):
        emit(_name(prefix, "power", key), power.get(key))
    for tier, st in (power.get("per_tier") or {}).items():
        for key in ("tokens", "mac_units", "mac_units_saved",
                    "power_saving_pct"):
            emit(_name(prefix, "power_tier", key), st.get(key),
                 {"tier": tier})
    for path, st in (power.get("per_layer") or {}).items():
        for key in ("mac_units", "mac_units_saved", "saving_pct"):
            emit(_name(prefix, "power_layer", key), st.get(key),
                 {"layer": path})
    return "\n".join(lines) + "\n# EOF\n"


def parse_openmetrics(text: str) -> dict:
    """Parse exposition text back into ``{(name, labels...): value}``.

    Keys are ``(name, frozenset((label, value), ...))`` tuples; use
    :func:`metric_value` for ergonomic lookups."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line: {line!r}")
        name, labpart, value = m.groups()
        labels = frozenset((k, _unescape(v))
                           for k, v in _LABEL_RE.findall(labpart or ""))
        out[(name, labels)] = float(value)
    return out


def metric_value(parsed: dict, name: str, **labels):
    """Look up one series by name + label SUBSET (None when absent)."""
    want = set(labels.items())
    for (n, lab), v in parsed.items():
        if n == name and want <= set(lab):
            return v
    return None


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Parse an OpenMetrics export and assert on it "
                    "(CI hook for repro.serving.prom exports)")
    ap.add_argument("path", help="exposition file to parse")
    ap.add_argument("--require", nargs="*", default=[],
                    help="metric names that must be present")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        parsed = parse_openmetrics(f.read())
    names = {n for n, _ in parsed}
    missing = [n for n in args.require if n not in names]
    print(f"{args.path}: {len(parsed)} series, {len(names)} metric names")
    if missing:
        print(f"MISSING required metrics: {missing}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
