"""Slot scheduler: admission + fixed-shape batch construction.

Every engine iteration is one of two fixed shapes, so the jitted model step
compiles exactly twice and never again:

  * a PREFILL batch ``(slots, prefill_chunk)`` — the next chunk of every
    request still processing its prompt (several requests prefill in the
    same call);
  * a DECODE batch ``(slots, 1)`` — the last token of every decoding
    request.

Rows for idle/finished slots (and the padding tail of a short chunk) carry
``n_valid = 0`` and do not advance their cursor.

Fairness: admission is (priority, FIFO); when both prefill and decode work
exist the scheduler alternates strictly between the two batch kinds
(``interleave=True``), so a stream of long prompts cannot starve running
decodes and queued decodes cannot starve prompt processing.  Admission into
a freed slot happens before every batch, so a waiting request is picked up
at the first opportunity — together with FIFO order this bounds every
request's wait by the work admitted before it (no starvation).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.kv_pool import SlotPool
from repro.serving.request import Request, RequestQueue, RequestState


@dataclasses.dataclass
class ScheduledBatch:
    """One fixed-shape engine iteration."""

    kind: str  # "prefill" | "decode"
    tokens: np.ndarray  # (slots, C) int32
    n_valid: np.ndarray  # (slots,) int32
    rows: list[Request]  # participating requests (their .slot indexes rows)


class SlotScheduler:
    def __init__(self, slots: int, prefill_chunk: int,
                 interleave: bool = True) -> None:
        self.slots = slots
        self.prefill_chunk = prefill_chunk
        self.interleave = interleave
        self._prefill_turn = True  # alternation state when both kinds pend

    # -- admission -----------------------------------------------------------

    def admit(self, queue: RequestQueue, pool: SlotPool,
              active: dict[int, Request]) -> list[Request]:
        """Move queued requests into free slots (priority, then FIFO)."""
        admitted = []
        while len(queue) and pool.n_free:
            req = queue.pop()
            slot = pool.acquire(req.rid)
            assert slot is not None
            req.slot = slot
            req.state = RequestState.PREFILL
            active[slot] = req
            admitted.append(req)
        return admitted

    # -- batch construction --------------------------------------------------

    def next_batch(self, active: dict[int, Request]) -> ScheduledBatch | None:
        prefilling = [r for r in active.values()
                      if r.state == RequestState.PREFILL]
        decoding = [r for r in active.values()
                    if r.state == RequestState.DECODE]
        if not prefilling and not decoding:
            return None

        if prefilling and decoding:
            do_prefill = self._prefill_turn if self.interleave else True
            self._prefill_turn = not self._prefill_turn
        else:
            do_prefill = bool(prefilling)

        if do_prefill:
            return self._prefill_batch(prefilling)
        return self._decode_batch(decoding)

    def _prefill_batch(self, prefilling: list[Request]) -> ScheduledBatch:
        ch = self.prefill_chunk
        tokens = np.zeros((self.slots, ch), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        for r in prefilling:
            n = min(ch, r.prompt_len - r.prefilled)
            tokens[r.slot, :n] = r.prompt[r.prefilled : r.prefilled + n]
            n_valid[r.slot] = n
        return ScheduledBatch("prefill", tokens, n_valid, prefilling)

    def _decode_batch(self, decoding: list[Request]) -> ScheduledBatch:
        tokens = np.zeros((self.slots, 1), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        for r in decoding:
            tokens[r.slot, 0] = r.generated[-1]
            n_valid[r.slot] = 1
        return ScheduledBatch("decode", tokens, n_valid, decoding)
