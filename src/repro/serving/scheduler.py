"""Slot scheduler: admission + fixed-shape batch construction.

Every engine iteration is one of two fixed shapes, so the jitted model step
compiles exactly twice and never again:

  * a chunk-shaped batch ``(slots, prefill_chunk)`` — the next chunk of
    every request still processing its prompt, and (``mixed=True``, the
    default) every decoding request riding the same call with
    ``n_valid = 1``;
  * a DECODE batch ``(slots, 1)`` — the last token of every decoding
    request, used whenever no prefill work pends so the thin-M
    decode-specialized kernel tiles keep firing.

Rows for idle/finished slots (and the padding tail of a short chunk) carry
``n_valid = 0`` and do not advance their cursor.  ``ScheduledBatch.row_kinds``
records, per participating request, whether its row is a prompt chunk
("prefill") or a single generated token ("decode") — the engine's unified
postprocess and per-row metrics attribution key off it.

Fairness: admission is (priority, FIFO).  With ``mixed=True`` a running
decode advances on EVERY iteration, so a stream of long prompts cannot
stall it at all (the historical decode stall).  With ``mixed=False`` the
scheduler falls back to strict whole-batch alternation between the two
kinds (``interleave=True``), which bounds — but does not remove — the
stall at one chunk call per decode token.  Admission into a freed slot
happens before every batch, so a waiting request is picked up at the first
opportunity — together with FIFO order this bounds every request's wait by
the work admitted before it (no starvation).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.kv_pool import SlotPool
from repro.serving.request import Request, RequestQueue, RequestState


@dataclasses.dataclass
class ScheduledBatch:
    """One fixed-shape engine iteration."""

    #: "prefill" | "decode" | "mixed" (chunk-shaped, both row kinds) |
    #: "draft" (thin speculative draft call) | "spec" (chunk-shaped verify)
    kind: str
    tokens: np.ndarray  # (slots, C) int32
    n_valid: np.ndarray  # (slots,) int32
    rows: list[Request]  # participating requests (their .slot indexes rows)
    #: per entry of ``rows``: "prefill" | "decode" | "verify" (a decoding
    #: request's [last-token, drafts...] row inside a speculative verify
    #: call — n_valid = k_eff + 1 instead of a decode row's 1)
    row_kinds: list[str]


class SlotScheduler:
    def __init__(self, slots: int, prefill_chunk: int,
                 interleave: bool = True, mixed: bool = True) -> None:
        self.slots = slots
        self.prefill_chunk = prefill_chunk
        self.interleave = interleave
        self.mixed = mixed
        self._prefill_turn = True  # alternation state when both kinds pend

    # -- admission -----------------------------------------------------------

    def purge_expired(self, queue: RequestQueue, metrics=None,
                      tracer=None) -> list[Request]:
        """Evict queued requests whose deadline already passed.

        They are terminal (``finish_reason = "deadline"``) without ever
        touching a slot — admitting a request that cannot possibly answer
        inside its latency budget only wastes prefill compute.  The engine
        calls this before every admission pass and returns the expired
        requests from ``step()`` so pollers observe them finishing.
        """
        import time

        expired = queue.purge(lambda r: r.deadline_expired)
        for r in expired:
            r.state = RequestState.FINISHED
            r.finish_reason = "deadline"
            r.t_finish = time.time()
            if metrics is not None:
                metrics.requests_deadline_expired += 1
            if tracer is not None:
                tracer.record("evicted", rid=r.rid, reason="deadline",
                              deadline_ms=r.deadline_ms)
        return expired

    def admit(self, queue: RequestQueue, pool: SlotPool,
              active: dict[int, Request], metrics=None,
              tracer=None) -> list[Request]:
        """Move queued requests into free slots (priority, then FIFO).

        Placement can fail on CAPACITY, not just on slots: the paged pool
        admits only when every block the request can need is reservable.
        The head request is therefore peeked, placed, and only then popped
        — on failure it keeps its queue position and the iteration is
        counted as a ``no_capacity_stalls`` sample (distinct from
        queue-full rejection, which drops work; a stall only delays it).

        A prefix-cache hit comes back with ``req.prefix_hit_tokens`` set
        and the slot cursor pre-advanced; the request enters chunked
        prefill with that much of its prompt already marked done (at least
        one token always remains, to produce its first-token logits).

        ``tracer`` (a :class:`repro.serving.telemetry.SpanTracer`) gets an
        ``admitted`` span per placement (with the request's queue wait) and
        a ``capacity_stall`` span per stalled iteration.
        """
        import time

        admitted = []
        stalled = False
        while len(queue):
            if not pool.n_free:
                stalled = True
                break
            req = queue.peek()
            slot = pool.acquire_for(req)
            if slot is None:
                stalled = True
                break
            queue.pop()
            req.slot = slot
            req.prefilled = req.prefix_hit_tokens
            req.state = RequestState.PREFILL
            active[slot] = req
            admitted.append(req)
            if tracer is not None:
                tracer.record(
                    "admitted", rid=req.rid, slot=slot,
                    queue_wait_s=round(
                        time.perf_counter() - req.t_queued_mono, 6))
        if stalled:
            if metrics is not None:
                metrics.no_capacity_stalls += 1
            if tracer is not None:
                head = queue.peek()
                tracer.record("capacity_stall",
                              rid=head.rid if head else None,
                              queued=len(queue))
        return admitted

    # -- load accounting -----------------------------------------------------

    def backlog(self, queue: RequestQueue,
                active: dict[int, Request]) -> dict:
        """Work pending on this scheduler, split by phase — the fleet
        router's load-balancing signal.  ``queued`` is admission backlog,
        ``prefilling``/``decoding`` are slot-resident; their sum is the
        number of requests that must finish before a new submit drains."""
        prefilling = sum(r.state == RequestState.PREFILL
                         for r in active.values())
        decoding = sum(r.state == RequestState.DECODE
                       for r in active.values())
        return {"queued": len(queue), "prefilling": prefilling,
                "decoding": decoding,
                "pending": len(queue) + prefilling + decoding}

    # -- batch construction --------------------------------------------------

    def next_batch(self, active: dict[int, Request]) -> ScheduledBatch | None:
        prefilling = [r for r in active.values()
                      if r.state == RequestState.PREFILL]
        decoding = [r for r in active.values()
                    if r.state == RequestState.DECODE]
        if not prefilling and not decoding:
            return None

        if prefilling and decoding:
            if self.mixed:
                return self._chunk_batch(prefilling, decoding)
            do_prefill = self._prefill_turn if self.interleave else True
            self._prefill_turn = not self._prefill_turn
        else:
            do_prefill = bool(prefilling)

        if do_prefill:
            return self._chunk_batch(prefilling, [])
        return self._decode_batch(decoding)

    def _chunk_batch(self, prefilling: list[Request],
                     decoding: list[Request]) -> ScheduledBatch:
        """Chunk-shaped ``(slots, prefill_chunk)`` batch: prompt chunks plus
        (mixed mode) decode rows with ``n_valid = 1``."""
        ch = self.prefill_chunk
        tokens = np.zeros((self.slots, ch), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        for r in prefilling:
            n = min(ch, r.prompt_len - r.prefilled)
            tokens[r.slot, :n] = r.prompt[r.prefilled : r.prefilled + n]
            n_valid[r.slot] = n
        for r in decoding:
            tokens[r.slot, 0] = r.generated[-1]
            n_valid[r.slot] = 1
        kind = "mixed" if decoding else "prefill"
        return ScheduledBatch(kind, tokens, n_valid, prefilling + decoding,
                              ["prefill"] * len(prefilling)
                              + ["decode"] * len(decoding))

    def _decode_batch(self, decoding: list[Request]) -> ScheduledBatch:
        tokens = np.zeros((self.slots, 1), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        for r in decoding:
            tokens[r.slot, 0] = r.generated[-1]
            n_valid[r.slot] = 1
        return ScheduledBatch("decode", tokens, n_valid, decoding,
                              ["decode"] * len(decoding))

    # -- speculative batches (repro.serving.speculative) ---------------------

    def draft_batch(self, rnd, i: int) -> ScheduledBatch:
        """Thin ``(slots, 1)`` draft call ``i`` of a speculative round.

        Only spec rows still inside their ``k_eff`` participate; everyone
        else (prefill, plain-decode, idle) is ``n_valid = 0`` padding.  The
        engine runs these with the DRAFT parameters, so the jit cache entry
        is (draft structure, thin shape) — the same thin shape slot plain
        decode would have used, never a third one."""
        from repro.serving.speculative import draft_inputs

        tokens, n_valid = draft_inputs(rnd, self.slots, i)
        rows = [row.req for row in rnd.spec_rows if i < row.k_eff]
        return ScheduledBatch("draft", tokens, n_valid, rows,
                              ["draft"] * len(rows))

    def verify_batch(self, rnd) -> ScheduledBatch:
        """The speculative round's single chunk-shaped exact call.

        Three row kinds share the ``(slots, prefill_chunk)`` shape: prompt
        chunks ("prefill", exactly as in :meth:`_chunk_batch`), verify rows
        carrying ``[last-token, d_1..d_k]`` with ``n_valid = k_eff + 1``
        ("verify" — k+1 greedy verdicts in one dispatch, riding the same
        mixed-batch machinery that lets decode rows share chunk calls), and
        budget-exhausted decoders as ordinary ``n_valid = 1`` rows
        ("decode").  Keeping the latter chunk-shaped is what preserves the
        two-compiled-shapes invariant under speculation: the exact
        parameters never see the thin shape."""
        ch = self.prefill_chunk
        tokens = np.zeros((self.slots, ch), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        rows: list[Request] = []
        kinds: list[str] = []
        for r in rnd.prefilling:
            n = min(ch, r.prompt_len - r.prefilled)
            tokens[r.slot, :n] = r.prompt[r.prefilled : r.prefilled + n]
            n_valid[r.slot] = n
            rows.append(r)
            kinds.append("prefill")
        for row in rnd.spec_rows:
            r = row.req
            seq = [r.generated[-1]] + row.drafts
            tokens[r.slot, :len(seq)] = seq
            n_valid[r.slot] = len(seq)
            rows.append(r)
            kinds.append("verify")
        for r in rnd.plain:
            tokens[r.slot, 0] = r.generated[-1]
            n_valid[r.slot] = 1
            rows.append(r)
            kinds.append("decode")
        return ScheduledBatch("spec", tokens, n_valid, rows, kinds)
