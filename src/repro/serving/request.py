"""Request lifecycle for the continuous-batching engine.

A :class:`Request` carries one generation job through the state machine

    QUEUED -> PREFILL -> DECODE -> FINISHED
       \\-> REJECTED (admission control)

:class:`RequestQueue` orders admission by (priority, arrival): lower
``priority`` values run first, FIFO within a priority class.
:class:`AdmissionController` bounds queue depth and rejects jobs that can
never fit a slot, so the engine fails fast instead of deadlocking a slot.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import time
from typing import Callable


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    """One generation job and its per-request serving telemetry."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    priority: int = 0  # lower = more urgent; FIFO within a class
    eos_id: int | None = None
    #: streaming hook, called as on_token(request, token) per generated token
    on_token: Callable | None = None

    state: RequestState = RequestState.QUEUED
    reject_reason: str | None = None
    slot: int | None = None
    prefilled: int = 0  # prompt tokens already processed (chunked prefill)
    generated: list[int] = dataclasses.field(default_factory=list)

    t_submit: float = dataclasses.field(default_factory=time.time)
    t_first_token: float | None = None
    t_finish: float | None = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def finished(self) -> bool:
        return self.state == RequestState.FINISHED

    @property
    def finish_reason(self) -> str | None:
        if not self.finished:
            return None
        if self.eos_id is not None and self.generated and \
                self.generated[-1] == self.eos_id:
            return "eos"
        return "length"

    @property
    def ttft(self) -> float | None:
        """Time to first token (seconds from submit)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    def emit(self, token: int) -> None:
        if self.t_first_token is None:
            self.t_first_token = time.time()
        self.generated.append(token)
        if self.on_token is not None:
            self.on_token(self, token)


class RequestQueue:
    """Priority queue with FIFO order inside each priority class."""

    def __init__(self) -> None:
        self._heap: list[tuple[int, int, Request]] = []
        self._seq = itertools.count()

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.priority, next(self._seq), req))

    def pop(self) -> Request | None:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request | None:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


class AdmissionController:
    """Bounds queue depth and rejects jobs that cannot fit a slot.

    ``max_len`` is the per-slot KV capacity; a prompt must fit when rounded
    up to whole prefill chunks (chunk writes are fixed-shape) AND leave room
    for its generation budget, otherwise the job would stall a slot forever.
    """

    def __init__(self, max_queue: int, max_len: int, prefill_chunk: int) -> None:
        self.max_queue = max_queue
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk

    def check(self, queue: RequestQueue, req: Request) -> tuple[bool, str | None]:
        if req.prompt_len == 0:
            return False, "empty prompt"
        if req.max_new_tokens < 1:
            return False, "max_new_tokens must be >= 1"
        if len(queue) >= self.max_queue:
            return False, f"queue full ({self.max_queue})"
        ch = self.prefill_chunk
        padded = ((req.prompt_len + ch - 1) // ch) * ch
        if padded > self.max_len:
            return False, (f"prompt of {req.prompt_len} (padded {padded}) "
                           f"exceeds slot capacity {self.max_len}")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            return False, (f"prompt+generation {req.prompt_len}+"
                           f"{req.max_new_tokens} exceeds slot capacity "
                           f"{self.max_len}")
        return True, None
