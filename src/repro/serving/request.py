"""Request lifecycle for the continuous-batching engine.

A :class:`Request` carries one generation job through the state machine

    QUEUED -> PREFILL -> DECODE -> FINISHED
       \\-> REJECTED (admission control)

:class:`RequestQueue` orders admission by (priority, arrival): lower
``priority`` values run first, FIFO within a priority class.
:class:`AdmissionController` bounds queue depth and rejects jobs that can
never fit a slot, so the engine fails fast instead of deadlocking a slot.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import time
from typing import Callable


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    REJECTED = "rejected"


@dataclasses.dataclass
class Request:
    """One generation job and its per-request serving telemetry."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    priority: int = 0  # lower = more urgent; FIFO within a class
    eos_id: int | None = None
    #: per-request latency SLO: wall-clock budget in ms from submission.
    #: None = no deadline.  Expired QUEUED requests are purged before
    #: admission (finish_reason "deadline", never served); running
    #: requests stop at the first emission/prefill boundary past the
    #: budget (partial output kept).
    deadline_ms: float | None = None
    #: streaming hook, called as on_token(request, token) per generated token
    on_token: Callable | None = None

    state: RequestState = RequestState.QUEUED
    reject_reason: str | None = None
    #: prompt tokens whose prefill was skipped by attaching to prefix-cache
    #: blocks at admission (paged KV layout); the cursor starts here
    prefix_hit_tokens: int = 0
    #: memoized sha256 block-hash chain of the prompt (paged layout) — a
    #: capacity-stalled admission retries every engine step and must not
    #: rehash the prompt each time; filled lazily by PagedKVPool
    block_hashes: list | None = dataclasses.field(default=None, repr=False)
    #: recorded by the engine at the moment the stop condition fires
    #: ("length" | "eos"); None while running.  Recorded — not re-derived
    #: from the token tail — because a length-stopped generation whose last
    #: greedy token merely coincides with ``eos_id`` is still a length stop.
    finish_reason: str | None = None
    slot: int | None = None
    prefilled: int = 0  # prompt tokens already processed (chunked prefill)
    generated: list[int] = dataclasses.field(default_factory=list)

    t_submit: float = dataclasses.field(default_factory=time.time)
    #: monotonic (perf_counter) submission stamp for span tracing — queue
    #: waits and step durations must not jump with wall-clock adjustments
    t_queued_mono: float = dataclasses.field(
        default_factory=time.perf_counter, repr=False)
    t_first_token: float | None = None
    t_last_token: float | None = None
    t_finish: float | None = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def finished(self) -> bool:
        return self.state == RequestState.FINISHED

    @property
    def deadline_expired(self) -> bool:
        return (self.deadline_ms is not None
                and (time.time() - self.t_submit) * 1000.0 > self.deadline_ms)

    @property
    def ttft(self) -> float | None:
        """Time to first token (seconds from submit)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    def emit(self, token: int) -> float | None:
        """Record one generated token; returns the inter-token gap in
        seconds (None for the first token) for stall accounting."""
        now = time.time()
        gap = None if self.t_last_token is None else now - self.t_last_token
        if self.t_first_token is None:
            self.t_first_token = now
        self.t_last_token = now
        self.generated.append(token)
        if self.on_token is not None:
            self.on_token(self, token)
        return gap


class RequestQueue:
    """Priority queue with FIFO order inside each priority class."""

    def __init__(self) -> None:
        self._heap: list[tuple[int, int, Request]] = []
        self._seq = itertools.count()

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.priority, next(self._seq), req))

    def pop(self) -> Request | None:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request | None:
        return self._heap[0][2] if self._heap else None

    def lowest_priority(self) -> int | None:
        """Worst (numerically largest) priority value currently queued."""
        return max(pr for pr, _, _ in self._heap) if self._heap else None

    def evict_lowest(self) -> Request | None:
        """Remove and return the worst queued request: the lowest priority
        class, latest arrival within it (evicting the newest lowest-priority
        job preserves FIFO fairness among its peers)."""
        if not self._heap:
            return None
        i = max(range(len(self._heap)),
                key=lambda j: (self._heap[j][0], self._heap[j][1]))
        req = self._heap[i][2]
        self._heap[i] = self._heap[-1]
        self._heap.pop()
        heapq.heapify(self._heap)
        return req

    def purge(self, pred) -> list[Request]:
        """Remove and return every queued request satisfying ``pred``,
        preserving (priority, FIFO) order among the survivors.  Used for
        deadline expiry: an expired request must not consume a slot."""
        flagged = [bool(pred(e[2])) for e in self._heap]  # evaluate ONCE:
        # a time-based predicate must not flip between the two passes
        if not any(flagged):
            return []
        gone = [e[2] for e, f in zip(self._heap, flagged) if f]
        self._heap = [e for e, f in zip(self._heap, flagged) if not f]
        heapq.heapify(self._heap)
        return gone

    def __len__(self) -> int:
        return len(self._heap)


class AdmissionController:
    """Bounds queue depth and rejects jobs that cannot fit a slot.

    ``max_len`` is the per-slot KV capacity; a prompt must fit when rounded
    up to whole prefill chunks (chunk writes are fixed-shape) AND leave room
    for its generation budget, otherwise the job would stall a slot forever.
    Under the paged KV layout (``kv_block_size``/``kv_blocks`` set) the
    job's worst-case block need must also fit the WHOLE pool — a request
    needing more blocks than exist could never be placed, and leaving it
    queued would wedge the engine behind an eternal capacity stall.
    """

    def __init__(self, max_queue: int, max_len: int, prefill_chunk: int,
                 kv_block_size: int | None = None,
                 kv_blocks: int | None = None) -> None:
        self.max_queue = max_queue
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.kv_block_size = kv_block_size
        self.kv_blocks = kv_blocks

    def check(self, queue: RequestQueue, req: Request) -> tuple[bool, str | None]:
        """Pure admission predicate (no queue mutation).

        A full queue rejects the newcomer only when nothing queued has
        strictly lower priority; otherwise :meth:`admit` makes room by
        evicting the worst queued request — a priority-0 job must never be
        dropped in favour of already-queued best-effort work.
        """
        if req.prompt_len == 0:
            return False, "empty prompt"
        if req.max_new_tokens < 1:
            return False, "max_new_tokens must be >= 1"
        if len(queue) >= self.max_queue:
            worst = queue.lowest_priority()
            if worst is None or worst <= req.priority:
                return False, f"queue full ({self.max_queue})"
        ch = self.prefill_chunk
        padded = ((req.prompt_len + ch - 1) // ch) * ch
        if padded > self.max_len:
            return False, (f"prompt of {req.prompt_len} (padded {padded}) "
                           f"exceeds slot capacity {self.max_len}")
        if req.prompt_len + req.max_new_tokens > self.max_len:
            return False, (f"prompt+generation {req.prompt_len}+"
                           f"{req.max_new_tokens} exceeds slot capacity "
                           f"{self.max_len}")
        if self.kv_blocks is not None:
            bs = self.kv_block_size
            need = (req.prompt_len + req.max_new_tokens + bs - 1) // bs
            if need > self.kv_blocks:
                return False, (f"needs {need} KV blocks; the pool holds "
                               f"{self.kv_blocks}")
        return True, None

    def admit(self, queue: RequestQueue,
              req: Request) -> tuple[bool, str | None, Request | None]:
        """:meth:`check` plus queue-full eviction.

        Returns ``(ok, reason, evicted)``.  When the queue is at capacity
        but holds strictly lower-priority work, the worst queued request is
        removed and returned so the caller can re-reject it (and account
        for the eviction); the newcomer is admitted in its place.
        """
        ok, reason = self.check(queue, req)
        if not ok:
            return False, reason, None
        evicted = None
        if len(queue) >= self.max_queue:
            evicted = queue.evict_lowest()
        return True, None, evicted
