"""Accuracy-SLO numerics governor: adaptive approximation under a live
error budget.

The paper's deployment premise is that approximate multipliers stay
inside a bounded accuracy cost — but the deployed CV constants are
calibration-time, and a drifting MAC array (or a miscalibrated spec) can
silently blow the budget at serving time.  The governor turns the PR 6
error probe into an *enforced* SLO:

  * every probe report's logits moments fold into the current **window**
    (a fixed count of probe runs, so windows are deterministic and
    layout-independent);
  * closed windows Chan-merge (:func:`repro.serving.metrics._merge_moments`)
    into a bounded history — the **running variance estimate** the SLO is
    checked against, exactly the fleet-merge arithmetic applied in time
    instead of across engines;
  * a breach **escalates** one rung up the degradation ladder
    (:mod:`repro.numerics.ladder`; e.g. perforated-m2-cv -> int8 ->
    float), a detected fault (NaN = unbounded variance) escalates
    immediately without waiting for the window;
  * after ``clean_windows_to_relax`` consecutive windows comfortably
    under the SLO the governor **relaxes** one rung back down to
    re-harvest power.

The governor itself is engine-agnostic pure bookkeeping: it consumes
probe reports and returns :class:`GovernorDecision`\\ s; the engine
executes them by hot-swapping the live pack (``apply_numerics`` of the
rung's spec) and records a ``governor_switch`` span carrying the
cost-model power delta.  History resets on every switch — the estimate
must describe the *current* rung, not a mixture of regimes.
"""

from __future__ import annotations

import collections
import dataclasses
import fnmatch

from repro.numerics.ladder import LadderRung
from repro.serving.metrics import _merge_moments, merge_layer_moments

__all__ = ["GovernorConfig", "GovernorDecision", "NumericsGovernor"]


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Accuracy-SLO policy knobs.

    ``slo_err_var``   — max acceptable running logits err-var (the probe's
                        approx-vs-exact delta variance).
    ``window_probes`` — probe reports per governor window (count-based, so
                        window boundaries are deterministic).
    ``history_windows`` — closed windows Chan-merged into the running
                        estimate (bounded; resets on every switch).
    ``clean_windows_to_relax`` — consecutive clean windows required before
                        stepping back down the ladder.
    ``relax_headroom`` — a window only counts as *clean* when its running
                        estimate is under ``relax_headroom * slo_err_var``
                        (hysteresis: relaxing at 0.99x the SLO would
                        oscillate).
    ``severe_factor`` — a breach with running err-var >=
                        ``severe_factor * slo_err_var`` is *severe*: the
                        governor jumps directly to the first rung whose
                        modeled residual clears the SLO instead of walking
                        one rung per window (each intermediate rung would
                        burn a full window while the SLO stays blown).
                        None (the default) keeps the one-rung walk.
    ``layer_slo``     — opt-in per-layer ceilings: fnmatch patterns over
                        probe layer paths (e.g. ``"blocks/3/*"``) mapped
                        to max acceptable per-layer err-var.  A breach on
                        any watched layer escalates with the breaching
                        layer NAMED in the decision (``reason
                        "layer_slo_breach"``), catching a single
                        mis-specced layer before it dilutes into the
                        logits-level SLO.  Accepts a dict at construction;
                        normalized to a sorted tuple of (pattern, ceiling)
                        pairs so the config stays hashable.  First
                        matching pattern wins per layer.
    """

    slo_err_var: float
    window_probes: int = 4
    history_windows: int = 8
    clean_windows_to_relax: int = 3
    relax_headroom: float = 0.25
    severe_factor: float | None = None
    layer_slo: tuple = ()

    def __post_init__(self) -> None:
        if isinstance(self.layer_slo, dict):
            object.__setattr__(self, "layer_slo",
                               tuple(sorted(self.layer_slo.items())))
        else:
            object.__setattr__(self, "layer_slo",
                               tuple(tuple(p) for p in self.layer_slo))
        for pat, ceiling in self.layer_slo:
            if not pat:
                raise ValueError("layer_slo pattern must be non-empty")
            if ceiling <= 0:
                raise ValueError(f"layer_slo ceiling for {pat!r} must be "
                                 f"> 0, got {ceiling}")
        if self.slo_err_var <= 0:
            raise ValueError(
                f"slo_err_var must be > 0, got {self.slo_err_var}")
        if self.window_probes < 1:
            raise ValueError(
                f"window_probes must be >= 1, got {self.window_probes}")
        if self.clean_windows_to_relax < 1:
            raise ValueError("clean_windows_to_relax must be >= 1, got "
                             f"{self.clean_windows_to_relax}")
        if not 0 < self.relax_headroom <= 1:
            raise ValueError("relax_headroom must be in (0, 1], got "
                             f"{self.relax_headroom}")
        if self.severe_factor is not None and self.severe_factor < 1:
            raise ValueError("severe_factor must be >= 1 (a severe breach "
                             f"is at least a breach), got "
                             f"{self.severe_factor}")


@dataclasses.dataclass(frozen=True)
class GovernorDecision:
    """One ladder move for the engine to execute (pack hot-swap)."""

    action: str  # "escalate" | "relax"
    reason: str  # "slo_breach" | "layer_slo_breach" | "fault"
    #          #   | "clean_windows"
    rung_from: LadderRung
    rung_to: LadderRung
    window: int  # windows closed when the decision fired
    err_var: float | None  # running estimate that drove it (None: fault)
    #: the breaching layer path for reason "layer_slo_breach" (its
    #: per-layer estimate is then what ``err_var`` carries); None for
    #: logits-level decisions
    layer: str | None = None

    @property
    def power_delta_pct(self) -> float:
        """Modeled MAC-array power-saving change: negative = the switch
        SPENDS power (escalation), positive = re-harvests it (relax)."""
        return round(self.rung_to.power_saving_pct
                     - self.rung_from.power_saving_pct, 2)

    def to_dict(self) -> dict:
        d = {"action": self.action, "reason": self.reason,
             "from": self.rung_from.name, "to": self.rung_to.name,
             "window": self.window, "err_var": self.err_var,
             "power_delta_pct": self.power_delta_pct}
        if self.layer is not None:
            d["layer"] = self.layer
        return d


class NumericsGovernor:
    """Pure SLO bookkeeping over probe reports for one engine."""

    def __init__(self, ladder: list[LadderRung], cfg: GovernorConfig,
                 start: int = 0) -> None:
        if len(ladder) < 2:
            raise ValueError("governor needs a ladder of >= 2 rungs")
        if not 0 <= start < len(ladder):
            raise ValueError(f"start rung {start} outside ladder of "
                             f"{len(ladder)}")
        self.ladder = list(ladder)
        self.cfg = cfg
        self.rung_idx = start
        self.windows_closed = 0
        self.first_breach_window: int | None = None
        self.decisions: list[GovernorDecision] = []
        self._history: collections.deque = collections.deque(
            maxlen=cfg.history_windows)
        self._win: tuple[int, float, float] = (0, 0.0, 0.0)
        self._win_probes = 0
        self._clean = 0
        # per-layer mirrors of the window/history state, populated only
        # when layer SLOs are configured (layer folding is otherwise
        # skipped so the unwatched path stays exactly as cheap)
        self._layer_history: collections.deque = collections.deque(
            maxlen=cfg.history_windows)
        self._layer_win: dict = {}

    @property
    def rung(self) -> LadderRung:
        return self.ladder[self.rung_idx]

    @property
    def err_var_estimate(self) -> float | None:
        """Running logits err-var over history + the open window (None
        until any probe sample exists)."""
        est = (0, 0.0, 0.0)
        for m in self._history:
            est = _merge_moments(est, m)
        est = _merge_moments(est, self._win)
        return est[2] if est[0] else None

    @property
    def layer_err_estimates(self) -> dict:
        """Running per-layer ``(n, mean, var)`` over history + the open
        window (empty unless ``layer_slo`` is configured)."""
        return merge_layer_moments(*self._layer_history, self._layer_win)

    def _layer_ceiling(self, path: str) -> float | None:
        for pat, ceiling in self.cfg.layer_slo:
            if fnmatch.fnmatch(path, pat):
                return ceiling
        return None

    # -- inputs --------------------------------------------------------------

    def observe_probe(self, report: dict) -> GovernorDecision | None:
        """Fold one error-probe report; returns a decision when it closes
        a window that demands a switch.  Reports without logits moments
        (or with n=0 — a zero-sample window) are exact no-ops."""
        lg = (report or {}).get("logits")
        if lg is None or not lg.get("n"):
            return None
        self._win = _merge_moments(
            self._win, (lg["n"], lg["mean"], lg["var"]))
        if self.cfg.layer_slo:
            self._layer_win = merge_layer_moments(
                self._layer_win,
                {path: (st["n"], st["mean"], st["var"])
                 for path, st in (report.get("layers") or {}).items()
                 if st.get("n")})
        self._win_probes += 1
        if self._win_probes < self.cfg.window_probes:
            return None
        return self._close_window()

    def note_fault(self) -> GovernorDecision | None:
        """A detected NaN/divergence fault: unbounded error variance —
        escalate immediately, no window arithmetic."""
        if self.first_breach_window is None:
            self.first_breach_window = self.windows_closed
        return self._switch("escalate", "fault", err_var=None)

    # -- internals -----------------------------------------------------------

    def _close_window(self) -> GovernorDecision | None:
        est = self.err_var_estimate
        layer_ests = (self.layer_err_estimates if self.cfg.layer_slo
                      else {})
        self._history.append(self._win)
        self._win = (0, 0.0, 0.0)
        if self.cfg.layer_slo:
            self._layer_history.append(self._layer_win)
            self._layer_win = {}
        self._win_probes = 0
        self.windows_closed += 1
        if est is None:
            return None
        # per-layer SLOs check FIRST: a single blown layer usually drags
        # the logits estimate over the global SLO too, and the per-layer
        # decision is the one that NAMES the culprit
        worst: tuple[float, str, float] | None = None  # (ratio, path, var)
        layers_clean = True
        for path, (n, _, var) in layer_ests.items():
            ceiling = self._layer_ceiling(path)
            if ceiling is None or not n:
                continue
            if var > ceiling:
                ratio = var / ceiling
                if worst is None or ratio > worst[0]:
                    worst = (ratio, path, var)
            if var > self.cfg.relax_headroom * ceiling:
                layers_clean = False
        if worst is not None:
            if self.first_breach_window is None:
                self.first_breach_window = self.windows_closed - 1
            self._clean = 0
            return self._switch("escalate", "layer_slo_breach",
                                err_var=worst[2], layer=worst[1])
        if est > self.cfg.slo_err_var:
            if self.first_breach_window is None:
                self.first_breach_window = self.windows_closed - 1
            self._clean = 0
            return self._switch("escalate", "slo_breach", err_var=est)
        if est <= self.cfg.relax_headroom * self.cfg.slo_err_var \
                and layers_clean:
            self._clean += 1
            if self._clean >= self.cfg.clean_windows_to_relax:
                return self._switch("relax", "clean_windows", err_var=est)
        else:
            # inside the hysteresis band: neither a breach nor clean
            self._clean = 0
        return None

    def _severe_target(self, est: float) -> int:
        """Severe breach: the first rung past the current one whose
        *modeled* residual clears the SLO.  The probe's err-var tracks the
        approximate array's aggressiveness, which the cost model's power
        saving proxies: ``residual_j ~= est * saving_j / saving_current``
        (an exact rung, saving 0, models residual 0, so the most-exact
        rung always qualifies).  When the current rung's saving is already
        0 the proxy has no signal — fall back to the one-rung walk."""
        cur = self.ladder[self.rung_idx].power_saving_pct
        if cur <= 0:
            return self.rung_idx + 1
        for j in range(self.rung_idx + 1, len(self.ladder)):
            if est * (self.ladder[j].power_saving_pct / cur) \
                    <= self.cfg.slo_err_var:
                return j
        return len(self.ladder) - 1

    def _switch(self, action: str, reason: str, err_var: float | None,
                layer: str | None = None) -> GovernorDecision | None:
        step = 1 if action == "escalate" else -1
        target = self.rung_idx + step
        if not 0 <= target < len(self.ladder):
            return None  # already at the ladder end
        if (action == "escalate" and err_var is not None and layer is None
                and self.cfg.severe_factor is not None
                and err_var >= self.cfg.severe_factor * self.cfg.slo_err_var):
            # severe-jump arithmetic compares err_var against the LOGITS
            # SLO scale, so layer-driven escalations (whose err_var is a
            # per-layer variance) keep the one-rung walk
            target = self._severe_target(err_var)
        d = GovernorDecision(action=action, reason=reason,
                             rung_from=self.ladder[self.rung_idx],
                             rung_to=self.ladder[target],
                             window=self.windows_closed, err_var=err_var,
                             layer=layer)
        self.rung_idx = target
        self.decisions.append(d)
        # new numerics regime: the running estimate must restart
        self._history.clear()
        self._win = (0, 0.0, 0.0)
        self._win_probes = 0
        self._clean = 0
        self._layer_history.clear()
        self._layer_win = {}
        return d
