"""A/B shadow serving: a second NumericsSpec pack mirrors live traffic.

The PR 2 NumericsSpec made packs declarative and the PR 7 speculative
path proved two packs can share one engine's jitted callable (parameters
are a traced argument, so the jit cache keys on parameter structure).
:class:`ShadowRunner` reuses that dual-pack machinery for *evaluation*
instead of drafting: a deterministic sample of FINISHED requests replays
teacher-forced — both packs forward the primary's emitted sequence in
``prefill_chunk``-shaped calls against a private slot cache — and the
runner diffs the two packs where it matters:

  * **tokens** — would the shadow pack have emitted the same argmax
    token at each generation position? (the same agreement measure as
    speculative acceptance, so numbers are comparable across both
    subsystems);
  * **logits** — elementwise logit-delta moments at generation
    positions, Chan-merged across replays (the serving-time analogue of
    the error probe's calibration-time residual);
  * **power** — each pack's MAC-weighted modeled array-power saving
    (:func:`repro.serving.engine.power_profile_from_params`).

:meth:`verdict` folds the three into an automated accuracy-vs-power
recommendation ("adopt-shadow" / "keep-primary" with the reason spelled
out) consumable by the ``serve`` CLI, ``trace_report``, and the
BENCH_serve.json shadow rows.

Replays are teacher-forced along the PRIMARY's tokens on purpose: both
packs see identical inputs at every position, so the diff isolates the
numerics instead of compounding trajectory divergence — and the replay
cost is ``ceil(len/chunk)`` chunk-shaped calls per pack, not one thin
call per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_pool import SlotPool
from repro.serving.metrics import _merge_moments


class ShadowRunner:
    """Teacher-forced dual-pack replay + accuracy-vs-power verdict.

    ``min_token_match`` — token agreement at or above this adopts the
    shadow pack (if it also saves modeled power); below it the verdict
    is keep-primary on accuracy grounds.  ``slo_err_var`` — optional
    additional ceiling on the replayed logit-delta variance.
    """

    def __init__(self, api, ecfg, primary_params, shadow_params,
                 primary_label: str, shadow_label: str, mesh=None,
                 min_token_match: float = 0.9,
                 slo_err_var: float | None = None) -> None:
        if not 0 < ecfg.shadow_fraction <= 1:
            raise ValueError("shadow_fraction must be in (0, 1], got "
                             f"{ecfg.shadow_fraction}")
        if api.cfg.rwkv:
            raise NotImplementedError(
                f"{api.cfg.name}: shadow replay resets the slot cache by "
                "cursor; recurrent RWKV state has no cursor")
        self.primary_params = primary_params
        self.shadow_params = shadow_params
        self.primary_label = primary_label
        self.shadow_label = shadow_label
        self.fraction = float(ecfg.shadow_fraction)
        #: deterministic sampling: every Nth finished request replays
        self.every = max(1, round(1.0 / self.fraction))
        self.min_token_match = min_token_match
        self.slo_err_var = slo_err_var
        self.chunk = ecfg.prefill_chunk
        self.slots = ecfg.slots
        # a private slot cache (contiguous, whatever the engine serves
        # under): replays never touch the engine's pool, and the batch
        # shape matches the engine's chunk calls so the model sees
        # nothing new.  Reset between replays is the acquire semantics —
        # a cursor move; stale K/V beyond it is position-masked.
        self._pool = SlotPool(api, ecfg.slots, ecfg.max_len,
                              ecfg.cache_dtype)
        self._cache = self._pool.cache
        decode_slots = api.decode_slots
        # one jitted callable, one shape, BOTH packs: params are traced,
        # so primary and shadow structures share it (the speculative-
        # decode dual-pack mechanism, reused)
        self._fn = jax.jit(
            lambda p, t, c, nv: decode_slots(p, t, c, nv, mesh=mesh))
        # accumulated A/B state
        self.sampled = 0
        self.tokens = 0
        self.matches = 0
        self._logits: tuple[int, float, float] = (0, 0.0, 0.0)
        self._max_abs = 0.0
        # modeled pack power (MAC-weighted saving over the profile)
        self.primary_saving_pct = _pack_saving_pct(primary_params)
        self.shadow_saving_pct = _pack_saving_pct(shadow_params)

    # -- sampling ------------------------------------------------------------

    def wants(self, finish_index: int) -> bool:
        """Deterministic request sampling by finish order (1-based)."""
        return finish_index % self.every == 0

    # -- replay --------------------------------------------------------------

    def _forward(self, params, fed: list[int]) -> np.ndarray:
        """Teacher-forced logits for one token sequence, chunk by chunk.

        Row 0 of the (slots, chunk) batch carries the tokens; the other
        rows ride with ``n_valid = 0``.  Returns (len(fed), vocab)."""
        cache = {**self._cache,
                 "lengths": jnp.zeros_like(self._cache["lengths"])}
        outs = []
        for off in range(0, len(fed), self.chunk):
            part = fed[off:off + self.chunk]
            toks = np.zeros((self.slots, self.chunk), dtype=np.int32)
            toks[0, :len(part)] = part
            nv = np.zeros((self.slots,), dtype=np.int32)
            nv[0] = len(part)
            logits, cache = self._fn(params, jnp.asarray(toks), cache,
                                     jnp.asarray(nv))
            outs.append(np.asarray(logits[0, :len(part)], dtype=np.float32))
        self._cache = cache  # keep the allocations warm for the next replay
        return np.concatenate(outs, axis=0)

    def replay(self, prompt, generated) -> dict:
        """Replay one finished request through BOTH packs; returns the
        per-request record ``EngineMetrics.record_shadow`` consumes."""
        prompt = [int(t) for t in prompt]
        generated = [int(t) for t in generated]
        if not generated:
            raise ValueError("shadow replay needs generated tokens")
        plen = len(prompt)
        fed = prompt + generated[:-1]  # inputs; outputs predict fed[i+1]
        lg_p = self._forward(self.primary_params, fed)
        lg_s = self._forward(self.shadow_params, fed)
        # generation positions: fed index plen-1 predicts generated[0], ...
        gen_p = lg_p[plen - 1:]
        gen_s = lg_s[plen - 1:]
        pred_s = np.argmax(gen_s, axis=-1)
        matches = int((pred_s == np.asarray(generated)).sum())
        delta = (gen_s.astype(np.float64)
                 - gen_p.astype(np.float64)).ravel()
        rec = {
            "tokens": len(generated),
            "matches": matches,
            "logits_err": {"n": int(delta.size),
                           "mean": float(delta.mean()),
                           "var": float(delta.var()),
                           "max_abs": float(np.abs(delta).max())},
        }
        self.sampled += 1
        self.tokens += rec["tokens"]
        self.matches += matches
        le = rec["logits_err"]
        self._logits = _merge_moments(self._logits,
                                      (le["n"], le["mean"], le["var"]))
        self._max_abs = max(self._max_abs, le["max_abs"])
        return rec

    # -- verdict -------------------------------------------------------------

    def verdict(self) -> dict | None:
        """Automated accuracy-vs-power recommendation over everything
        sampled so far (None until a replay happened)."""
        if not self.sampled:
            return None
        match_rate = self.matches / self.tokens if self.tokens else 0.0
        _, _, err_var = self._logits
        power_delta = round(self.shadow_saving_pct
                            - self.primary_saving_pct, 2)
        accurate = match_rate >= self.min_token_match and (
            self.slo_err_var is None or err_var <= self.slo_err_var)
        if not accurate:
            decision = "keep-primary"
            if match_rate < self.min_token_match:
                reason = (f"token match {match_rate:.3f} below "
                          f"{self.min_token_match:g} threshold")
            else:
                reason = (f"logits err-var {err_var:.3g} above "
                          f"{self.slo_err_var:g} ceiling")
        elif power_delta > 0:
            decision = "adopt-shadow"
            reason = (f"token match {match_rate:.3f} >= "
                      f"{self.min_token_match:g} and modeled power saving "
                      f"+{power_delta:g}pp")
        else:
            decision = "keep-primary"
            reason = (f"accuracy parity but no modeled power win "
                      f"({power_delta:+g}pp)")
        return {
            "primary": self.primary_label,
            "shadow": self.shadow_label,
            "sampled_requests": self.sampled,
            "sampled_fraction": round(1.0 / self.every, 4),
            "tokens": self.tokens,
            "token_matches": self.matches,
            "token_match_rate": round(match_rate, 4),
            "logits_err_var": err_var,
            "logits_err_max_abs": self._max_abs,
            "primary_power_saving_pct": round(self.primary_saving_pct, 2),
            "shadow_power_saving_pct": round(self.shadow_saving_pct, 2),
            "power_delta_pct": power_delta,
            "verdict": decision,
            "reason": reason,
        }


def _pack_saving_pct(params) -> float:
    """MAC-weighted modeled array-power saving of one pack."""
    from repro.serving.engine import power_profile_from_params

    prof = power_profile_from_params(params)
    units = sum(e["mac_per_token"] for e in prof.values())
    saved = sum(e["mac_per_token"] * e["saving_pct"] / 100.0
                for e in prof.values())
    return 100.0 * saved / units if units else 0.0
