"""Fleet serving: spec-aware routing over heterogeneous-numerics replicas.

The paper's deployment argument is per-*tier*: different approximate-
multiplier configurations serve different accuracy/power operating points
(the CV line arXiv:2102.09642 and the multiplier-diversity line
arXiv:2107.09366 both compound the win this way).  ``NumericsSpec`` can
already express the per-engine choice; this module makes an *engine* a
**replica behind a router** so one deployment runs several choices at
once:

* a **tier** (:class:`TierConfig`) is N replicas packing the SAME loaded
  checkpoint under one per-tier ``NumericsSpec`` override — one
  host-memory copy of float params, one pack per tier, shared by the
  tier's replicas (numerics live in the parameters, so heterogeneity
  costs packs, not checkpoints);
* the :class:`FleetRouter` spreads requests over the replicas through
  the engine's **replica handle** surface (submit / step / drain / load /
  snapshot / prefix sharing / tracer — plain-data boundary, so it could
  later sit on a socket): latency-sensitive traffic goes to *exact*
  tiers, bulk/background traffic to *approximate* tiers, each placement
  picking the least-loaded candidate (queue-depth, TTFT tie-break) with
  optional overflow **spill** from a saturated approximate tier into the
  exact tiers (never the reverse — a latency request must not silently
  lose exactness);
* replicas share their **prefix caches** content-addressedly
  (:meth:`FleetRouter.share_prefixes`): the PR 5 sha256 chain hash
  commits to the whole token prefix, so a warm replica's exported
  (hash, block content) pairs are adoptable sight unseen by cold ones;
* observability aggregates along the PR 6 ``EngineMetrics.merge`` path:
  per-tier merges, then a fleet merge of the tier merges (merge is
  associative; heterogeneous numerics labels collapse to ``"mixed"``),
  plus per-replica trace files whose events carry the replica's
  ``engine_id``.

Every replica gets its own single-device mesh (:func:`replica_mesh`), so
a fleet run exercises the ``decode_slots(..., mesh=)`` plumb-through N
times per host — the N-meshes-on-one-host shape multi-host placement
will inherit.

Token identity: generation is greedy and numerics live in the pack, so a
request's output depends only on the tier that served it — a fleet run
is token-identical to single engines packed per tier serving the same
requests sequentially (tests/test_fleet.py pins this per routing
policy).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

from repro.serving.metrics import EngineMetrics

__all__ = ["TierConfig", "FleetReplica", "FleetRouter", "build_fleet",
           "replica_mesh", "REQUEST_CLASSES", "ROUTING_POLICIES"]

#: routing classes a request may declare (or derive from priority)
REQUEST_CLASSES = ("latency", "bulk")

#: ``spec-aware`` — class -> tier exactness + least-loaded + spill (the
#: default, the tentpole policy); ``least-loaded`` — ignore class, min
#: pending everywhere; ``round-robin`` — ignore class and load, cycle
ROUTING_POLICIES = ("spec-aware", "least-loaded", "round-robin")


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """One numerics tier of the fleet.

    ``spec`` is a ladder-style spec name (preset, ``"float"``, or a JSON
    spec path — whatever the deployment's pack function resolves).
    ``exact`` routes the tier: None (default) classifies from the
    resolved spec itself (``NumericsSpec.is_exact``; ``"float"`` is
    exact) so the router cannot mislabel a tier a human mislabeled.
    """

    name: str
    spec: str
    count: int = 1
    exact: bool | None = None

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError(f"tier {self.name!r} needs count >= 1, "
                             f"got {self.count}")


class FleetReplica:
    """One engine behind the replica-handle boundary, with its fleet
    identity (tier, index, exactness).  The router only ever touches the
    handle surface of ``engine`` — nothing model- or device-shaped
    crosses this object."""

    def __init__(self, engine, tier: TierConfig, index: int,
                 exact: bool) -> None:
        self.engine = engine
        self.tier = tier
        self.index = index
        self.exact = exact
        self.replica_id = f"{tier.name}:{index}"
        self.routed = 0

    @property
    def idle(self) -> bool:
        return self.engine.idle


def replica_mesh():
    """A single-device mesh for one replica (axis ``"model"``, size 1).

    Gives every replica the mesh-parameterized ``decode_slots`` path the
    multi-host fleet will use, while staying a no-op numerically — the
    regression test in tests/test_decode_consistency.py pins that a
    single-device mesh is token-identical to the mesh-less path."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), ("model",))


class FleetRouter:
    """Spec-aware request router over heterogeneous-numerics replicas.

    ``submit`` places one request: its class ("latency" | "bulk",
    derived from ``priority`` when not given — 0 is latency-sensitive,
    anything later is bulk) selects the candidate tier set, the
    least-loaded candidate wins (queue-depth first, observed mean TTFT
    as tie-break), and a saturated bulk side spills into the exact tiers
    when ``spill_threshold`` is set.  Latency traffic NEVER spills to
    approximate tiers: degrading a latency request's numerics silently
    is the one thing a spec-aware fleet exists to prevent.

    The placed engine ``Request`` is returned annotated with
    ``fleet_replica`` / ``fleet_tier`` / ``fleet_class`` / ``fleet_spill``
    so callers can audit placement (and tests can assert it).
    """

    def __init__(self, replicas: list[FleetReplica],
                 policy: str = "spec-aware",
                 spill_threshold: int | None = None) -> None:
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; valid: "
                             f"{list(ROUTING_POLICIES)}")
        if spill_threshold is not None and spill_threshold < 1:
            raise ValueError("spill_threshold must be >= 1 (or None)")
        self.replicas = list(replicas)
        self.policy = policy
        self.spill_threshold = spill_threshold
        self._exact = [r for r in self.replicas if r.exact]
        self._approx = [r for r in self.replicas if not r.exact]
        self._rr = itertools.cycle(self.replicas)
        self.spills = 0
        self.routed_by_class = {k: 0 for k in REQUEST_CLASSES}

    # -- placement -----------------------------------------------------------

    @staticmethod
    def _least_loaded(cands: list[FleetReplica]) -> FleetReplica:
        """Min pending work; TTFT mean breaks ties (a replica that has
        been answering faster absorbs the marginal request better).
        ``min`` is stable, so equal scores keep tier declaration order —
        placement stays deterministic for the identity tests."""
        def score(rep: FleetReplica):
            ld = rep.engine.load()
            ttft = ld["ttft_mean_s"]
            return (ld["pending"], ttft if ttft is not None else 0.0)

        return min(cands, key=score)

    def _route(self, klass: str) -> tuple[FleetReplica, bool]:
        """(replica, spilled) for one request of ``klass``."""
        if self.policy == "round-robin":
            return next(self._rr), False
        if self.policy == "least-loaded":
            return self._least_loaded(self.replicas), False
        home = self._exact if klass == "latency" else self._approx
        if klass == "latency" and not home:
            raise ValueError(
                "no exact tier in the fleet: latency-sensitive traffic "
                "requires one (it never spills to approximate tiers)")
        if not home:
            # no approximate tier configured: bulk runs on the exact side
            return self._least_loaded(self._exact), False
        pick = self._least_loaded(home)
        if (klass == "bulk" and self._exact
                and self.spill_threshold is not None
                and pick.engine.load()["pending"] >= self.spill_threshold):
            spill = self._least_loaded(self._exact)
            if spill.engine.load()["pending"] < self.spill_threshold:
                return spill, True
        return pick, False

    def submit(self, prompt, max_new_tokens: int, priority: int = 0,
               klass: str | None = None, **kw):
        """Route one request; returns the placed engine ``Request``
        (annotated with its fleet placement)."""
        if klass is None:
            klass = "latency" if priority <= 0 else "bulk"
        if klass not in REQUEST_CLASSES:
            raise ValueError(f"unknown request class {klass!r}; valid: "
                             f"{list(REQUEST_CLASSES)}")
        rep, spilled = self._route(klass)
        req = rep.engine.submit(prompt, max_new_tokens, priority=priority,
                                **kw)
        req.fleet_replica = rep.replica_id
        req.fleet_tier = rep.tier.name
        req.fleet_class = klass
        req.fleet_spill = spilled
        rep.routed += 1
        self.routed_by_class[klass] += 1
        if spilled:
            self.spills += 1
        tr = rep.engine.tracer
        if tr is not None:
            tr.record("routed", rid=req.rid, klass=klass,
                      tier=rep.tier.name, replica=rep.replica_id,
                      spill=spilled)
        return req

    # -- serving loop --------------------------------------------------------

    @property
    def idle(self) -> bool:
        return all(r.idle for r in self.replicas)

    def step(self) -> list:
        """One fleet iteration: every non-idle replica advances one engine
        step.  Returns the requests that finished across the fleet."""
        finished = []
        for rep in self.replicas:
            if not rep.idle:
                finished.extend(rep.engine.step())
        return finished

    def drain(self, max_steps: int | None = None,
              share_every: int | None = None) -> list:
        """Serve until the whole fleet is idle (or ``max_steps`` fleet
        iterations).  ``share_every`` runs :meth:`share_prefixes` every N
        iterations, so prompt blocks finished on a warm replica reach
        cold ones while traffic is still arriving via ``submit``."""
        finished = []
        steps = 0
        while not self.idle:
            finished.extend(self.step())
            steps += 1
            if share_every and steps % share_every == 0:
                self.share_prefixes()
            if max_steps is not None and steps >= max_steps:
                break
        return finished

    # -- cross-replica prefix sharing ----------------------------------------

    def share_prefixes(self) -> int:
        """Propagate prefix-cache entries across the fleet; returns the
        total blocks imported.

        Exports from every (paged) replica are pooled by chain hash —
        content-addressed, so two replicas publishing the same prompt
        contribute one entry — then every replica imports its pool
        (importers skip hashes they already hold, so a steady-state fleet
        converges to zero imports).  Sharing is scoped WITHIN a tier:
        the chain hash commits to the tokens, but the KV *content* was
        written by prefill under the exporter's pack, so an exact tier
        adopting blocks prefilled by an approximate pack would leak
        approximate prefill state into exact-tier generations and break
        the tier's token-identity contract.  Same tier = same pack =
        bit-identical prefill state, hence adoptable sight unseen."""
        total = 0
        by_tier: dict[str, dict[bytes, dict]] = {}
        for rep in self.replicas:
            pool = by_tier.setdefault(rep.tier.name, {})
            for h, content in rep.engine.export_prefix():
                pool.setdefault(h, content)
        for rep in self.replicas:
            pool = by_tier.get(rep.tier.name)
            if pool:
                total += rep.engine.import_prefix(list(pool.items()))
        return total

    # -- observability -------------------------------------------------------

    def snapshot(self) -> dict:
        """Fleet-level metrics: per-tier ``EngineMetrics.merge`` of the
        tier's replica snapshots, a fleet-wide merge of the tier merges
        (merge is associative, so this equals merging every replica at
        once), and the router's own placement counters."""
        tier_snaps: dict[str, dict] = {}
        tier_order: list[str] = []
        for rep in self.replicas:
            if rep.tier.name not in tier_order:
                tier_order.append(rep.tier.name)
        for tname in tier_order:
            snaps = [r.engine.snapshot() for r in self.replicas
                     if r.tier.name == tname]
            tier_snaps[tname] = EngineMetrics.merge(snaps)
        return {
            "fleet": EngineMetrics.merge(list(tier_snaps.values())),
            "tiers": tier_snaps,
            "replicas": {r.replica_id: {
                "tier": r.tier.name, "exact": r.exact,
                "numerics": r.engine.numerics, "routed": r.routed,
            } for r in self.replicas},
            "routing": {"policy": self.policy,
                        "spill_threshold": self.spill_threshold,
                        "routed_by_class": dict(self.routed_by_class),
                        "spills": self.spills},
        }

    def write_traces(self, directory) -> list[str]:
        """One JSONL trace file per traced replica (named by replica id);
        returns the written paths.  tools/trace_report.py consumes them
        together (``--trace`` per file) and prefixes every request id
        with the replica's engine id."""
        import os

        paths = []
        os.makedirs(directory, exist_ok=True)
        for rep in self.replicas:
            if rep.engine.tracer is None:
                continue
            path = os.path.join(
                directory, f"trace-{rep.replica_id.replace(':', '-')}.jsonl")
            rep.engine.tracer.write(path)
            paths.append(path)
        return paths

    def compile_count(self) -> int:
        """Sum of per-replica jit cache sizes; each replica individually
        keeps the two-compiled-shapes invariant."""
        return sum(r.engine.compile_count() for r in self.replicas)


def build_fleet(cfg, float_params, tiers: list[TierConfig],
                ecfg, pack: Callable, api=None,
                policy: str = "spec-aware",
                spill_threshold: int | None = None,
                mesh_per_replica: bool = True) -> FleetRouter:
    """Assemble a router over in-process replicas from ONE checkpoint.

    ``pack(spec_name) -> (params, numerics_label, spec_or_none)`` builds
    a tier's serving parameters from the shared ``float_params`` (the
    deployment supplies it — normally a ``build_serving_params`` closure,
    see ``repro.launch.serve``).  Packing happens once per tier; the
    tier's replicas share the packed tree (JAX arrays are immutable), so
    fleet memory scales with tiers, not replicas.

    Each replica gets its own engine, its own single-device mesh
    (``mesh_per_replica=False`` drops the mesh for debugging), and an
    ``engine_id`` of ``"<tier>:<i>"`` that its trace events carry.
    """
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    if not tiers:
        raise ValueError("build_fleet needs at least one TierConfig")
    names = [t.name for t in tiers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tier names: {names}")
    api = api or build_model(cfg)
    replicas: list[FleetReplica] = []
    for tier in tiers:
        params, label, spec = pack(tier.spec)
        exact = tier.exact
        if exact is None:
            exact = spec is None or spec.is_exact  # "float" resolves None
        for i in range(tier.count):
            engine = ServingEngine(
                cfg, params, ecfg, api=api,
                mesh=replica_mesh() if mesh_per_replica else None,
                numerics=label, engine_id=f"{tier.name}:{i}")
            replicas.append(FleetReplica(engine, tier, i, exact))
    return FleetRouter(replicas, policy=policy,
                       spill_threshold=spill_threshold)
