"""repro — Control-Variate Approximation for Approximate-Multiplier DNN Inference.

A production-grade JAX training/inference framework reproducing and extending

    "Leveraging Highly Approximated Multipliers in DNN Inference"
    G. Zervakis, F. Frustaci, O. Spantidi, I. Anagnostopoulos, H. Amrouch,
    J. Henkel (2024).

Public surface:
    repro.core            the paper's contribution (multipliers, control variate,
                          approximate quantized layers, policies, cost model)
    repro.quant           gemmlowp-style uint8 quantization substrate
    repro.nn / repro.models   model zoo (10 assigned architectures + CNN suite)
    repro.kernels         Pallas TPU kernels (+ jnp oracles)
    repro.launch          mesh / dry-run / train / serve drivers
"""

__version__ = "1.0.0"
