"""Declarative numerics specification: *what* runs approximate, serialized.

A :class:`NumericsSpec` is the single public way to configure the paper's
parameter transformation.  It holds an ordered list of :class:`Rule`s —
pattern on the parameter-tree path, first match wins — plus a default
action, and round-trips through JSON so the same spec can live in a
checkpoint, travel over a CLI flag, and be audited layer by layer.

Actions (what a matched layer does):

  * an :class:`~repro.core.policy.ApproxPolicy` — pack for the approximate
    MAC array with that multiplier family / ``m`` / CV setting;
  * ``FLOAT`` (``None``) — keep the layer in float (not packed);
  * :func:`auto` — defer to the greedy ALWANN-style per-layer search at
    resolve time, bounded by an error budget.

Pattern semantics are **segment-anchored**, not substring: a ``glob``
pattern without ``/`` must fnmatch one *whole* path segment (``"norm"``
matches ``blocks/0/norm/w`` but not ``blocks/0/denormalizer/w``); a
pattern with ``/`` must match the full joined path, ``*`` staying within a
segment and ``**`` spanning any number of segments.  ``regex`` rules are
``re.search`` over the ``/``-joined path for escape-hatch cases.

``spec.resolve(params)`` produces the concrete, inspectable
:class:`~repro.numerics.plan.PackPlan`; ``apply_numerics(params, plan)``
executes it.  Resolution is pure shape/metadata work (no weight math
unless an ``auto`` rule needs calibration), so it also runs on
``jax.eval_shape`` abstract trees — that is what the ``plan`` CLI uses.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
import re
from typing import Any, Union

from repro.core.policy import INT8_EXACT, ApproxPolicy, paper_policies

__all__ = [
    "FLOAT",
    "Auto",
    "auto",
    "Rule",
    "NumericsSpec",
    "match_path",
]

#: Sentinel action: keep the matched layer in float (same sentinel as
#: repro.core.policy.FLOAT — None).
FLOAT = None


@dataclasses.dataclass(frozen=True)
class Auto:
    """Deferred per-layer assignment: lowered to concrete policies by the
    greedy search during :meth:`NumericsSpec.resolve`.

    ``candidates`` names a registered candidate set (names, not callables,
    so the rule stays serializable).
    """

    budget_rel_err: float = 0.05
    candidates: str = "paper-grid"

    def __post_init__(self):
        if self.budget_rel_err <= 0:
            raise ValueError("budget_rel_err must be positive")
        if self.candidates not in CANDIDATE_SETS:
            raise ValueError(
                f"unknown candidate set {self.candidates!r}; "
                f"known: {sorted(CANDIDATE_SETS)}")


def auto(budget: float = 0.05, candidates: str = "paper-grid") -> Auto:
    """Rule action: pick the most aggressive policy per layer whose model
    output error stays under ``budget`` (relative, on calibration inputs)."""
    return Auto(budget_rel_err=budget, candidates=candidates)


#: Named candidate sets an ``auto`` rule may search over (serializable by
#: name).  Values are zero-arg builders.
CANDIDATE_SETS = {
    "paper-grid": lambda: paper_policies(use_cv=True),
    "paper-grid-nocv": lambda: paper_policies(use_cv=False),
}

Action = Union[ApproxPolicy, Auto, None]


# ---------------------------------------------------------------------------
# Path matching
# ---------------------------------------------------------------------------


def _match_segments(pat: list[str], segs: tuple[str, ...]) -> bool:
    if not pat:
        return not segs
    head, rest = pat[0], pat[1:]
    if head == "**":
        return any(_match_segments(rest, segs[i:]) for i in range(len(segs) + 1))
    if not segs:
        return False
    return fnmatch.fnmatchcase(segs[0], head) and _match_segments(rest, segs[1:])


def match_path(pattern: str, path: tuple[str, ...], kind: str = "glob") -> bool:
    """Segment-anchored rule matching (see module docstring)."""
    if kind == "regex":
        return re.search(pattern, "/".join(path)) is not None
    if kind != "glob":
        raise ValueError(f"unknown rule kind {kind!r} (glob|regex)")
    if "/" in pattern:
        return _match_segments(pattern.split("/"), tuple(path))
    return any(fnmatch.fnmatchcase(seg, pattern) for seg in path)


# ---------------------------------------------------------------------------
# Rules and specs
# ---------------------------------------------------------------------------


def _action_to_dict(action: Action) -> Any:
    if action is None:
        return "float"
    if isinstance(action, Auto):
        return {"auto": {"budget_rel_err": action.budget_rel_err,
                         "candidates": action.candidates}}
    if isinstance(action, ApproxPolicy):
        return {"policy": action.to_dict()}
    raise TypeError(f"not a rule action: {action!r}")


def _action_from_dict(obj: Any) -> Action:
    if obj == "float" or obj is None:
        return None
    if isinstance(obj, dict) and "auto" in obj:
        return Auto(**obj["auto"])
    if isinstance(obj, dict) and "policy" in obj:
        return ApproxPolicy.from_dict(obj["policy"])
    raise ValueError(f"unrecognized action {obj!r}")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One ordered pattern -> action entry.  ``note`` documents *why* the
    rule exists; it is serialized and shown in the resolved plan table."""

    pattern: str
    action: Action = FLOAT
    kind: str = "glob"  # "glob" | "regex"
    note: str = ""

    def __post_init__(self):
        if self.kind not in ("glob", "regex"):
            raise ValueError(f"rule kind must be glob|regex, got {self.kind!r}")
        if self.kind == "regex":
            re.compile(self.pattern)  # fail fast on bad patterns

    def matches(self, path: tuple[str, ...]) -> bool:
        return match_path(self.pattern, path, self.kind)

    def to_dict(self) -> dict:
        d = {"pattern": self.pattern, "action": _action_to_dict(self.action)}
        if self.kind != "glob":
            d["kind"] = self.kind
        if self.note:
            d["note"] = self.note
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Rule":
        return cls(pattern=d["pattern"],
                   action=_action_from_dict(d.get("action", "float")),
                   kind=d.get("kind", "glob"),
                   note=d.get("note", ""))


@dataclasses.dataclass(frozen=True)
class NumericsSpec:
    """Ordered, serializable per-layer numerics configuration.

    ``rules`` are tried in order against every packable linear layer's
    parameter-tree path; the first match decides the layer's action.
    Layers no rule matches take ``default``.
    """

    name: str
    rules: tuple[Rule, ...] = ()
    default: Action = INT8_EXACT

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))

    # -- rule application ----------------------------------------------------

    def action_for(self, path: tuple[str, ...]) -> tuple[Action, str]:
        """(action, source) for one layer path; source is the matching
        rule's pattern, or "default"."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.action, rule.pattern
        return self.default, "default"

    # -- tier classification -------------------------------------------------

    @property
    def is_exact(self) -> bool:
        """True when no layer this spec can assign runs on the approximate
        MAC array: every rule action and the default are FLOAT or exact
        int8.  This is decidable from the spec alone (no parameter tree),
        which is what the fleet router needs to classify replica tiers —
        latency-sensitive traffic must only land on exact tiers.  ``auto``
        rules are conservatively non-exact: their assignment is
        resolve-time and may pick an approximate policy."""
        def _exact(action: Action) -> bool:
            return action is None or (isinstance(action, ApproxPolicy)
                                      and not action.is_approx)

        return (_exact(self.default)
                and all(_exact(r.action) for r in self.rules))

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "name": self.name,
            "rules": [r.to_dict() for r in self.rules],
            "default": _action_to_dict(self.default),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "NumericsSpec":
        version = d.get("version", 1)
        if version != 1:
            raise ValueError(f"unsupported NumericsSpec version {version}")
        return cls(
            name=d["name"],
            rules=tuple(Rule.from_dict(r) for r in d.get("rules", ())),
            default=_action_from_dict(d.get("default", "float")),
        )

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "NumericsSpec":
        return cls.from_dict(json.loads(s))

    # -- resolution ----------------------------------------------------------

    def resolve(self, params: Any, *, apply_fn=None, calib_inputs=None,
                act_ranges: dict | None = None, n_array: int = 64):
        """Resolve against a parameter tree into a concrete
        :class:`~repro.numerics.plan.PackPlan`.

        ``params`` may be a real tree or ``jax.eval_shape`` output — only
        shapes are read, unless an ``auto`` rule fires, which additionally
        needs ``apply_fn(params, calib_inputs)`` (and optionally
        ``act_ranges``) to run the greedy search on real values.
        """
        from repro.core.approx_linear import is_linear_params
        from repro.numerics.plan import PackPlan, PlanEntry, plan_entry

        assignments: list[tuple[str, tuple[str, ...], Any, Action, str]] = []

        def walk(node: Any, path: tuple[str, ...]):
            if is_linear_params(node):
                action, source = self.action_for(path)
                assignments.append(("/".join(path), path, node, action, source))
                return
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, path + (str(k),))
            elif isinstance(node, (list, tuple)):
                for i, v in enumerate(node):
                    walk(v, path + (str(i),))

        walk(params, ())

        auto_items = [(joined, node, action) for joined, _, node, action, _
                      in assignments if isinstance(action, Auto)]
        lowered: dict[str, ApproxPolicy] = {}
        if auto_items:
            lowered = _lower_auto(params, auto_items, apply_fn, calib_inputs,
                                  act_ranges)

        entries: list[PlanEntry] = []
        for joined, _, node, action, source in assignments:
            if isinstance(action, Auto):
                policy = lowered[joined]
                source = f"{source} [auto<= {action.budget_rel_err}]"
            else:
                policy = action
            entries.append(plan_entry(joined, node, policy, source,
                                      n_array=n_array))
        return PackPlan(spec_name=self.name, entries=tuple(entries))


def _lower_auto(params: Any,
                auto_items: list[tuple[str, Any, Auto]],
                apply_fn, calib_inputs,
                act_ranges: dict | None) -> dict[str, ApproxPolicy]:
    """Lower ``auto`` rules through the shared greedy ALWANN-style core
    (:func:`repro.core.policy.greedy_assign`)."""
    if apply_fn is None or calib_inputs is None:
        raise ValueError(
            "spec contains auto(...) rules; resolve() needs apply_fn= and "
            "calib_inputs= to run the greedy search (auto rules cannot be "
            "resolved on abstract shape-only trees)")

    from repro.core.policy import greedy_assign, order_most_aggressive

    ordered = {name: order_most_aggressive(CANDIDATE_SETS[name]())
               for name in {a.candidates for _, _, a in auto_items}}
    items = [(joined, ordered[a.candidates], a.budget_rel_err)
             for joined, _, a in auto_items]
    return greedy_assign(apply_fn, params, calib_inputs, items,
                         act_ranges=act_ranges)
