"""Degradation ladder: the ordered NumericsSpec rungs the SLO governor
walks (:mod:`repro.serving.governor`).

A ladder is most-approximate-first: rung 0 is the cheapest (highest
modeled MAC-array power saving), the last rung the most exact (float —
the always-safe floor).  Escalating moves right (spends power to buy
accuracy), relaxing moves left (re-harvests power).  ``resolve_ladder``
turns preset names / spec-JSON paths into rungs carrying the mean modeled
power saving of their resolved :class:`~repro.numerics.plan.PackPlan`, so
every governor switch can record the watts it traded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from repro.numerics.presets import get_preset
from repro.numerics.spec import NumericsSpec

__all__ = ["DEFAULT_LADDER", "LadderRung", "ladder_spec", "resolve_ladder"]

#: the production default: perforated-m2+CV serving, exact int8 under
#: pressure, float as the floor
DEFAULT_LADDER: tuple[str, ...] = ("serve-default", "int8", "float")


@dataclasses.dataclass(frozen=True)
class LadderRung:
    """One governor rung: a spec (None = raw float params) plus the mean
    modeled power saving of its packed layers (cost-model %, 0 for
    exact/float rungs)."""

    name: str
    spec: NumericsSpec | None
    power_saving_pct: float


def ladder_spec(name: str) -> tuple[str, NumericsSpec | None]:
    """Resolve one ladder entry name: ``"float"``, a preset name, or a
    path to a NumericsSpec JSON file."""
    if name == "float":
        return "float", None
    if name.endswith(".json"):
        with open(name) as f:
            spec = NumericsSpec.from_json(f.read())
        return spec.name, spec
    spec = get_preset(name)
    return spec.name, spec


def resolve_ladder(names: Sequence[str | NumericsSpec | None],
                   params: Any) -> list[LadderRung]:
    """Build governor rungs from ladder entries, resolving each spec
    against ``params`` (real or abstract) for its modeled power saving.

    Entries may be names (see :func:`ladder_spec`) or NumericsSpec
    objects (None = float).  The ladder must be most-approximate-first:
    power savings must be non-increasing toward the exact end, otherwise
    "escalate" would REDUCE accuracy spend — a configuration error.
    """
    if len(names) < 2:
        raise ValueError(f"a governor ladder needs >= 2 rungs, got "
                         f"{list(names)!r}")
    rungs: list[LadderRung] = []
    for entry in names:
        if entry is None or isinstance(entry, NumericsSpec):
            label, spec = (entry.name, entry) if entry is not None \
                else ("float", None)
        else:
            label, spec = ladder_spec(entry)
        if spec is None:
            saving = 0.0
        else:
            packed = spec.resolve(params).packed
            saving = (sum(e.power_saving_pct for e in packed) / len(packed)
                      if packed else 0.0)
        rungs.append(LadderRung(label, spec, round(saving, 2)))
    for lo, hi in zip(rungs, rungs[1:]):
        if lo.power_saving_pct < hi.power_saving_pct:
            raise ValueError(
                "ladder must be ordered most-approximate first: "
                f"{lo.name} saves {lo.power_saving_pct}% < "
                f"{hi.name} saves {hi.power_saving_pct}%")
    return rungs
