"""Declarative, serializable numerics configuration — the single entrypoint
for per-layer approximation across packing, serving, and sweeps.

The three-step contract::

    spec = get_preset("serve-default")            # or NumericsSpec(...)
    plan = spec.resolve(params)                   # inspectable assignment table
    packed = apply_numerics(params, plan, act_ranges=ranges)

Specs are ordered pattern rules (segment-anchored glob / regex on
parameter-tree paths) mapping to an ApproxPolicy, FLOAT, or a deferred
``auto(budget=...)`` search; they round-trip through JSON so the same
object travels in checkpoints, CLI flags, and engine metadata.  See
docs/numerics.md for the worked example.
"""

from repro.numerics.ladder import (DEFAULT_LADDER, LadderRung, ladder_spec,
                                   resolve_ladder)
from repro.numerics.plan import PackPlan, PlanEntry, apply_numerics
from repro.numerics.presets import (PRESETS, SERVE_FLOAT_RULES, get_preset,
                                    paper_grid_specs, uniform_spec)
from repro.numerics.spec import (FLOAT, Auto, NumericsSpec, Rule, auto,
                                 match_path)

__all__ = [
    "NumericsSpec",
    "Rule",
    "Auto",
    "auto",
    "FLOAT",
    "match_path",
    "PackPlan",
    "PlanEntry",
    "apply_numerics",
    "DEFAULT_LADDER",
    "LadderRung",
    "ladder_spec",
    "resolve_ladder",
    "PRESETS",
    "SERVE_FLOAT_RULES",
    "get_preset",
    "paper_grid_specs",
    "uniform_spec",
]
