"""Named NumericsSpec presets.

``get_preset(name, **kw)`` is the catalogue the CLI / ServeConfig /
benchmarks draw from:

  * ``serve-default`` — the production serving recipe: the documented
    keep-float rule-set below plus one uniform policy (paper default
    perforated m=2 + CV) everywhere else;
  * ``int8`` — same rule-set, exact int8 everywhere else (the paper's
    baseline array);
  * ``paper-grid`` — the serving rule-set with an ``auto(budget=...)``
    default: per-layer greedy assignment over the paper's Tables 2-4
    candidate grid at resolve time.

``paper_grid_specs()`` expands the same Tables 2-4 grid into one uniform
spec per (multiplier, m) point — the sweep form benchmarks iterate.
"""

from __future__ import annotations

import dataclasses

from repro.core.policy import INT8_EXACT, ApproxPolicy, Backend, paper_policies
from repro.numerics.spec import FLOAT, Auto, NumericsSpec, Rule

__all__ = [
    "SERVE_FLOAT_RULES",
    "PRESETS",
    "get_preset",
    "paper_grid_specs",
    "uniform_spec",
]


#: The serving keep-float rule-set (was the ``SERVE_SKIP`` substring list in
#: launch/serve.py).  Patterns are segment-anchored globs — ``*norm``
#: matches the ``attn_norm`` / ``q_norm`` / ``final_norm`` segments but NOT
#: a hypothetical ``denormalizer`` layer, which the old substring test
#: matched by accident.
SERVE_FLOAT_RULES: tuple[Rule, ...] = (
    Rule("embed*", FLOAT, note="token embedding: a lookup, not a GEMM"),
    Rule("router", FLOAT, note="MoE router: control logic stays exact"),
    Rule("kv_a", FLOAT, note="MLA latent down-proj: absorbed-decode einsum"),
    Rule("kv_b", FLOAT, note="MLA latent up-proj: absorbed-decode einsum"),
    Rule("*norm", FLOAT, note="norm scales: elementwise, no MAC array"),
    Rule("dt_proj", FLOAT, note="SSM dt projection: tiny, timestep-critical"),
    Rule("x_proj", FLOAT, note="SSM input mix: tiny low-rank projection"),
)


def serve_default(policy: ApproxPolicy | None = None) -> NumericsSpec:
    pol = policy if policy is not None else ApproxPolicy("perforated", 2,
                                                         use_cv=True)
    return NumericsSpec(name=f"serve-default[{pol.label()}]",
                        rules=SERVE_FLOAT_RULES, default=pol)


def int8() -> NumericsSpec:
    return NumericsSpec(name="int8", rules=SERVE_FLOAT_RULES,
                        default=INT8_EXACT)


def paper_grid(budget: float = 0.05) -> NumericsSpec:
    return NumericsSpec(name=f"paper-grid[auto<={budget}]",
                        rules=SERVE_FLOAT_RULES,
                        default=Auto(budget_rel_err=budget))


PRESETS = {
    "serve-default": serve_default,
    "int8": int8,
    "paper-grid": paper_grid,
}


def get_preset(name: str, **kwargs) -> NumericsSpec:
    """Build a named preset spec (kwargs are preset-specific, e.g.
    ``policy=`` for serve-default, ``budget=`` for paper-grid)."""
    try:
        builder = PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown numerics preset {name!r}; known: {sorted(PRESETS)}"
        ) from None
    return builder(**kwargs)


def uniform_spec(policy: ApproxPolicy | None,
                 rules: tuple[Rule, ...] = (),
                 name: str | None = None) -> NumericsSpec:
    """One policy everywhere (after ``rules``) — the spec form of the old
    ``uniform_policy`` helper."""
    label = "float" if policy is None else policy.label()
    return NumericsSpec(name=name or f"uniform[{label}]", rules=rules,
                        default=policy)


def paper_grid_specs(use_cv: bool = True, backend: Backend = "jnp",
                     rules: tuple[Rule, ...] = ()) -> list[NumericsSpec]:
    """The Tables 2-4 sweep: one uniform spec per (multiplier, m) grid
    point, in the paper's presentation order."""
    return [
        dataclasses.replace(uniform_spec(p, rules=rules),
                            name=f"paper-grid/{p.label()}")
        for p in paper_policies(use_cv=use_cv, backend=backend)
    ]
