"""PackPlan: the concrete, inspectable result of resolving a NumericsSpec.

A plan is the full per-layer assignment table — path, policy (or float),
which rule decided it, weight shape, packed size, and the modeled power
saving of the assigned MAC array — exactly what an operator audits before
shipping a numerics change.  ``apply_numerics`` executes a plan through the
existing :func:`~repro.core.approx_linear.pack_params` machinery, so a plan
applied is bit-identical to the legacy ``pack_params(uniform_policy(...))``
path for the same assignments.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

from repro.core.policy import ApproxPolicy

__all__ = ["PlanEntry", "PackPlan", "plan_entry", "apply_numerics"]


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One layer's resolved assignment."""

    path: str
    policy: ApproxPolicy | None  # None = layer stays float
    rule: str  # pattern that decided it (or "default")
    w_shape: tuple[int, ...]
    has_bias: bool
    packed_bytes: int  # serving footprint of the packed representation
    power_saving_pct: float  # modeled MAC-array power saving (cost_model)

    @property
    def label(self) -> str:
        return "float" if self.policy is None else self.policy.label()

    def to_dict(self) -> dict:
        return {
            "path": self.path,
            "policy": None if self.policy is None else self.policy.to_dict(),
            "rule": self.rule,
            "w_shape": list(self.w_shape),
            "has_bias": self.has_bias,
            "packed_bytes": self.packed_bytes,
            "power_saving_pct": self.power_saving_pct,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PlanEntry":
        pol = d.get("policy")
        return cls(
            path=d["path"],
            policy=None if pol is None else ApproxPolicy.from_dict(pol),
            rule=d["rule"],
            w_shape=tuple(int(x) for x in d["w_shape"]),
            has_bias=bool(d["has_bias"]),
            packed_bytes=int(d["packed_bytes"]),
            power_saving_pct=float(d["power_saving_pct"]),
        )


def _packed_bytes(w_shape: tuple[int, ...], policy: ApproxPolicy | None,
                  has_bias: bool, expert_stack: bool = False) -> int:
    """Serving bytes for one layer: float layers at f32, packed layers as
    uint8 codes + int32 column sums + float32 CV constants (+ bias).

    Packed layers additionally count their resident serving staging —
    everything the fast paths actually read at serving time, on top of the
    canonical pack:

      * pallas-backend single-CV layers: the OFFLINE-BLOCKED layout
        (repro.quant.BlockedPack — tile-padded codes, the aligned
        (EPI_ROWS, Nb) f32 epilogue table, the f32 meta vector);
      * jnp-backend single-CV layers at shallow fan-in: the FOLDED f32
        operands (repro.quant.build_fold — A, the mode's B slice, delta).
    """
    n_elem = math.prod(w_shape)
    if policy is None:
        return 4 * n_elem + (4 * w_shape[-1] if has_bias else 0)
    *lead, k, n = w_shape
    stacks = math.prod(lead) if lead else 1
    per_stack = 4 * n * (1 + 1 + policy.groups)  # sum_qw + c + c0
    if has_bias:
        per_stack += 4 * n
    total = n_elem + stacks * per_stack  # canonical uint8 pack
    if policy.backend == "pallas" and policy.is_approx and policy.groups == 1:
        from repro.quant.quantize import EPI_ROWS, META_LEN, serving_blocks

        bn, bk = serving_blocks(k, n)
        kb, nb = -(-k // bk) * bk, -(-n // bn) * bn
        total += stacks * (kb * nb + 4 * (EPI_ROWS * nb + META_LEN))
    elif not expert_stack:  # expert stacks never carry fold operands
        total += stacks * _fold_bytes(k, n, policy)
    return total


def _fold_bytes(k: int, n: int, policy: ApproxPolicy) -> int:
    """Bytes of the folded f32 serving operands (mirrors build_fold's
    eligibility and shapes: A (k, n), mode slice B, delta (n,))."""
    from repro.core.multipliers import _F32_EXACT_K

    if policy.groups != 1 or k > _F32_EXACT_K:
        return 0
    b_rows = 0
    if policy.is_approx:
        if policy.mode in ("perforated", "recursive"):
            b_rows = k
        elif policy.mode == "truncated":
            b_rows = policy.m * k + (k if policy.use_cv else 0)
    return 4 * ((k + b_rows) * n + n)


def plan_entry(path: str, node: dict, policy: ApproxPolicy | None,
               rule: str, n_array: int = 64) -> PlanEntry:
    """Build one entry from a linear-params leaf (real or abstract)."""
    from repro.core.cost_model import power_saving

    w_shape = tuple(int(s) for s in node["w"].shape)
    has_bias = node.get("b") is not None and "b" in node
    saving = (power_saving(policy.mode, policy.m, n_array)
              if policy is not None and policy.is_approx else 0.0)
    expert_stack = path.split("/")[-2:-1] == ["experts"]
    return PlanEntry(path=path, policy=policy, rule=rule, w_shape=w_shape,
                     has_bias=has_bias,
                     packed_bytes=_packed_bytes(w_shape, policy, has_bias,
                                                expert_stack=expert_stack),
                     power_saving_pct=round(saving, 2))


@dataclasses.dataclass(frozen=True)
class PackPlan:
    """The resolved assignment table for one parameter tree."""

    spec_name: str
    entries: tuple[PlanEntry, ...]

    def __post_init__(self):
        object.__setattr__(self, "entries", tuple(self.entries))

    # -- lookup --------------------------------------------------------------

    def policy_for(self, path: tuple[str, ...] | str) -> ApproxPolicy | None:
        joined = path if isinstance(path, str) else "/".join(path)
        for e in self.entries:
            if e.path == joined:
                return e.policy
        return None

    @property
    def packed(self) -> tuple[PlanEntry, ...]:
        return tuple(e for e in self.entries if e.policy is not None)

    @property
    def kept_float(self) -> tuple[PlanEntry, ...]:
        return tuple(e for e in self.entries if e.policy is None)

    @property
    def total_packed_bytes(self) -> int:
        return sum(e.packed_bytes for e in self.entries)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return {"spec_name": self.spec_name,
                "entries": [e.to_dict() for e in self.entries]}

    @classmethod
    def from_dict(cls, d: dict) -> "PackPlan":
        return cls(spec_name=d["spec_name"],
                   entries=tuple(PlanEntry.from_dict(e) for e in d["entries"]))

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "PackPlan":
        return cls.from_dict(json.loads(s))

    # -- reporting -----------------------------------------------------------

    def table(self) -> str:
        """Human-readable assignment table (the `plan` CLI output)."""
        rows = [("layer", "numerics", "rule", "w shape", "bytes", "power-%")]
        for e in self.entries:
            rows.append((e.path, e.label, e.rule,
                         "x".join(str(s) for s in e.w_shape),
                         f"{e.packed_bytes:,}",
                         f"-{e.power_saving_pct:.1f}" if e.power_saving_pct
                         else "0.0"))
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
                 for r in rows]
        lines.insert(1, "  ".join("-" * w for w in widths))
        lines.append(
            f"[{self.spec_name}] {len(self.packed)} packed / "
            f"{len(self.kept_float)} float layers, "
            f"{self.total_packed_bytes:,} bytes total")
        return "\n".join(lines)


def apply_numerics(params: Any, plan: PackPlan,
                   act_ranges: dict | None = None,
                   default_range: tuple[float, float] = (-8.0, 8.0),
                   strict: bool = True, fuse: bool = True,
                   fold: bool = True) -> Any:
    """Execute a plan: float params -> packed approximate params.

    With ``strict`` (default) the plan must cover exactly the packable
    layers of ``params`` — applying a plan resolved from a different
    architecture is an error, not a silent partial pack.

    ``fuse``/``fold`` pass through to
    :func:`~repro.core.approx_linear.pack_params`: disable fan-out fusion
    (keep member layers separate) or the folded f32 serving operands (keep
    every pack on the exact-integer path, no staging memory).
    """
    from repro.core.approx_linear import is_linear_params, pack_params

    want = {e.path: e.policy for e in plan.entries}
    if strict:
        have: set[str] = set()

        def walk(node: Any, path: tuple[str, ...]):
            if is_linear_params(node):
                have.add("/".join(path))
                return
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, path + (str(k),))
            elif isinstance(node, (list, tuple)):
                for i, v in enumerate(node):
                    walk(v, path + (str(i),))

        walk(params, ())
        if have != set(want):
            missing = sorted(set(want) - have)
            extra = sorted(have - set(want))
            raise ValueError(
                f"plan [{plan.spec_name}] does not match the parameter tree: "
                f"plan-only layers {missing[:5]}, unplanned layers {extra[:5]}")

    return pack_params(params, lambda p: want.get("/".join(p)),
                       act_ranges=act_ranges, default_range=default_range,
                       fuse=fuse, fold=fold)
