"""Per-layer approximation policies.

An :class:`ApproxPolicy` describes how one linear layer executes on the
(emulated) approximate MAC array: which multiplier family, its knob ``m``,
whether the control-variate correction V is added, how many CV groups
(beyond-paper extension), and which backend computes it.

Policies are static/hashable so jit can specialize on them; they travel with
packed parameters as pytree metadata.

This module is the *mechanism* layer.  The public way to choose policies
per layer is the declarative :mod:`repro.numerics` spec subsystem
(``NumericsSpec`` -> ``PackPlan`` -> ``apply_numerics``); the ``PolicyFn``
callables below are an internal detail of ``pack_params`` that specs lower
to.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

from repro.core.multipliers import APPROX_MODES, PAPER_M_RANGE, Mode

Backend = Literal["jnp", "pallas"]


@dataclasses.dataclass(frozen=True)
class ApproxPolicy:
    """Static per-layer approximation configuration."""

    mode: Mode = "exact"  # multiplier family ("exact" = plain int8)
    m: int = 0  # approximation knob (paper Sec. 2)
    use_cv: bool = True  # add the control variate V (the paper's technique)
    groups: int = 1  # >1 = grouped CV (beyond paper)
    backend: Backend = "jnp"

    def __post_init__(self):
        if self.mode != "exact" and not (0 <= self.m <= 8):
            raise ValueError(f"m={self.m} out of range for 8-bit codes")
        if self.groups < 1:
            raise ValueError("groups must be >= 1")

    @property
    def is_approx(self) -> bool:
        return self.mode != "exact" and self.m > 0

    def label(self) -> str:
        if not self.is_approx:
            return "int8-exact"
        cv = f"+cv(g={self.groups})" if self.use_cv else "-cv"
        return f"{self.mode}(m={self.m}){cv}"

    def to_dict(self) -> dict:
        """JSON-safe form (consumed by repro.numerics serialization)."""
        return {"mode": self.mode, "m": self.m, "use_cv": self.use_cv,
                "groups": self.groups, "backend": self.backend}

    @classmethod
    def from_dict(cls, d: dict) -> "ApproxPolicy":
        unknown = set(d) - {"mode", "m", "use_cv", "groups", "backend"}
        if unknown:
            raise ValueError(f"unknown ApproxPolicy fields {sorted(unknown)}")
        return cls(**d)


FLOAT = None  # sentinel: layer stays in float (not packed)
INT8_EXACT = ApproxPolicy("exact", 0)


def paper_policies(use_cv: bool = True, backend: Backend = "jnp") -> list[ApproxPolicy]:
    """The full grid the paper evaluates (Tables 2-4): three multipliers x
    their m ranges."""
    out = []
    for mode in APPROX_MODES:
        for m in PAPER_M_RANGE[mode]:
            out.append(ApproxPolicy(mode, m, use_cv=use_cv, backend=backend))
    return out


# A PolicyFn maps a parameter tree path (tuple of str keys) to a policy, or
# FLOAT/None to keep the layer in float.  Used by pack_params.  Internal:
# user-facing configuration goes through repro.numerics specs, which lower
# to a PolicyFn at apply time.
PolicyFn = Callable[[tuple[str, ...]], ApproxPolicy | None]


def uniform_policy(policy: ApproxPolicy | None, skip: tuple[str, ...] = ()) -> PolicyFn:
    """Apply one policy to every linear layer, except paths containing any of
    the ``skip`` substrings (e.g. first/last layers, router gates)."""

    def fn(path: tuple[str, ...]) -> ApproxPolicy | None:
        joined = "/".join(path)
        if any(s in joined for s in skip):
            return None
        return policy

    return fn


# ---------------------------------------------------------------------------
# Automatic per-layer policy search (beyond paper; ALWANN-flavoured)
# ---------------------------------------------------------------------------


def order_most_aggressive(candidates: list[ApproxPolicy]) -> list[ApproxPolicy]:
    """Candidates sorted most-aggressive-first by the analytic error sigma."""
    from repro.core.multipliers import analytic_error_moments_uniform

    return sorted(
        candidates,
        key=lambda p: analytic_error_moments_uniform(p.mode, p.m)[1],
        reverse=True,
    )


def greedy_assign(apply_fn, params, calib_inputs,
                  items: list[tuple[str, list[ApproxPolicy], float]],
                  act_ranges: dict | None = None) -> dict[str, ApproxPolicy]:
    """The greedy ALWANN-style per-layer assignment core (shared by
    :func:`auto_policy` and the ``auto(...)`` rule lowering in
    :mod:`repro.numerics`).

    ``items`` is ``[(path, candidates, budget_rel_err)]`` with candidates
    ordered most-aggressive-first (see :func:`order_most_aggressive`).  Per
    layer (independently), the first candidate whose model-output relative
    error on the calibration inputs fits the budget wins; layers too
    sensitive for any candidate fall back to exact int8.  Greedy-independent
    works because the CV keeps per-layer errors zero-mean, so sensitivities
    compose roughly additively at small errors.
    """
    import jax.numpy as jnp

    from repro.core.approx_linear import pack_params

    ref = apply_fn(params, calib_inputs)
    ref_scale = float(jnp.abs(ref).mean()) + 1e-12

    out: dict[str, ApproxPolicy] = {}
    for path, candidates, budget in items:
        chosen = INT8_EXACT
        for cand in candidates:
            one = pack_params(
                params,
                lambda p, path=path, cand=cand:
                    cand if "/".join(p) == path else None,
                act_ranges=act_ranges,
            )
            err = float(jnp.abs(apply_fn(one, calib_inputs) - ref).mean())
            if err / ref_scale <= budget:
                chosen = cand
                break
        out[path] = chosen
    return out


def auto_policy(
    apply_fn,
    params,
    calib_inputs,
    *,
    candidates: list[ApproxPolicy] | None = None,
    budget_rel_err: float = 0.05,
    skip: tuple[str, ...] = (),
    act_ranges: dict | None = None,
):
    """Greedy per-layer approximation assignment.

    For each packable linear layer (independently), measure the model-output
    relative error of every candidate policy against the float model on the
    calibration inputs, and keep the MOST AGGRESSIVE candidate whose error
    stays under ``budget_rel_err``; layers too sensitive for any candidate
    fall back to exact int8.  Greedy-independent is the ALWANN-style
    heuristic: per-layer sensitivities compose roughly additively at small
    errors (the CV keeps per-layer errors zero-mean, which is what makes the
    additive approximation work well here).

    Returns (policy_map: path -> ApproxPolicy, report rows).
    """
    from repro.core.approx_linear import pack_params, packed_layer_paths

    candidates = order_most_aggressive(candidates or paper_policies(use_cv=True))

    # enumerate packable layer paths
    probe = pack_params(params, uniform_policy(INT8_EXACT, skip=skip),
                        act_ranges=act_ranges)
    paths = packed_layer_paths(probe)
    policy_map = greedy_assign(
        apply_fn, params, calib_inputs,
        [(path, candidates, budget_rel_err) for path in paths],
        act_ranges=act_ranges)
    rows = [{"layer": path, "policy": policy_map[path].label()}
            for path in paths]

    def fn(p: tuple[str, ...]):
        return policy_map.get("/".join(p))

    return fn, rows
