"""The paper's primary contribution: approximate-multiplier numerics and the
control-variate correction, as composable JAX building blocks.

  multipliers.py      bit-exact AM_P / AM_R / AM_T emulation (elementwise +
                      MXU bit-slice matmul forms) and analytic error moments
  control_variate.py  the CV statistics/constants and the corrected matmul
  approx_linear.py    the approximation-aware linear op used by every model
  policy.py           per-layer approximation policies + auto-policy search
  cost_model.py       MAC-array power/area model (paper Figs. 7-9, Table 5)
"""

from repro.core import multipliers
from repro.core import control_variate

__all__ = ["multipliers", "control_variate"]
