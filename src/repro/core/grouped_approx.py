"""The paper's technique for MoE expert GEMMs: grouped (ragged) approximate
matmuls with PER-EXPERT quantization scales and control-variate constants.

`pack_params` on a stacked (E, k, n) expert weight leaf already produces
per-expert codes/scales/CV constants (vmapped pack).  This module executes
the expert-sorted token buffer against them:

    rows sorted by expert, group_sizes (E,)
    -> per-row expert id -> per-row activation scale/zero-point
    -> bit-slice approximate ragged_dot (exact int32 algebra, same
       identities as core.multipliers)
    -> rank-1 CV correction with the ROW'S OWN expert's (C, C0)
    -> exact per-row zero-point corrections

This is the `_expert_ffn_sorted` fast path used by repro.nn.moe when the
expert stacks are packed (approximate serving of MoE architectures —
DESIGN.md §Arch-applicability's "per-expert CV constants").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import control_variate as cvlib
from repro.core import multipliers as am
from repro.core.approx_linear import QuantizedDense


def _row_expert_ids(group_sizes: jax.Array, m_rows: int) -> jax.Array:
    """group_sizes (E,) -> (M,) expert id per sorted row."""
    e = group_sizes.shape[0]
    return jnp.repeat(jnp.arange(e), group_sizes, total_repeat_length=m_rows)


def _ragged_int_dot(a, w, group_sizes) -> jax.Array:
    """Exact grouped integer matmul: (M, k) x (E, k, n) -> (M, n) int32."""
    return jax.lax.ragged_dot(
        a.astype(jnp.int32), w.astype(jnp.int32), group_sizes,
        preferred_element_type=jnp.int32)


def _approx_ragged(a_i32, w_q, group_sizes, mode: str, m: int) -> jax.Array:
    """sum_k AM(w, a) via the bit-slice identities, ragged over experts."""
    if mode == "exact" or m == 0:
        return _ragged_int_dot(a_i32, w_q, group_sizes)
    mask = (1 << m) - 1
    if mode == "perforated":
        return _ragged_int_dot(a_i32 - (a_i32 & mask), w_q, group_sizes)
    if mode == "recursive":
        return (_ragged_int_dot(a_i32, w_q, group_sizes)
                - _ragged_int_dot(a_i32 & mask,
                                  jnp.asarray(w_q, jnp.int32) & mask, group_sizes))
    if mode == "truncated":
        acc = _ragged_int_dot(a_i32, w_q, group_sizes)
        planes_a = jnp.concatenate(
            [((a_i32 >> i) & 1) << i for i in range(m)], axis=-1)
        planes_w = jnp.concatenate(
            [jnp.asarray(w_q, jnp.int32) & ((1 << (m - i)) - 1) for i in range(m)],
            axis=1)
        return acc - _ragged_int_dot(planes_a, planes_w, group_sizes)
    raise ValueError(mode)


def grouped_quantized_dense(qd: QuantizedDense, xs: jax.Array,
                            group_sizes: jax.Array) -> jax.Array:
    """Approximate quantized grouped linear.  xs: (M, k) sorted by expert;
    qd.pack leaves are stacked (E, ...).  Returns (M, n) float32."""
    pol = qd.policy
    pack = qd.pack
    m_rows, k = xs.shape
    ids = _row_expert_ids(group_sizes, m_rows)

    # per-row activation quantization with the row's expert's parameters
    a_scale = qd.a_qp.scale[ids][:, None]
    a_zp = qd.a_qp.zero_point[ids][:, None].astype(jnp.float32)
    a_q = jnp.clip(jnp.round(xs.astype(jnp.float32) / a_scale)
                   + a_zp, 0, 255).astype(jnp.int32)

    acc = _approx_ragged(a_q, pack.w_q, group_sizes, pol.mode, pol.m
                         ).astype(jnp.float32)
    if pol.use_cv and pol.mode != "exact" and pol.m > 0:
        sx = cvlib.sum_x(a_q, pol.mode, pol.m, axis=-1).astype(jnp.float32)
        acc = acc + sx[:, None] * pack.c[ids] + pack.c0[ids]

    # exact zero-point corrections (per-row expert constants)
    sum_qa = jnp.sum(a_q, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    zw = pack.w_zp[ids][:, None].astype(jnp.float32)
    acc = (acc - zw * sum_qa[:, None]
           - a_zp * pack.sum_qw[ids].astype(jnp.float32)
           + k * a_zp * zw)
    y = acc * (a_scale * pack.w_scale[ids][:, None])
    if pack.bias is not None:
        y = y + pack.bias[ids]
    return y


def grouped_quantized_swiglu(experts: dict, xs: jax.Array,
                             group_sizes: jax.Array) -> jax.Array:
    """swiglu over packed expert stacks: silu(gate(x)) * up(x) -> down."""
    g = grouped_quantized_dense(experts["gate"], xs, group_sizes)
    u = grouped_quantized_dense(experts["up"], xs, group_sizes)
    h = (jax.nn.silu(g) * u).astype(xs.dtype)
    return grouped_quantized_dense(experts["down"], h, group_sizes).astype(xs.dtype)
