"""Bit-exact models of the paper's approximate multipliers (Sec. 2).

All functions operate on *unsigned 8-bit codes* held in int32 arrays (the
gemmlowp/TFApprox convention: quantized weights/activations are uint8 codes,
products accumulate in int32).  Three multiplier families, each parameterized
by its approximation knob ``m``:

  perforated  AM_P (Eq. 2):  the m least-significant partial products of A are
              omitted (s = 0 per the paper).  Error (Eq. 3):
              eps = W * (A mod 2^m).
  recursive   AM_R (Eq. 5):  the low x low sub-product is pruned.  Error
              (Eq. 6): eps = (W mod 2^m) * (A mod 2^m).
  truncated   AM_T (Eq. 7):  the m least-significant columns of the partial
              product matrix are removed.  Error (Eq. 8):
              eps = sum_{i<m} (W mod 2^{m-i}) * a_i * 2^i.

Two computational forms are provided and tested for exact int32 equality:

  * elementwise  — the scalar hardware definition (oracle form);
  * matmul       — the bit-slice algebra used on TPU so the MXU still runs
                   exact integer matmuls (DESIGN.md Sec. 2b).

Analytic error moments (mean/variance) back the paper's Table 1 and the
control-variate derivations; they are exact for independent uniform codes and
numerically integrated for arbitrary code distributions.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

Mode = Literal["perforated", "truncated", "recursive", "exact"]

#: All approximation modes implemented in this framework (order is the order
#: the paper presents them in Sec. 2).
APPROX_MODES: tuple[str, ...] = ("perforated", "recursive", "truncated")

#: Paper-evaluated m ranges per multiplier (Sec. 5).
PAPER_M_RANGE = {
    "perforated": (1, 2, 3),
    "recursive": (2, 3, 4),
    "truncated": (5, 6, 7),
}

NBITS = 8  # the paper's accelerator multiplies 8-bit codes


def _as_i32(x) -> jax.Array:
    return jnp.asarray(x, dtype=jnp.int32)


def low_bits(x, m: int) -> jax.Array:
    """``x mod 2^m`` for non-negative codes (bitwise AND with the low mask)."""
    if m <= 0:
        return jnp.zeros_like(_as_i32(x))
    return _as_i32(x) & ((1 << m) - 1)


def high_part(x, m: int) -> jax.Array:
    """``x - (x mod 2^m)``: the code with its m LSBs zeroed."""
    return _as_i32(x) - low_bits(x, m)


def bit(x, i: int) -> jax.Array:
    """Bit i of the code, as int32 in {0, 1}."""
    return (_as_i32(x) >> i) & 1


# ---------------------------------------------------------------------------
# Elementwise (scalar hardware definition) forms
# ---------------------------------------------------------------------------


def am_exact(w, a) -> jax.Array:
    """The exact 8x8 product (reference MAC)."""
    return _as_i32(w) * _as_i32(a)


def am_perforated(w, a, m: int) -> jax.Array:
    """AM_P (Eq. 2) with s=0: omit the m least partial products of A.

    Equivalent closed form: W * (A - A mod 2^m).
    """
    return _as_i32(w) * high_part(a, m)


def am_recursive(w, a, m: int) -> jax.Array:
    """AM_R (Eq. 5): prune the W_L x A_L sub-product (m-bit low parts)."""
    return am_exact(w, a) - low_bits(w, m) * low_bits(a, m)


def am_truncated(w, a, m: int) -> jax.Array:
    """AM_T (Eq. 7): remove the m least-significant partial-product columns.

    Implemented as exact product minus the Eq. 8 error term; bit-level
    equivalence with the explicit partial-product-matrix definition is
    asserted in tests (tests/test_multipliers.py).
    """
    return am_exact(w, a) - err_truncated(w, a, m)


def err_perforated(w, a, m: int) -> jax.Array:
    """Eq. 3: eps = W * p,  p = A mod 2^m."""
    return _as_i32(w) * low_bits(a, m)


def err_recursive(w, a, m: int) -> jax.Array:
    """Eq. 6: eps = W_L * A_L."""
    return low_bits(w, m) * low_bits(a, m)


def err_truncated(w, a, m: int) -> jax.Array:
    """Eq. 8: eps = sum_{i=0}^{m-1} (W mod 2^{m-i}) * a_i * 2^i."""
    w = _as_i32(w)
    a = _as_i32(a)
    err = jnp.zeros(jnp.broadcast_shapes(w.shape, a.shape), dtype=jnp.int32)
    for i in range(m):
        err = err + low_bits(w, m - i) * bit(a, i) * (1 << i)
    return err


def am_truncated_ppmatrix(w, a, m: int) -> jax.Array:
    """AM_T from first principles: sum partial-product bits with i+j >= m.

    This is the literal hardware definition (the AND gates w_j & a_i with
    i + j < m are not implemented).  O(n^2) bit ops — used only as a test
    oracle for :func:`am_truncated`.
    """
    w = _as_i32(w)
    a = _as_i32(a)
    acc = jnp.zeros(jnp.broadcast_shapes(w.shape, a.shape), dtype=jnp.int32)
    for i in range(NBITS):
        for j in range(NBITS):
            if i + j >= m:
                acc = acc + (bit(w, j) * bit(a, i)) * (1 << (i + j))
    return acc


_ELEMENTWISE = {
    "exact": lambda w, a, m: am_exact(w, a),
    "perforated": am_perforated,
    "recursive": am_recursive,
    "truncated": am_truncated,
}

_ERROR = {
    "exact": lambda w, a, m: jnp.zeros(
        jnp.broadcast_shapes(jnp.shape(w), jnp.shape(a)), jnp.int32
    ),
    "perforated": err_perforated,
    "recursive": err_recursive,
    "truncated": err_truncated,
}


def am(w, a, mode: Mode, m: int) -> jax.Array:
    """Dispatch: approximate product of uint8 codes under ``mode``/``m``."""
    return _ELEMENTWISE[mode](w, a, m)


def am_error(w, a, mode: Mode, m: int) -> jax.Array:
    """Dispatch: multiplication error ``w*a - AM(w, a)``."""
    return _ERROR[mode](w, a, m)


# ---------------------------------------------------------------------------
# Matmul-algebra (MXU) forms — exact bit-slice decompositions
# ---------------------------------------------------------------------------


#: Largest contraction depth for which a product of two uint8 codes summed in
#: float32 is still exact: every partial sum of k products bounded by 255*255
#: stays below 2^24, so each f32 addition is exact regardless of order.
_F32_EXACT_K = (1 << 24) // (255 * 255)  # 258


def _int_matmul(a, w) -> jax.Array:
    """Exact integer matmul with int32 accumulation: (..., k) @ (k, n).

    For shallow contractions (k <= 258) the dot runs on the float32 unit
    instead: all operands are uint8-code-bounded integers, so every partial
    sum stays below 2^24 and the f32 result is the exact integer — bit-for-bit
    identical to the int32 dot, but an order of magnitude faster on CPU
    backends whose int32 GEMM is scalar.  (On TPU both land on the MXU.)
    """
    if w.shape[0] <= _F32_EXACT_K:
        out = jax.lax.dot_general(
            jnp.asarray(a, jnp.float32),
            jnp.asarray(w, jnp.float32),
            dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
            # exactness needs TRUE f32 multiplies: TPU's default bf16-pass
            # dot would round 16-bit products and break bit-identity
            precision=jax.lax.Precision.HIGHEST,
        )
        return out.astype(jnp.int32)
    return jax.lax.dot_general(
        _as_i32(a),
        _as_i32(w),
        dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def truncated_error_planes(w, m: int) -> jax.Array:
    """Precomputed weight error planes for AM_T: plane[i] = W mod 2^{m-i}.

    Shape (m, *w.shape).  These live in the quantized parameter pack so the
    runtime only extracts activation bitplanes.
    """
    if m == 0:
        return jnp.zeros((0,) + jnp.shape(w), jnp.int32)
    return jnp.stack([low_bits(w, m - i) for i in range(m)])


def approx_matmul_ref(a, w, mode: Mode, m: int) -> jax.Array:
    """Oracle: sum of elementwise AM products.  a: (..., k), w: (k, n).

    O(B*K*N) memory — test-scale shapes only.
    """
    a_e = _as_i32(a)[..., :, None]  # (..., k, 1)
    w_e = _as_i32(w)[None, :, :] if w.ndim == 2 else _as_i32(w)
    prod = am(w_e, a_e, mode, m)  # (..., k, n)
    return jnp.sum(prod, axis=-2, dtype=jnp.int32)


def approx_matmul(a, w, mode: Mode, m: int) -> jax.Array:
    """Exact bit-slice matmul form of sum_k AM(w[k, n], a[..., k]).

    perforated: A_hi @ W                          (1 matmul)
    recursive : A @ W - A_lo @ W_lo               (2 matmuls)
    truncated : A @ W - sum_i 2^i bit_i(A) @ (W mod 2^{m-i})   (1 + m matmuls)
    exact     : A @ W

    All matmuls are exact int32; results match :func:`approx_matmul_ref`
    bit-for-bit.
    """
    if mode == "exact" or m == 0:
        return _int_matmul(a, w)
    if mode == "perforated":
        return _int_matmul(high_part(a, m), w)
    if mode == "recursive":
        return _int_matmul(a, w) - _int_matmul(low_bits(a, m), low_bits(w, m))
    if mode == "truncated":
        acc = _int_matmul(a, w)
        # Batch the m thin bitplane matmuls into one matmul on a widened
        # contraction axis: concat bitplanes of A along k, concat scaled
        # error planes of W along k.
        planes_a = jnp.concatenate([bit(a, i) << i for i in range(m)], axis=-1)
        planes_w = jnp.concatenate([low_bits(w, m - i) for i in range(m)], axis=0)
        return acc - _int_matmul(planes_a, planes_w)
    raise ValueError(f"unknown mode: {mode}")


# ---------------------------------------------------------------------------
# Analytic error moments (Table 1 math + CV derivations)
# ---------------------------------------------------------------------------


def _uniform_code_moments(nbits: int = NBITS) -> tuple[float, float]:
    """Mean and second moment of U{0, ..., 2^nbits - 1}."""
    n = float(2**nbits)
    mean = (n - 1) / 2.0
    second = (n - 1) * (2 * n - 1) / 6.0
    return mean, second


def _mod_moments_uniform(nbits: int, m: int) -> tuple[float, float]:
    """Mean/second moment of (X mod 2^m) for X ~ U{0..2^nbits-1}, m<=nbits."""
    return _uniform_code_moments(m)


def analytic_error_moments_uniform(mode: Mode, m: int) -> tuple[float, float]:
    """(mu, sigma) of the multiplier error for i.i.d. U{0..255} operands.

    Closed forms from Eqs. 3/6/8 with independent uniform W, A — these are the
    numbers the paper's Table 1 measures empirically with 1M samples.
    """
    if mode == "exact" or m == 0:
        return 0.0, 0.0
    ew, ew2 = _uniform_code_moments(NBITS)
    if mode == "perforated":
        ep, ep2 = _mod_moments_uniform(NBITS, m)
        mu = ew * ep
        var = ew2 * ep2 - mu * mu
        return mu, float(np.sqrt(var))
    if mode == "recursive":
        el, el2 = _mod_moments_uniform(NBITS, m)
        mu = el * el
        var = el2 * el2 - mu * mu
        return mu, float(np.sqrt(var))
    if mode == "truncated":
        # eps = sum_i (W mod 2^{m-i}) a_i 2^i.  The a_i are independent
        # Bernoulli(1/2) for uniform A, and (W mod 2^{m-i}) terms share W, so
        # compute moments by exhausting W (256 values) with a_i independent.
        w = np.arange(256)
        terms = [((w % (1 << (m - i))) * (1 << i)).astype(np.float64) for i in range(m)]
        # E over a: each a_i ~ B(1/2) independent; E over w: uniform.
        mu_w = sum(0.5 * t for t in terms)  # E[eps | W]
        var_w = sum(0.25 * t * t for t in terms)  # Var[eps | W]
        mu = float(mu_w.mean())
        var = float(var_w.mean() + mu_w.var())
        return mu, float(np.sqrt(var))
    raise ValueError(f"unknown mode: {mode}")


def empirical_error_moments(
    mode: Mode,
    m: int,
    w_codes: np.ndarray,
    a_codes: np.ndarray,
) -> tuple[float, float]:
    """Empirical (mu, sigma) of the error over given code samples."""
    err = np.asarray(am_error(w_codes, a_codes, mode, m))
    return float(err.mean()), float(err.std())


@functools.lru_cache(maxsize=None)
def error_mean_per_weight_uniform_a(mode: Mode, m: int) -> np.ndarray:
    """E_A[eps | W = w] for all 256 codes w, A ~ U{0..255}.

    Used by the control-variate module for analytic validation; Eq. 23 for
    truncated, W * E[A mod 2^m] for perforated, (W mod 2^m) * E[A_L] for
    recursive.
    """
    w = np.arange(256, dtype=np.float64)
    if mode == "exact" or m == 0:
        return np.zeros(256)
    if mode == "perforated":
        return w * ((1 << m) - 1) / 2.0
    if mode == "recursive":
        return (np.arange(256) % (1 << m)) * ((1 << m) - 1) / 2.0
    if mode == "truncated":
        acc = np.zeros(256)
        for i in range(m):
            acc += (np.arange(256) % (1 << (m - i))) * (1 << i)
        return acc / 2.0  # E[a_i] = 1/2
    raise ValueError(f"unknown mode: {mode}")
