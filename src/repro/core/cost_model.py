"""Analytical MAC-array area/power model (paper Sec. 4-5.1, Figs. 7-9, Table 5).

The paper synthesizes N x N systolic arrays at 14nm and reports power/area of
the approximate+CV arrays normalized to the exact array.  Silicon synthesis is
impossible in this container, so we reproduce those tables with a
component-count cost model of the microarchitecture the paper describes:

  MAC   (exact):   8x8 multiplier (64 pp bits, reduction tree, 16b CPA) +
                   W_acc-bit accumulator adder + pipeline FFs,
                   W_acc = ceil(log2(N * (2^16 - 1))).
  MAC*  (approx):  multiplier with pruned pp bits (per multiplier family and
                   m), accumulator reduced by m bits, PLUS the sumX path:
                   perforated/recursive — ceil(log2(N*(2^m-1)))-bit adder+FFs;
                   truncated — m-input OR + ceil(log2 N)-bit adder+FFs.
  MAC+  (CV col):  exact multiplier of width (sumX bits x 8) + W_acc adder
                   + FFs (one column of N units, Sec. 4.4).

Partial-product bits removed:  perforated m -> 8m;  truncated m ->
m(m+1)/2;  recursive m -> m^2.  Reduction-tree compressor count scales with
pp bits; final CPA width is 16 - m for all three families (Sec. 4.1-4.3).

Unit energies/areas (AND gate, FA in tree, CPA bit, FF bit, OR input) are
the model's free parameters, least-squares calibrated ONCE against the
paper's reported power/area percentages (constants below quote the paper
text; Fig. 9's per-point recursive values are stated as ranges/averages in
the text, so its midpoints are annotated as inferred).  The calibration and
model-vs-paper deltas are printed by benchmarks/fig7_9_power.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np

from repro.core.multipliers import Mode

ACC_BITS_FULL = 16  # product width of the exact 8x8 multiplier


def _clog2(x: float) -> int:
    return int(math.ceil(math.log2(x)))


def pp_bits_removed(mode: Mode, m: int) -> int:
    if mode == "exact" or m == 0:
        return 0
    if mode == "perforated":
        return 8 * m
    if mode == "truncated":
        return m * (m + 1) // 2
    if mode == "recursive":
        return m * m
    raise ValueError(mode)


@dataclasses.dataclass(frozen=True)
class UnitCosts:
    """Relative cost of primitive components (calibrated), plus family
    activity factors.

    For POWER the removed partial-product hardware is weighted by a
    per-family activity factor: perforation removes entire high-toggle pp
    *rows* (and their reduction-tree glitching, plus iso-delay gate
    downsizing from the shortened tree — Sec. 4.4's "delay slack ... boosts
    further the area and power savings"), truncation removes the glitchiest
    low-significance *columns*, recursion removes a square low x low block.
    For AREA all activity factors are 1 (area is purely structural).
    ``plus_activity`` discounts the MAC+ column (C operand is static per
    filter, so its multiplier toggles far less — calibrated to Table 5).
    """

    and_gate: float  # pp generation AND
    fa: float  # compressor/full-adder in reduction tree
    cpa_bit: float  # carry-propagate adder bit
    ff: float  # flip-flop bit
    or_in: float  # OR-gate input (truncated x_j)
    act_perforated: float = 1.0  # activity weight of removed pp hardware
    act_truncated: float = 1.0
    act_recursive: float = 1.0
    plus_activity: float = 1.0  # MAC+ switching discount

    def activity(self, mode: Mode) -> float:
        return {
            "perforated": self.act_perforated,
            "truncated": self.act_truncated,
            "recursive": self.act_recursive,
            "exact": 1.0,
        }[mode]


@dataclasses.dataclass(frozen=True)
class UnitBreakdown:
    mult: float
    acc_adder: float
    sumx: float
    ffs: float

    @property
    def total(self) -> float:
        return self.mult + self.acc_adder + self.sumx + self.ffs


def mac_cost(mode: Mode, m: int, n_array: int, u: UnitCosts,
             with_cv: bool = True) -> UnitBreakdown:
    """Cost of one MAC (exact) or MAC* (approx) processing element.

    The exact-MAC cost is computed with activity 1; the approximate MAC's
    *removed* hardware is credited at the family activity weight (>=1 means
    the removed bits were hotter than average — see UnitCosts docstring).
    """
    w_acc = _clog2(n_array * (2**ACC_BITS_FULL - 1))
    removed = pp_bits_removed(mode, m) * u.activity(mode)
    pp = max(64.0 - removed, 0.0)
    prod_bits = ACC_BITS_FULL if mode == "exact" or m == 0 else ACC_BITS_FULL - m
    mult = u.and_gate * pp + u.fa * max(pp - prod_bits, 0) + u.cpa_bit * prod_bits
    acc = u.cpa_bit * (w_acc - (ACC_BITS_FULL - prod_bits))
    # pipeline FFs: product reg + accumulator reg
    ffs = u.ff * (prod_bits + w_acc)
    sumx = 0.0
    if with_cv and mode != "exact" and m > 0:
        if mode in ("perforated", "recursive"):
            sx_bits = _clog2(n_array * (2**m - 1))
            sumx = u.cpa_bit * sx_bits * 0.5 + u.ff * sx_bits  # ripple-carry: 0.5x
        else:  # truncated: m-input OR + log2(N) counter
            sx_bits = _clog2(n_array)
            sumx = u.or_in * m + u.cpa_bit * sx_bits * 0.5 + u.ff * sx_bits
    return UnitBreakdown(mult=mult, acc_adder=acc, sumx=sumx, ffs=ffs)


def mac_plus_cost(mode: Mode, m: int, n_array: int, u: UnitCosts) -> UnitBreakdown:
    """Cost of one MAC+ unit (the extra CV column, Sec. 4.4).

    The whole unit is scaled by ``plus_activity``: the C operand is a
    per-filter constant, so the multiplier's switching is far below a MAC*'s
    (for area calibration plus_activity stays 1).
    """
    w_acc = _clog2(n_array * (2**ACC_BITS_FULL - 1))
    if mode in ("perforated", "recursive"):
        mul_w = _clog2(n_array * (2**m - 1))
    else:
        mul_w = _clog2(n_array)
    pp = mul_w * 8
    s = u.plus_activity
    mult = s * (u.and_gate * pp + u.fa * max(pp - (mul_w + 8), 0) + u.cpa_bit * (mul_w + 8))
    acc = s * u.cpa_bit * w_acc
    ffs = s * u.ff * (w_acc + mul_w + 8)
    return UnitBreakdown(mult=mult, acc_adder=acc, sumx=0.0, ffs=ffs)


def array_cost(mode: Mode, m: int, n_array: int, u: UnitCosts,
               with_cv: bool = True) -> float:
    """Total cost of the N x N (+1 CV column) array."""
    pe = mac_cost(mode, m, n_array, u, with_cv=with_cv).total * n_array * n_array
    plus = (
        mac_plus_cost(mode, m, n_array, u).total * n_array
        if with_cv and mode != "exact" and m > 0
        else 0.0
    )
    return pe + plus


def normalized_cost(mode: Mode, m: int, n_array: int, u: UnitCosts,
                    with_cv: bool = True) -> float:
    """Approximate-array cost normalized to the exact array (paper's y-axis)."""
    return array_cost(mode, m, n_array, u, with_cv) / array_cost(
        "exact", 0, n_array, u, with_cv=False
    )


def mac_plus_fraction(mode: Mode, m: int, n_array: int, u: UnitCosts) -> float:
    """Table 5: MAC+ share of total array cost (percent)."""
    plus = mac_plus_cost(mode, m, n_array, u).total * n_array
    return 100.0 * plus / array_cost(mode, m, n_array, u, with_cv=True)


# ---------------------------------------------------------------------------
# Paper-reported savings (percent power/area reduction vs exact array).
# Midpoints of the ranges given in Sec. 5.1; entries marked inferred=True are
# reconstructed from textual averages/maxima because the figure axis values
# are not in the text.
# ---------------------------------------------------------------------------

PAPER_POWER_SAVINGS: dict[tuple[str, int], float] = {
    ("perforated", 1): 28.45,
    ("perforated", 2): 35.10,
    ("perforated", 3): 45.25,
    ("truncated", 5): 24.45,
    ("truncated", 6): 31.80,
    ("truncated", 7): 40.15,
    ("recursive", 2): 9.0,  # inferred: avg 17%, max 26% over m in [2,4]
    ("recursive", 3): 17.0,  # inferred
    ("recursive", 4): 25.0,  # inferred
}

PAPER_AREA_SAVINGS: dict[tuple[str, int], float] = {
    ("perforated", 1): 1.0,  # "almost the same as the accurate MAC"
    ("perforated", 2): 10.0,  # average 10%
    ("perforated", 3): 21.0,  # up to 22%
    ("truncated", 5): 23.0,  # avg 31%, max 39% at m=7 (inferred spread)
    ("truncated", 6): 31.0,
    ("truncated", 7): 38.0,
    ("recursive", 2): -7.0,  # m=2: overhead (up to -14% at N=16)
    ("recursive", 3): 2.0,  # inferred
    ("recursive", 4): 7.0,  # max 8%
}


#: Table 5 (power %, perforated) — MAC+ share of total array power, used to
#: calibrate ``plus_activity``.
PAPER_TABLE5_POWER_PERF = {
    (1, 16): 1.22, (1, 32): 0.63, (1, 48): 0.43, (1, 64): 0.32,
    (2, 16): 1.32, (2, 32): 0.68, (2, 48): 0.46, (2, 64): 0.35,
    (3, 16): 1.52, (3, 32): 0.80, (3, 48): 0.53, (3, 64): 0.40,
}
PAPER_TABLE5_AREA_PERF = {
    (1, 16): 1.07, (1, 32): 0.55, (1, 48): 0.38, (1, 64): 0.28,
    (2, 16): 1.18, (2, 32): 0.61, (2, 48): 0.41, (2, 64): 0.31,
    (3, 16): 1.36, (3, 32): 0.71, (3, 48): 0.47, (3, 64): 0.36,
}


def _calibrate(
    target: dict[tuple[str, int], float],
    table5: dict[tuple[int, int], float],
    fit_activity: bool,
    n_array: int = 64,
) -> UnitCosts:
    """Least-squares fit of unit costs (+ optional activity factors) to the
    paper's normalized savings, then ``plus_activity`` to Table 5.

    Coordinate-descent keeps it dependency-free (no scipy).
    """
    pts = list(target.items())

    def loss(u: UnitCosts) -> float:
        err = 0.0
        for (mode, m), saving in pts:
            model = normalized_cost(mode, m, n_array, u)
            err += (model - (1.0 - saving / 100.0)) ** 2
        return err

    fields = ["and_gate", "fa", "cpa_bit", "ff", "or_in"]
    if fit_activity:
        fields += ["act_perforated", "act_truncated", "act_recursive"]

    u = UnitCosts(0.5, 3.0, 2.0, 1.0, or_in=0.3)
    best_l = loss(u)
    step = 0.5
    for _ in range(400):
        improved = False
        for field in fields:
            for d in (+step, -step):
                cand = dataclasses.replace(
                    u, **{field: min(max(getattr(u, field) + d, 0.01), 8.0)}
                )
                l = loss(cand)
                if l < best_l:
                    u, best_l, improved = cand, l, True
        if not improved:
            step *= 0.5
            if step < 1e-3:
                break

    # Second stage: plus_activity against Table 5 (closed-form-ish scan).
    def t5_loss(u: UnitCosts) -> float:
        err = 0.0
        for (m, n), frac in table5.items():
            err += (mac_plus_fraction("perforated", m, n, u) - frac) ** 2
        return err

    best_pa, best = 1.0, float("inf")
    for pa in np.linspace(0.02, 1.5, 149):
        cand = dataclasses.replace(u, plus_activity=float(pa))
        l = t5_loss(cand)
        if l < best:
            best_pa, best = float(pa), l
    return dataclasses.replace(u, plus_activity=best_pa)


_POWER_UNITS: UnitCosts | None = None
_AREA_UNITS: UnitCosts | None = None


def power_units() -> UnitCosts:
    global _POWER_UNITS
    if _POWER_UNITS is None:
        _POWER_UNITS = _calibrate(
            PAPER_POWER_SAVINGS, PAPER_TABLE5_POWER_PERF, fit_activity=True
        )
    return _POWER_UNITS


def area_units() -> UnitCosts:
    global _AREA_UNITS
    if _AREA_UNITS is None:
        _AREA_UNITS = _calibrate(
            PAPER_AREA_SAVINGS, PAPER_TABLE5_AREA_PERF, fit_activity=False
        )
    return _AREA_UNITS


def power_saving(mode: Mode, m: int, n_array: int) -> float:
    """Modeled % power reduction of the CV array vs the exact array."""
    return 100.0 * (1.0 - normalized_cost(mode, m, n_array, power_units()))


def area_saving(mode: Mode, m: int, n_array: int) -> float:
    return 100.0 * (1.0 - normalized_cost(mode, m, n_array, area_units()))
