"""Control-variate approximation (the paper's Sec. 3).

The convolution/GEMM computed on the approximate array is

    G* = B + sum_j AM(W_j, A_j) + V,      V = C * sum_j x_j + C0     (13)-(15)

with the per-multiplier choices (all derived in the paper, reproduced here):

  perforated (Sec. 3.1):  x_j = A_j mod 2^m,           C = E_j[W_j],      C0 = 0
  truncated  (Sec. 3.2):  x_j = OR(A_j[m-1:0]),        C = E_j[W_hat_j],
                          C0 = 2^-m sum_j W_hat_j   (folded into the bias)
  recursive  (Sec. 3.3):  x_j = A_j mod 2^m,           C = E_j[W_j mod 2^m], C0 = 0

where W_hat = 1/2 sum_{i<m} (W mod 2^{m-i}) 2^i (Eq. 24).  The expectation
E_j[.] runs over the reduction (fan-in) axis of each output neuron, so C and
C0 are per-output-channel vectors computed OFFLINE from the weight codes; the
only runtime statistic is the scalar-per-row reduction sum_j x_j — the paper's
MAC+ column, i.e. a rank-1 epilogue on TPU (DESIGN.md Sec. 2a).

Everything here operates on uint8 codes held in int32, matching
:mod:`repro.core.multipliers`.

Beyond-paper extension: *grouped* control variates (``groups > 1``) split the
reduction axis into contiguous groups with an independent C per group.  This
interpolates between the paper's single-C CV (groups=1) and exact error
reconstruction (groups=k), strictly reducing Eq. 20's variance at a cost of
one extra rank-1 term per group.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multipliers as am

Mode = am.Mode


# ---------------------------------------------------------------------------
# Runtime activation statistics  x_j  (cheap, per the paper's hardware)
# ---------------------------------------------------------------------------


def x_stat(a_codes, mode: Mode, m: int) -> jax.Array:
    """The control-variate input statistic x_j per activation code (int32).

    perforated/recursive: the m low bits of the code (Eqs. 18/29).
    truncated: 1 iff any of the m low bits is set (Eq. 25's Kronecker term).
    """
    if mode == "exact" or m == 0:
        return jnp.zeros_like(jnp.asarray(a_codes, jnp.int32))
    if mode in ("perforated", "recursive"):
        return am.low_bits(a_codes, m)
    if mode == "truncated":
        return (am.low_bits(a_codes, m) != 0).astype(jnp.int32)
    raise ValueError(f"unknown mode: {mode}")


def sum_x(a_codes, mode: Mode, m: int, axis: int = -1) -> jax.Array:
    """sum_j x_j along the reduction axis — the MAC+ column's running sum."""
    return jnp.sum(x_stat(a_codes, mode, m), axis=axis, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Offline weight constants  C, C0
# ---------------------------------------------------------------------------


def w_hat(w_codes, m: int) -> jax.Array:
    """Eq. 24: W_hat = 1/2 sum_{i<m} (W mod 2^{m-i}) * 2^i, as float32."""
    w = jnp.asarray(w_codes, jnp.int32)
    acc = jnp.zeros(w.shape, jnp.float32)
    for i in range(m):
        acc = acc + (am.low_bits(w, m - i) << i).astype(jnp.float32)
    return acc / 2.0


@dataclasses.dataclass(frozen=True)
class CVConstants:
    """Offline control-variate constants for one linear layer.

    c:  (n_out,) float32 — the multiplicative constant C per output channel.
    c0: (n_out,) float32 — the additive constant C0 (zero except truncated);
        in hardware it is folded into the bias (Sec. 3.2), we do the same.
    """

    c: jax.Array
    c0: jax.Array

    def astuple(self):
        return (self.c, self.c0)


def cv_constants(w_codes, mode: Mode, m: int, reduce_axis: int = 0) -> CVConstants:
    """Compute (C, C0) from the weight codes of a (k, n) linear layer.

    ``reduce_axis`` is the fan-in axis (the axis summed by the MAC array).
    """
    w = jnp.asarray(w_codes, jnp.int32)
    n_out_shape = tuple(
        d for i, d in enumerate(w.shape) if i != (reduce_axis % w.ndim)
    )
    if mode == "exact" or m == 0:
        z = jnp.zeros(n_out_shape, jnp.float32)
        return CVConstants(c=z, c0=z)
    if mode == "perforated":
        c = jnp.mean(w.astype(jnp.float32), axis=reduce_axis)
        return CVConstants(c=c, c0=jnp.zeros_like(c))
    if mode == "recursive":
        c = jnp.mean(am.low_bits(w, m).astype(jnp.float32), axis=reduce_axis)
        return CVConstants(c=c, c0=jnp.zeros_like(c))
    if mode == "truncated":
        wh = w_hat(w, m)
        c = jnp.mean(wh, axis=reduce_axis)
        c0 = jnp.sum(wh, axis=reduce_axis) / float(1 << m)
        return CVConstants(c=c, c0=c0)
    raise ValueError(f"unknown mode: {mode}")


def cv_constants_grouped(
    w_codes, mode: Mode, m: int, groups: int, reduce_axis: int = 0
) -> CVConstants:
    """Beyond-paper grouped CV: per-group C over ``groups`` contiguous slices
    of the fan-in axis.  Returns c of shape (groups, n_out); c0 as in the
    paper (computed over the full axis — the mean-nullification argument is
    unchanged because it is linear in the group partition).
    """
    w = jnp.asarray(w_codes, jnp.int32)
    w = jnp.moveaxis(w, reduce_axis, 0)
    k = w.shape[0]
    if k % groups != 0:
        raise ValueError(f"fan-in {k} not divisible by groups {groups}")
    wg = w.reshape(groups, k // groups, *w.shape[1:])
    per_group = cv_constants(wg, mode, m, reduce_axis=1)
    full = cv_constants(w, mode, m, reduce_axis=0)
    return CVConstants(c=per_group.c, c0=full.c0)


# ---------------------------------------------------------------------------
# The control variate V and the corrected matmul
# ---------------------------------------------------------------------------


def cv_term(a_codes, const: CVConstants, mode: Mode, m: int) -> jax.Array:
    """V = C * sum_j x_j + C0 for a batch of activation rows.

    a_codes: (..., k) uint8 codes; returns (..., n_out) float32.
    The rank-1 structure is explicit: outer(sum_x(A), C).
    """
    sx = sum_x(a_codes, mode, m, axis=-1).astype(jnp.float32)  # (...,)
    return sx[..., None] * const.c + const.c0


def cv_term_grouped(
    a_codes, const: CVConstants, mode: Mode, m: int, groups: int
) -> jax.Array:
    """Grouped-CV V: sum_g C_g * sum_{j in g} x_j + C0 (rank-``groups``)."""
    a = jnp.asarray(a_codes, jnp.int32)
    k = a.shape[-1]
    ag = a.reshape(*a.shape[:-1], groups, k // groups)
    sx = sum_x(ag, mode, m, axis=-1).astype(jnp.float32)  # (..., groups)
    # const.c: (groups, n_out)
    v = jax.lax.dot_general(
        sx,
        const.c,
        dimension_numbers=(((sx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return v + const.c0


def approx_matmul_cv(
    a_codes,
    w_codes,
    mode: Mode,
    m: int,
    const: CVConstants | None = None,
    groups: int = 1,
) -> jax.Array:
    """G*-style corrected code-space matmul: sum AM(w, a) + V  (float32).

    This is the reference composition used by the quantized layer and as the
    oracle for the fused Pallas kernel.
    """
    acc = am.approx_matmul(a_codes, w_codes, mode, m).astype(jnp.float32)
    if mode == "exact" or m == 0:
        return acc
    if const is None:
        const = (
            cv_constants(w_codes, mode, m)
            if groups == 1
            else cv_constants_grouped(w_codes, mode, m, groups)
        )
    if groups == 1:
        return acc + cv_term(a_codes, const, mode, m)
    return acc + cv_term_grouped(a_codes, const, mode, m, groups)


# ---------------------------------------------------------------------------
# Analytic predictions (Eqs. 12, 20, 22, 28) for tests/benchmarks
# ---------------------------------------------------------------------------


def predicted_conv_error_no_cv_uniform(mode: Mode, m: int, k: int) -> tuple[float, float]:
    """Eq. 12: mean/std of the convolution error WITHOUT the control variate,
    for k-term dot products of i.i.d. uniform codes."""
    mu, sigma = am.analytic_error_moments_uniform(mode, m)
    return k * mu, float(np.sqrt(k) * sigma)


def predicted_var_with_cv_perforated(w_codes: np.ndarray, m: int) -> float:
    """Eq. 20 evaluated at the optimal C = E[W]:
    Var(eps_G*) = Var(x) * sum_j (W_j - E[W])^2, Var(x) = (2^m-1)(2^m+1)/12.

    (A ~ uniform; the same expression holds for the recursive multiplier with
    W replaced by W mod 2^m, Sec. 3.3.)
    """
    w = np.asarray(w_codes, np.float64)
    var_x = ((1 << m) - 1) * ((1 << m) + 1) / 12.0
    return float(var_x * np.sum((w - w.mean()) ** 2))


def predicted_var_with_cv_recursive(w_codes: np.ndarray, m: int) -> float:
    wl = np.asarray(w_codes, np.int64) % (1 << m)
    return predicted_var_with_cv_perforated(wl, m)


def predicted_mean_with_cv(
    w_codes: np.ndarray, mode: Mode, m: int
) -> float:
    """Eqs. 22/28: with the paper's (C, C0) the mean error is exactly zero
    when A is uniform.  Returned analytically (always 0.0) — the tests verify
    the *empirical* mean is within CLT bounds of it.
    """
    return 0.0
