"""The approximation-aware dense op used by every model in the framework.

Two parameter representations for a linear layer:

  * float dict ``{"w": (k, n), "b": (n,)?}`` — training / exact inference;
  * :class:`QuantizedDense` — the offline-packed serving representation:
    uint8 weight codes, quant params, CV constants, and the static
    :class:`~repro.core.policy.ApproxPolicy` as pytree metadata.

``dense(p, x)`` dispatches on the representation, so model code is agnostic
to whether it runs float, exact-int8, or approximate+CV — the paper's
technique is a parameter transformation (:func:`pack_params`), not a model
rewrite.  This mirrors the hardware story: the same network is simply mapped
onto a different MAC array.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import control_variate as cv
from repro.core import multipliers as am
from repro.core.policy import ApproxPolicy, PolicyFn
from repro.quant.quantize import (
    BlockedPack,
    PackedLinear,
    QuantParams,
    build_blocked_layout,
    build_fold,
    calibrate_minmax,
    concat_packs,
    folded_linear,
    pack_linear,
    quantized_linear,
    serving_blocks,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedDense:
    """Packed approximate linear layer.  ``policy`` is static metadata.

    ``blocked`` (pallas-backend packs only) is the offline-blocked serving
    layout: weight codes pre-padded to kernel tiles and all epilogue
    operands in one aligned table, so the forward pass never pads or
    assembles static parameters (see repro.quant.BlockedPack).
    """

    pack: PackedLinear
    a_qp: QuantParams
    policy: ApproxPolicy = dataclasses.field(metadata=dict(static=True))
    blocked: BlockedPack | None = None
    fold: dict | None = None


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedDenseGroup:
    """Fan-out-fused sibling linears (Q|K|V, gate|up) sharing one input.

    One concatenated pack (per-column weight quant params) executes all
    members in a single wide-N call: activations are quantized ONCE and the
    per-row MAC* statistics (sumx, sumqa) are computed ONCE and reused for
    every fused output column — they are per-row, column-independent, so the
    fused outputs are bit-identical to the separate member calls.
    ``names``/``splits`` recover the member outputs by column range.

    ``members`` carries the individually packed member layers for the
    decode-shape fallback: at small flattened row counts (M <=
    repro.kernels.ops.DECODE_M_MAX) the wide fused call measured SLOWER
    than separate member calls (BENCH_kernels.json decode_m4/qkv_fused,
    0.67x), so :func:`dense_group` gates the fusion on M.  Both
    representations produce bit-identical outputs by construction; the
    cost is carrying the member codes alongside the fused pack (~2x pack
    memory on fused layers), the classic compute-for-memory serving trade.
    """

    pack: PackedLinear
    a_qp: QuantParams
    policy: ApproxPolicy = dataclasses.field(metadata=dict(static=True))
    names: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    splits: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    blocked: BlockedPack | None = None
    fold: dict | None = None
    members: tuple[QuantizedDense, ...] | None = None


def is_linear_params(p: Any) -> bool:
    """Float linear leaf: 2D weights, or 3D = (layers, k, n) scanned stack."""
    return isinstance(p, dict) and "w" in p and getattr(p["w"], "ndim", 0) in (2, 3)


def _packed_forward(p: QuantizedDense | QuantizedDenseGroup,
                    x: jax.Array) -> jax.Array:
    """Forward dispatch for one packed leaf (or fused group's wide call)."""
    pol = p.policy
    if pol.backend == "pallas" and pol.is_approx and pol.groups == 1:
        from repro.kernels import ops as kops

        if not isinstance(p, QuantizedDenseGroup):
            return kops.quantized_dense_pallas(x, p).astype(x.dtype)
        if p.blocked is not None:
            return kops.quantized_dense_fused_op(
                x, p.blocked, mode=pol.mode, m=pol.m, use_cv=pol.use_cv)
    if p.fold is not None:  # serving fast path: folded float GEMMs
        return folded_linear(x, p.fold, pol.mode, pol.m,
                             pol.use_cv).astype(x.dtype)
    # grouped CV has no Pallas kernel yet: backend="pallas" with
    # groups > 1 falls back to the jnp grouped path instead of crashing
    return quantized_linear(
        x,
        p.pack,
        p.a_qp,
        pol.mode,
        pol.m,
        use_cv=pol.use_cv,
        groups=pol.groups,
    ).astype(x.dtype)


def dense(p: Any, x: jax.Array, name: str | None = None) -> jax.Array:
    """y = x @ W (+ b), under whatever numerics ``p`` encodes.

    x: (..., k).  ``name`` (optional) scopes calibration recording so the
    recorded activation-range path matches the parameter-tree path used by
    :func:`pack_params`.

    When a :class:`repro.quant.error_probe.ProbeRecorder` is active (eager
    probe forwards only — tracers are ignored, so jitted serving steps pay
    one thread-local ``None`` check at TRACE time and nothing at runtime),
    packed layers additionally compute the exact-int8 reference on the
    same codes: mode "observe" records the elementwise approx-vs-exact
    delta moments, mode "exact" returns the reference instead.
    """
    from repro.quant import error_probe, faults, observers

    if isinstance(p, QuantizedDense):
        probe = error_probe.active()
        if probe is not None and not isinstance(x, jax.core.Tracer):
            if probe.mode == "exact":
                return error_probe.exact_dense(p, x).astype(x.dtype)
            y = _packed_forward(p, x)
            flt = faults.active()
            if flt is not None:
                # armed fault injector (repro.quant.faults): corrupt the
                # approximate output BEFORE the delta is observed, so a
                # degraded MAC array shows up in the probe's variance
                y = flt.corrupt_dense(observers.current_path(),
                                      name or "dense", y)
            probe.observe(observers.current_path(), name or "dense",
                          np.asarray(y, np.float64)
                          - np.asarray(error_probe.exact_dense(p, x),
                                       np.float64))
            return y
        return _packed_forward(p, x)
    # float path (+ calibration recording when a recorder is active)
    if name is not None:
        with observers.scope(name):
            observers.record(x)
    else:
        observers.record(x)
    y = jnp.matmul(x, p["w"])
    if "b" in p and p["b"] is not None:
        y = y + p["b"]
    return y


def init_dense(key, k: int, n: int, *, bias: bool = True, scale: float | None = None,
               dtype=jnp.float32) -> dict:
    """Standard trunc-normal linear init (1/sqrt(k) fan-in scaling)."""
    if scale is None:
        scale = k**-0.5
    p = {"w": (jax.random.truncated_normal(key, -2.0, 2.0, (k, n)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


# ---------------------------------------------------------------------------
# Offline packing: float params + calibration stats -> approximate params
# ---------------------------------------------------------------------------


def _pack_leaf(p: dict, policy: ApproxPolicy) -> PackedLinear:
    """Quantize one float linear leaf (2D, or vmapped over a 3D stack)."""
    import functools

    w = p["w"]
    b = p.get("b")
    fn = functools.partial(
        pack_linear, mode=policy.mode, m=policy.m, groups=policy.groups
    )
    if w.ndim == 3:
        pack = jax.vmap(lambda wi, bi: fn(wi, bi))(
            w, b if b is not None else jnp.zeros((w.shape[0], w.shape[-1]), w.dtype)
        )
        if b is None:
            pack = dataclasses.replace(pack, bias=None)
        return pack
    return fn(w, b)


def _act_qp(act_range, w: jax.Array) -> QuantParams:
    """Activation quant params; per-layer vectors for 3D stacks so
    ``lax.scan`` can slice the pack."""
    if w.ndim == 3:
        return calibrate_minmax(
            jnp.broadcast_to(jnp.asarray(act_range[0], jnp.float32), (w.shape[0],)),
            jnp.broadcast_to(jnp.asarray(act_range[1], jnp.float32), (w.shape[0],)),
        )
    return calibrate_minmax(act_range[0], act_range[1])


def _maybe_blocked(pack: PackedLinear, a_qp: QuantParams,
                   policy: ApproxPolicy, ndim: int) -> BlockedPack | None:
    """Offline-blocked serving layout for pallas-backend single-CV packs."""
    if not (policy.backend == "pallas" and policy.is_approx
            and policy.groups == 1):
        return None
    k, n = pack.w_q.shape[-2:]
    bn, bk = serving_blocks(k, n)
    if ndim == 3:
        return jax.vmap(
            lambda pk, aq: build_blocked_layout(pk, aq, bn, bk))(pack, a_qp)
    return build_blocked_layout(pack, a_qp, bn, bk)


def _maybe_fold(pack: PackedLinear, a_qp: QuantParams,
                policy: ApproxPolicy) -> dict | None:
    """Folded float serving operands for jnp-path packs (build_fold); the
    pallas-approx path reads the blocked layout instead."""
    if policy.backend == "pallas" and policy.is_approx and policy.groups == 1:
        return None
    return build_fold(pack, a_qp, policy.mode, policy.m, policy.use_cv)


def pack_dense(
    p: dict,
    policy: ApproxPolicy,
    act_range: tuple[float, float] | tuple[jax.Array, jax.Array],
    fold: bool = True,
) -> QuantizedDense:
    """Pack one float linear layer for the approximate array.

    Handles both 2D weights and 3D (layers, k, n) scanned stacks — for the
    latter every per-layer slice gets its own quant/CV constants (vmapped),
    and `lax.scan` over the resulting QuantizedDense xs slices them per step.
    """
    w = p["w"]
    pack = _pack_leaf(p, policy)
    a_qp = _act_qp(act_range, w)
    return QuantizedDense(pack=pack, a_qp=a_qp, policy=policy,
                          blocked=_maybe_blocked(pack, a_qp, policy, w.ndim),
                          fold=_maybe_fold(pack, a_qp, policy) if fold
                          else None)


def pack_dense_group(
    members: list[tuple[str, dict]],
    policy: ApproxPolicy,
    act_range: tuple[float, float] | tuple[jax.Array, jax.Array],
    fold: bool = True,
) -> QuantizedDenseGroup:
    """Pack sibling linears that consume the SAME activations into one
    fan-out-fused wide-N pack (quantize once, shared MAC* statistics).

    Each member keeps its own weight quant scale/zero-point (per-column
    vectors in the fused pack) and CV constants, so per-column arithmetic —
    and therefore the outputs — are bit-identical to separate packing.
    """
    names = tuple(name for name, _ in members)
    leaves = [leaf for _, leaf in members]
    w0 = leaves[0]["w"]
    splits = tuple(int(leaf["w"].shape[-1]) for leaf in leaves)
    member_packs = [_pack_leaf(leaf, policy) for leaf in leaves]
    pack = concat_packs(member_packs)
    a_qp = _act_qp(act_range, w0)
    # the individually packed members ride along for the decode-shape
    # fallback (dense_group gates the wide fused call on M); per-column
    # quant params make both representations bit-identical, so which one
    # runs is purely a latency choice
    member_qd = tuple(
        QuantizedDense(pack=mp, a_qp=a_qp, policy=policy,
                       blocked=_maybe_blocked(mp, a_qp, policy, w0.ndim),
                       fold=_maybe_fold(mp, a_qp, policy) if fold else None)
        for mp in member_packs)
    return QuantizedDenseGroup(
        pack=pack, a_qp=a_qp, policy=policy, names=names, splits=splits,
        blocked=_maybe_blocked(pack, a_qp, policy, w0.ndim),
        fold=_maybe_fold(pack, a_qp, policy) if fold else None,
        members=member_qd)


def _fuse_m_min() -> int:
    """Smallest flattened row count that runs the wide fused group call.

    BENCH_kernels.json measured the fused wide-N call SLOWER than separate
    member calls at decode shapes (decode_m4/qkv_fused 0.67x): at thin M
    the wide GEMM's fixed cost dominates and the shared-quantize win
    vanishes.  The threshold is the kernel block picker's decode window —
    below/at DECODE_M_MAX the decode-specialized tiles fire anyway, so the
    same boundary splits the two regimes.
    """
    from repro.kernels import ops as kops

    return kops.DECODE_M_MAX + 1


def dense_group(g: QuantizedDenseGroup, x: jax.Array) -> dict[str, jax.Array]:
    """Run a fused fan-out group: one wide-N call, outputs split per member.

    Returns ``{name: (..., n_name)}`` in the group's member order.

    Decode-shape M-gate: when the flattened row count is inside the
    kernel decode window (M < :func:`_fuse_m_min`) and the group carries
    its packed ``members``, the members run as separate :func:`dense`
    calls instead of the wide fused GEMM — bit-identical outputs, faster
    thin-M latency.  The branch is on a STATIC shape, so each jitted
    batch shape compiles exactly one of the two paths.
    """
    rows = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    if g.members is not None and rows < _fuse_m_min():
        return {name: dense(member, x, name=name)
                for name, member in zip(g.names, g.members)}
    from repro.quant import error_probe, faults, observers

    probe = error_probe.active()
    if probe is not None and not isinstance(x, jax.core.Tracer):
        if probe.mode == "exact":
            y = error_probe.exact_dense(g, x).astype(x.dtype)
        else:
            y = _packed_forward(g, x)
            flt = faults.active()
            if flt is not None:
                y = flt.corrupt_dense(observers.current_path(),
                                      "|".join(g.names), y)
            probe.observe(observers.current_path(), "|".join(g.names),
                          np.asarray(y, np.float64)
                          - np.asarray(error_probe.exact_dense(g, x),
                                       np.float64))
    else:
        y = _packed_forward(g, x)
    out: dict[str, jax.Array] = {}
    off = 0
    for name, n in zip(g.names, g.splits):
        out[name] = jax.lax.slice_in_dim(y, off, off + n, axis=-1)
        off += n
    return out


#: Sibling sets eligible for fan-out fusion (consume the SAME activations):
#: (member names, fused key, companion key).  The companion key must also be
#: present — it anchors the dict to the module shape whose call sites
#: actually feed every member the same input (attention blocks have "o",
#: swiglu has "down"), so name-coincidences in other modules (e.g. RWKV
#: time-mix r/k/v with token-shifted inputs) can never fuse.  MoE expert
#: stacks ("experts" dicts) carry the same member names but run through the
#: ragged grouped-GEMM path, so they are never fused here.
FUSABLE_GROUPS: tuple[tuple[tuple[str, ...], str, str], ...] = (
    (("q", "k", "v"), "qkv", "o"),
    (("gate", "up"), "gateup", "down"),
)


def _ranges_equal(a, b) -> bool:
    import numpy as np

    try:
        return bool(np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
                    and np.array_equal(np.asarray(a[1]), np.asarray(b[1])))
    except Exception:
        return False


def _fusable(node: dict, names: tuple[str, ...], companion: str, path,
             policy_fn, act_ranges, default_range):
    """If ``names`` form a fusable sibling set in ``node``, return
    (policy, act_range); else None."""
    if companion not in node:
        return None
    if not all(n in node and is_linear_params(node[n]) for n in names):
        return None
    leaves = [node[n] for n in names]
    w0 = leaves[0]["w"]
    if any(leaf["w"].shape[:-1] != w0.shape[:-1] or leaf["w"].ndim != w0.ndim
           for leaf in leaves):
        return None  # different fan-in / stacking: not the same input
    if len({("b" in leaf and leaf.get("b") is not None) for leaf in leaves}) > 1:
        return None
    policies = [policy_fn(path + (n,)) for n in names]
    if policies[0] is None or any(p != policies[0] for p in policies):
        return None
    ranges = [(act_ranges or {}).get("/".join(path + (n,)), default_range)
              for n in names]
    if any(not _ranges_equal(r, ranges[0]) for r in ranges):
        return None
    return policies[0], ranges[0]


def pack_params(
    params: Any,
    policy_fn: PolicyFn,
    act_ranges: dict[str, tuple[float, float]] | None = None,
    default_range: tuple[float, float] = (-8.0, 8.0),
    fuse: bool = True,
    fold: bool = True,
) -> Any:
    """Walk a parameter tree, replacing float linear leaves with packed ones.

    ``policy_fn(path)`` picks the policy per layer (None keeps float);
    ``act_ranges`` maps "/".join(path) -> (lo, hi) calibration stats recorded
    by :mod:`repro.quant.observers`.  Layers without stats use
    ``default_range`` (safe-wide; accuracy benchmarks always calibrate).

    With ``fuse`` (default), sibling layers in :data:`FUSABLE_GROUPS` that
    share a policy and activation range are packed into ONE fan-out-fused
    :class:`QuantizedDenseGroup` (key "qkv" / "gateup", replacing the member
    keys) — bit-identical outputs, one wide-N kernel call at serving time.
    With ``fold`` (default), jnp-path packs carry the folded f32 serving
    operands (:func:`repro.quant.quantize.build_fold`); pass ``fold=False``
    to keep every pack on the exact-integer path (no f32 staging memory).
    """

    def walk(node: Any, path: tuple[str, ...]) -> Any:
        if is_linear_params(node):
            policy = policy_fn(path)
            if policy is None:
                return node
            key = "/".join(path)
            rng = (act_ranges or {}).get(key, default_range)
            # expert stacks run the ragged grouped-GEMM path, which reads
            # only the canonical pack — folded operands would be dead weight
            leaf_fold = fold and path[-2:-1] != ("experts",)
            return pack_dense(node, policy, rng, fold=leaf_fold)
        if isinstance(node, dict):
            groups: dict[str, Any] = {}  # first-member key -> (group key, group)
            consumed: set[str] = set()
            if fuse and path[-1:] != ("experts",):
                for names, gkey, companion in FUSABLE_GROUPS:
                    if consumed.intersection(names):
                        continue
                    hit = _fusable(node, names, companion, path, policy_fn,
                                   act_ranges, default_range)
                    if hit is None:
                        continue
                    policy, rng = hit
                    groups[names[0]] = (gkey, pack_dense_group(
                        [(n, node[n]) for n in names], policy, rng,
                        fold=fold))
                    consumed.update(names)
            out: dict[str, Any] = {}
            for k, v in node.items():
                if k in groups:
                    gkey, g = groups[k]
                    out[gkey] = g
                elif str(k) not in consumed:
                    out[k] = walk(v, path + (str(k),))
            return out
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v, path + (str(i),)) for i, v in enumerate(node))
        return node

    return walk(params, ())


def packed_layer_paths(params: Any) -> list[str]:
    """All paths that hold packed layers (for reporting/tests).

    Fan-out-fused groups report their ORIGINAL member paths (e.g. a group
    at ``blocks/attn/qkv`` lists ``blocks/attn/q`` etc.), and the listing is
    sorted, so it is stable across the fused and unfused representations.
    """
    out: list[str] = []

    def walk(node: Any, path: tuple[str, ...]):
        if isinstance(node, QuantizedDense):
            out.append("/".join(path))
        elif isinstance(node, QuantizedDenseGroup):
            for name in node.names:
                out.append("/".join(path[:-1] + (name,)))
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))

    walk(params, ())
    return sorted(out)
