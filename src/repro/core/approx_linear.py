"""The approximation-aware dense op used by every model in the framework.

Two parameter representations for a linear layer:

  * float dict ``{"w": (k, n), "b": (n,)?}`` — training / exact inference;
  * :class:`QuantizedDense` — the offline-packed serving representation:
    uint8 weight codes, quant params, CV constants, and the static
    :class:`~repro.core.policy.ApproxPolicy` as pytree metadata.

``dense(p, x)`` dispatches on the representation, so model code is agnostic
to whether it runs float, exact-int8, or approximate+CV — the paper's
technique is a parameter transformation (:func:`pack_params`), not a model
rewrite.  This mirrors the hardware story: the same network is simply mapped
onto a different MAC array.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import control_variate as cv
from repro.core import multipliers as am
from repro.core.policy import ApproxPolicy, PolicyFn
from repro.quant.quantize import (
    PackedLinear,
    QuantParams,
    calibrate_minmax,
    pack_linear,
    quantized_linear,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedDense:
    """Packed approximate linear layer.  ``policy`` is static metadata."""

    pack: PackedLinear
    a_qp: QuantParams
    policy: ApproxPolicy = dataclasses.field(metadata=dict(static=True))


def is_linear_params(p: Any) -> bool:
    """Float linear leaf: 2D weights, or 3D = (layers, k, n) scanned stack."""
    return isinstance(p, dict) and "w" in p and getattr(p["w"], "ndim", 0) in (2, 3)


def dense(p: Any, x: jax.Array, name: str | None = None) -> jax.Array:
    """y = x @ W (+ b), under whatever numerics ``p`` encodes.

    x: (..., k).  ``name`` (optional) scopes calibration recording so the
    recorded activation-range path matches the parameter-tree path used by
    :func:`pack_params`.
    """
    from repro.quant import observers

    if isinstance(p, QuantizedDense):
        pol = p.policy
        if pol.backend == "pallas" and pol.is_approx:
            from repro.kernels import ops as kops

            return kops.quantized_dense_pallas(x, p).astype(x.dtype)
        return quantized_linear(
            x,
            p.pack,
            p.a_qp,
            pol.mode,
            pol.m,
            use_cv=pol.use_cv,
            groups=pol.groups,
        ).astype(x.dtype)
    # float path (+ calibration recording when a recorder is active)
    if name is not None:
        with observers.scope(name):
            observers.record(x)
    else:
        observers.record(x)
    y = jnp.matmul(x, p["w"])
    if "b" in p and p["b"] is not None:
        y = y + p["b"]
    return y


def init_dense(key, k: int, n: int, *, bias: bool = True, scale: float | None = None,
               dtype=jnp.float32) -> dict:
    """Standard trunc-normal linear init (1/sqrt(k) fan-in scaling)."""
    if scale is None:
        scale = k**-0.5
    p = {"w": (jax.random.truncated_normal(key, -2.0, 2.0, (k, n)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


# ---------------------------------------------------------------------------
# Offline packing: float params + calibration stats -> approximate params
# ---------------------------------------------------------------------------


def pack_dense(
    p: dict,
    policy: ApproxPolicy,
    act_range: tuple[float, float] | tuple[jax.Array, jax.Array],
) -> QuantizedDense:
    """Pack one float linear layer for the approximate array.

    Handles both 2D weights and 3D (layers, k, n) scanned stacks — for the
    latter every per-layer slice gets its own quant/CV constants (vmapped),
    and `lax.scan` over the resulting QuantizedDense xs slices them per step.
    """
    import functools

    w = p["w"]
    b = p.get("b")
    fn = functools.partial(
        pack_linear, mode=policy.mode, m=policy.m, groups=policy.groups
    )
    if w.ndim == 3:
        pack = jax.vmap(lambda wi, bi: fn(wi, bi))(
            w, b if b is not None else jnp.zeros((w.shape[0], w.shape[-1]), w.dtype)
        )
        if b is None:
            pack = dataclasses.replace(pack, bias=None)
        # per-layer activation quant params so lax.scan can slice the pack
        a_qp = calibrate_minmax(
            jnp.broadcast_to(jnp.asarray(act_range[0], jnp.float32), (w.shape[0],)),
            jnp.broadcast_to(jnp.asarray(act_range[1], jnp.float32), (w.shape[0],)),
        )
    else:
        pack = fn(w, b)
        a_qp = calibrate_minmax(act_range[0], act_range[1])
    return QuantizedDense(pack=pack, a_qp=a_qp, policy=policy)


def pack_params(
    params: Any,
    policy_fn: PolicyFn,
    act_ranges: dict[str, tuple[float, float]] | None = None,
    default_range: tuple[float, float] = (-8.0, 8.0),
) -> Any:
    """Walk a parameter tree, replacing float linear leaves with packed ones.

    ``policy_fn(path)`` picks the policy per layer (None keeps float);
    ``act_ranges`` maps "/".join(path) -> (lo, hi) calibration stats recorded
    by :mod:`repro.quant.observers`.  Layers without stats use
    ``default_range`` (safe-wide; accuracy benchmarks always calibrate).
    """

    def walk(node: Any, path: tuple[str, ...]) -> Any:
        if is_linear_params(node):
            policy = policy_fn(path)
            if policy is None:
                return node
            key = "/".join(path)
            rng = (act_ranges or {}).get(key, default_range)
            return pack_dense(node, policy, rng)
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v, path + (str(i),)) for i, v in enumerate(node))
        return node

    return walk(params, ())


def packed_layer_paths(params: Any) -> list[str]:
    """All paths that hold a QuantizedDense (for reporting/tests)."""
    out: list[str] = []

    def walk(node: Any, path: tuple[str, ...]):
        if isinstance(node, QuantizedDense):
            out.append("/".join(path))
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))

    walk(params, ())
    return out
