"""Uniform model API over the zoo + per-shape input specs.

``build_model(cfg)`` returns a :class:`ModelApi` whose methods are pure
functions of (params, batch[, cache]); ``input_specs(cfg, shape)`` returns
ShapeDtypeStruct stand-ins for every model input of the assigned input
shapes (weak-type-correct, shardable, no device allocation) — the dry-run
contract.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    s = SHAPES[shape]
    if s.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch; 500k decode assigned to SSM/hybrid only"
    return True, ""


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable  # (key) -> params
    forward: Callable  # (params, batch, mesh=None) -> logits
    train_loss: Callable  # (params, batch, mesh=None) -> scalar
    prefill: Callable  # (params, batch, max_len, mesh=None) -> (logits, cache)
    decode_step: Callable  # (params, tokens, cache, mesh=None) -> (logits, cache)
    init_cache: Callable  # (batch, max_len, dtype) -> cache
    # continuous-batching surface (serving engine): pooled per-slot cache +
    # fixed-shape multi-token step with per-slot cursors
    init_slot_cache: Callable = None  # (slots, max_len, dtype) -> cache
    # (params, tokens, cache, n_valid, mesh=None, block_tables=None);
    # block_tables selects the paged layout (repro.serving.paged)
    decode_slots: Callable = None
    # paged layout: (num_blocks, block_size, slots, dtype) -> block-pool cache
    init_paged_cache: Callable = None

    @property
    def supports_slots(self) -> bool:
        """True when the arch can serve through the slot engine."""
        if not self.cfg.has_decode:
            return False
        if self.cfg.rwkv:
            return True
        from repro.models.lm import _slot_unsupported

        return _slot_unsupported(self.cfg) is None

    @property
    def supports_paged(self) -> bool:
        """True when the arch can serve through the paged (block) KV
        layout.  Recurrent archs (RWKV) have per-slot state, not a KV
        sequence, so there is nothing to page."""
        return self.init_paged_cache is not None and self.supports_slots


def build_model(cfg: ArchConfig) -> ModelApi:
    if cfg.rwkv:
        from repro.models import rwkv_lm as m

        return ModelApi(
            cfg=cfg,
            init=lambda key: m.init_params(key, cfg),
            forward=lambda p, b, mesh=None: m.forward(p, b, cfg, mesh),
            train_loss=lambda p, b, mesh=None: m.train_loss(p, b, cfg, mesh),
            prefill=lambda p, b, max_len=0, mesh=None, cache_dtype=jnp.bfloat16:
                m.prefill(p, b, cfg, max_len, mesh, cache_dtype),
            decode_step=lambda p, t, c, mesh=None: m.decode_step(p, t, c, cfg, mesh),
            init_cache=lambda batch, max_len=0, dtype=jnp.bfloat16: m.init_cache(
                cfg, batch, max_len, dtype),
            init_slot_cache=lambda slots, max_len=0, dtype=jnp.bfloat16:
                m.init_slot_cache(cfg, slots, max_len, dtype),
            decode_slots=lambda p, t, c, n_valid, mesh=None:
                m.decode_slots(p, t, c, cfg, n_valid, mesh),
        )
    from repro.models import lm as m

    return ModelApi(
        cfg=cfg,
        init=lambda key: m.init_params(key, cfg),
        forward=lambda p, b, mesh=None: m.forward(p, b, cfg, mesh),
        train_loss=lambda p, b, mesh=None: m.train_loss(p, b, cfg, mesh),
        prefill=lambda p, b, max_len, mesh=None, cache_dtype=jnp.bfloat16:
            m.prefill(p, b, cfg, max_len, mesh, cache_dtype),
        decode_step=lambda p, t, c, mesh=None: m.decode_step(p, t, c, cfg, mesh),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: m.init_cache(
            cfg, batch, max_len, dtype),
        init_slot_cache=lambda slots, max_len, dtype=jnp.bfloat16:
            m.init_slot_cache(cfg, slots, max_len, dtype),
        # unroll_layers: eager python-loop layer stack for the error probe
        # (repro.quant.error_probe); jitted serving keeps the lax.scan
        decode_slots=lambda p, t, c, n_valid, mesh=None, block_tables=None,
            unroll_layers=False:
            m.decode_slots(p, t, c, cfg, n_valid, mesh, block_tables,
                           unroll_layers),
        init_paged_cache=lambda num_blocks, block_size, slots,
            dtype=jnp.bfloat16:
            m.init_paged_slot_cache(cfg, num_blocks, block_size, slots, dtype),
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """Model-input stand-ins for one (arch, shape) cell.

    [vlm]/[audio] archs take STUB precomputed embeddings for the sequence
    body (the modality frontend is out of scope per the assignment); decode
    still consumes token ids through the embedding table.
    """
    s = SHAPES[shape]
    b, t = s.global_batch, s.seq_len
    embeds_input = cfg.input_mode == "embeds"

    if s.kind == "train":
        if embeds_input:
            batch = {
                "embeds": _sds((b, t, cfg.d_model), jnp.bfloat16),
                "labels": _sds((b, t), jnp.int32),
            }
            if cfg.family == "audio":
                batch["mask"] = _sds((b, t), jnp.float32)
        else:
            batch = {
                "tokens": _sds((b, t), jnp.int32),
                "labels": _sds((b, t), jnp.int32),
            }
        return {"batch": batch}

    if s.kind == "prefill":
        if embeds_input:
            batch = {"embeds": _sds((b, t, cfg.d_model), jnp.bfloat16)}
            if cfg.rope == "mrope":
                batch["positions"] = _sds((3, b, t), jnp.int32)
        else:
            batch = {"tokens": _sds((b, t), jnp.int32)}
        return {"batch": batch, "max_len": t}

    # decode: one new token against a cache of seq_len
    api = build_model(cfg)
    cache = jax.eval_shape(
        lambda: api.init_cache(b, t, jnp.bfloat16)
    )
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}
