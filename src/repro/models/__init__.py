"""Model zoo: generic transformer LM (dense/MoE/MLA/hybrid/VLM/audio) and
RWKV6, built from ArchConfig; registry maps arch ids to a uniform ModelApi."""

from repro.models.registry import ModelApi, build_model

__all__ = ["ModelApi", "build_model"]
