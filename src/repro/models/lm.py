"""Generic transformer LM covering 9 of the 10 assigned architectures.

ArchConfig switches select: GQA vs MLA attention, swiglu/gelu/MoE MLP,
parallel SSM heads (hymba), qk-norm, sliding windows, RoPE/M-RoPE/none,
causal vs bidirectional (hubert), token vs embedding inputs (vlm/audio).

Scale-critical implementation choices (these are what make the 512-chip
dry-run lower/compile):

  * layer stacks are SCANNED: block params are stacked (L, ...) pytrees and
    the forward is one `lax.scan` — HLO size is O(1) in depth (95-layer
    deepseek-67b compiles like a 1-layer model);
  * attention is Q-CHUNKED for long sequences: a scan over query chunks
    bounds the live (chunk, S) score tile instead of materializing the
    (T, S) matrix (32k prefill would otherwise allocate TBs);
  * the LM head + cross-entropy are FUSED AND CHUNKED: logits for a 152k
    vocab are never materialized for the full sequence;
  * prefill is SINGLE-PASS: each block projects K/V once and shares them
    between attention and the decode-cache capture;
  * sliding-window decode uses RING-BUFFER caches of length W (slot of
    absolute position a is a mod W), making long_500k hymba decode state
    O(W), not O(S);
  * remat policy per config ("none" | "full" | "dots") wraps the scanned
    block body.

Caches are plain pytrees stacked over layers, so `lax.scan` slices them per
layer during decode and pjit shards them like any other state.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.approx_linear import dense
from repro.nn import attention as attn_lib
from repro.nn import moe as moe_lib
from repro.nn import ssm as ssm_lib
from repro.nn.layers import (
    apply_norm,
    embed,
    init_embedding,
    init_gelu_mlp,
    init_norm,
    init_rmsnorm,
    init_swiglu,
    gelu_mlp,
    rmsnorm,
    swiglu,
)
from repro.quant import observers

Params = Any

Q_CHUNK = 1024  # live attention score tile: (B, H, Q_CHUNK, S)
LOSS_CHUNK = 512  # live logits tile: (B, LOSS_CHUNK, V)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {
        "attn_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.attn == "mla":
        p["attn"] = attn_lib.init_mla(ks[0], cfg.mla_config(), dtype)
    elif cfg.attn == "gqa":
        p["attn"] = attn_lib.init_attention(ks[0], cfg.attn_config(), dtype)
    if cfg.parallel_ssm:
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg.ssm_config(), dtype)
        p["attn_out_norm"] = init_rmsnorm(cfg.d_model, dtype)
        p["ssm_out_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if cfg.mlp == "moe":
        p["mlp"] = moe_lib.init_moe(ks[2], cfg.moe_config(), dtype)
    elif cfg.mlp == "swiglu":
        p["mlp"] = init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head, k_dense = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.first_dense_layers
    block_keys = jax.random.split(k_blocks, n_scan)
    p: dict = {
        "embed": init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(block_keys),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.first_dense_layers:
        dense_cfg = dataclasses.replace(cfg, mlp="swiglu")
        p["dense_blocks"] = [
            _init_block(k, dense_cfg, dtype)
            for k in jax.random.split(k_dense, cfg.first_dense_layers)
        ]
    if not cfg.tie_embeddings:
        p["lm_head"] = {
            "w": (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) * cfg.d_model**-0.5
            ).astype(dtype)
        }
    return p


# ---------------------------------------------------------------------------
# attention (full-sequence, q-chunked), with optional cache capture
# ---------------------------------------------------------------------------


def _gqa_full(bp, h, cfg: ArchConfig, positions, want_cache: bool):
    acfg = cfg.attn_config()
    b, t, _ = h.shape
    angles = attn_lib._angles(acfg, positions)
    q, k, v = attn_lib._project_qkv(bp, h, acfg, angles)
    if t <= Q_CHUNK:
        ctx = attn_lib._sdpa(q, k, v, causal=acfg.causal, window=acfg.window)
    else:
        assert t % Q_CHUNK == 0, (t, Q_CHUNK)
        nch = t // Q_CHUNK

        def chunk_fn(_, inp):
            qc, i = inp
            return None, attn_lib._sdpa(
                qc, k, v,
                causal=acfg.causal,
                window=acfg.window,
                kv_valid_len=(i + 1) * Q_CHUNK if acfg.causal else None,
            )

        qch = jnp.moveaxis(q.reshape(b, nch, Q_CHUNK, acfg.n_heads, acfg.head_dim), 1, 0)
        _, ctx = jax.lax.scan(chunk_fn, None, (qch, jnp.arange(nch)))
        ctx = jnp.moveaxis(ctx, 0, 1).reshape(b, t, acfg.n_heads, acfg.head_dim)
    out = dense(bp["o"], ctx.reshape(b, t, acfg.q_dim), name="o")
    entry = None
    if want_cache:
        entry = {"k": jnp.moveaxis(k, 1, 2), "v": jnp.moveaxis(v, 1, 2)}  # (B,H,T,d)
    return out, entry


def _mla_full(bp, h, cfg: ArchConfig, positions, want_cache: bool):
    mcfg = cfg.mla_config()
    b, t, _ = h.shape
    q_nope, q_rope = attn_lib._mla_q(bp, h, mcfg, positions)
    latent, k_rope = attn_lib._mla_latent(bp, h, mcfg, positions)
    kv = dense(bp["kv_b"], latent, name="kv_b").reshape(
        b, t, mcfg.n_heads, mcfg.qk_nope_dim + mcfg.v_head_dim
    )
    k_nope, v = kv[..., : mcfg.qk_nope_dim], kv[..., mcfg.qk_nope_dim :]
    scale = mcfg.qk_head_dim**-0.5

    def score_chunk(qn, qr, q_off):
        logits = (
            jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope)
            + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope)
        ) * scale
        qpos = q_off + jnp.arange(qn.shape[1])[:, None]
        mask = jnp.arange(t)[None, :] <= qpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(h.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    if t <= Q_CHUNK:
        ctx = score_chunk(q_nope, q_rope, 0)
    else:
        assert t % Q_CHUNK == 0
        nch = t // Q_CHUNK

        def chunks(a):
            return jnp.moveaxis(a.reshape(b, nch, Q_CHUNK, *a.shape[2:]), 1, 0)

        _, ctx = jax.lax.scan(
            lambda _, inp: (None, score_chunk(inp[0], inp[1], inp[2] * Q_CHUNK)),
            None,
            (chunks(q_nope), chunks(q_rope), jnp.arange(nch)),
        )
        ctx = jnp.moveaxis(ctx, 0, 1)
    out = dense(bp["o"], ctx.reshape(b, t, -1), name="o")
    entry = {"latent": latent, "rope": k_rope} if want_cache else None
    return out, entry


# ---------------------------------------------------------------------------
# block forward (training / prefill)
# ---------------------------------------------------------------------------


def _block_forward(bp: dict, x, cfg: ArchConfig, positions, mesh,
                   want_cache: bool = False):
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    h = apply_norm(cfg.norm, bp["attn_norm"], x)
    entry: dict = {}
    if cfg.attn == "mla":
        a, e = _mla_full(bp["attn"], h, cfg, positions, want_cache)
    elif cfg.attn == "gqa":
        a, e = _gqa_full(bp["attn"], h, cfg, positions, want_cache)
    else:
        a, e = 0.0, None
    if e:
        entry.update(e)
    if cfg.parallel_ssm:
        if want_cache:
            s, st = _ssm_with_state(bp["ssm"], h, cfg.ssm_config())
            entry["ssm_conv"], entry["ssm_h"] = st["conv"], st["h"]
        else:
            s = ssm_lib.ssm_prefill(bp["ssm"], h, cfg.ssm_config())
        a = 0.5 * (rmsnorm(bp["attn_out_norm"], a.astype(x.dtype)) +
                   rmsnorm(bp["ssm_out_norm"], s.astype(x.dtype)))
    x = (x + a).astype(x.dtype)

    h = apply_norm(cfg.norm, bp["mlp_norm"], x)
    if cfg.mlp == "moe" and "router" in bp["mlp"]:
        m = moe_lib.moe_apply(bp["mlp"], h, cfg.moe_config(), mesh=mesh)
    elif cfg.mlp == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    return (x + m).astype(x.dtype), entry


def _sp_constrain(x: jax.Array, cfg: ArchConfig, mesh):
    """Sequence-parallel residual stream: (B, T, D) sharded
    (batch over DP axes, T over "model") at block boundaries."""
    if not cfg.sequence_parallel or mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    t = x.shape[1]
    if t % mesh.shape["model"] != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None)))


def _sp_gather(x: jax.Array, cfg: ArchConfig, mesh):
    """The Megatron-SP all-gather point: sequence re-assembled, ready for
    the TP-sharded projections.  Pinning this explicitly stops GSPMD from
    emitting redundant reshard ping-pong inside the block (measured 3.6k
    all-reduces/step -> see EXPERIMENTS.md §Perf iteration 5)."""
    if not cfg.sequence_parallel or mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, None)))


def _remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)


def backbone(params: Params, x: jax.Array, cfg: ArchConfig, positions=None,
             mesh=None) -> jax.Array:
    """Embedded input -> final-norm output (training / forward path)."""
    cdt = _dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    for i, bp in enumerate(params.get("dense_blocks", [])):
        with observers.scope("dense_blocks", i):
            x, _ = _block_forward(bp, x, cfg, positions, mesh)

    body = _remat_wrap(
        lambda carry, bp: (
            _sp_constrain(
                _block_forward(bp, _sp_constrain(carry, cfg, mesh), cfg,
                               positions, mesh)[0],
                cfg, mesh),
            None,
        ),
        cfg,
    )
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    else:
        n = jax.tree.leaves(params["blocks"])[0].shape[0]
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            with observers.scope("blocks", i):
                x, _ = body(x, bp)
    return apply_norm(cfg.norm, params["final_norm"], x)


def _embed_input(params, batch: dict, cfg: ArchConfig):
    if "embeds" in batch:
        return batch["embeds"]
    return embed(params["embed"], batch["tokens"])


def _head_w(params):
    head = params.get("lm_head", params["embed"])
    return head["table"].T if "table" in head else head["w"]


def _logits_head(params, x: jax.Array) -> jax.Array:
    """Unembedding that also accepts a PACKED (approximate) lm_head."""
    from repro.core.approx_linear import QuantizedDense

    head = params.get("lm_head", params["embed"])
    if isinstance(head, QuantizedDense):
        return dense(head, x, name="lm_head").astype(jnp.float32)
    w = head["table"].T if "table" in head else head["w"]
    return jnp.matmul(x, w.astype(x.dtype)).astype(jnp.float32)


def forward(params: Params, batch: dict, cfg: ArchConfig, mesh=None) -> jax.Array:
    """Full-sequence logits (test/benchmark use; training uses train_loss)."""
    x = backbone(params, _embed_input(params, batch, cfg), cfg,
                 batch.get("positions"), mesh)
    return _logits_head(params, x)


# ---------------------------------------------------------------------------
# fused chunked LM-head + cross-entropy
# ---------------------------------------------------------------------------


def _ce_from_logits(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum(), mask.sum()


def chunked_ce_loss(x, head_w, labels, mask):
    """Mean CE over (B, T, D) features without a (B, T, V) logits tensor."""
    b, t, _ = x.shape
    if t <= LOSS_CHUNK:
        nll, cnt = _ce_from_logits(jnp.matmul(x, head_w.astype(x.dtype)), labels, mask)
        return nll / jnp.maximum(cnt, 1.0)
    pad = (-t) % LOSS_CHUNK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nch = x.shape[1] // LOSS_CHUNK

    def chunks(a):
        return jnp.moveaxis(a.reshape(b, nch, LOSS_CHUNK, *a.shape[2:]), 1, 0)

    def body(acc, inp):
        xc, lc, mc = inp
        nll, cnt = _ce_from_logits(jnp.matmul(xc, head_w.astype(xc.dtype)), lc, mc)
        return (acc[0] + nll, acc[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (chunks(x), chunks(labels), chunks(mask)),
    )
    return nll / jnp.maximum(cnt, 1.0)


def train_loss(params: Params, batch: dict, cfg: ArchConfig, mesh=None) -> jax.Array:
    """Next-token (causal) or masked-frame (encoder) cross-entropy."""
    x = backbone(params, _embed_input(params, batch, cfg), cfg,
                 batch.get("positions"), mesh)
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.causal:
        x, labels = x[:, :-1], labels[:, 1:]
        mask = jnp.ones(labels.shape, jnp.float32) if mask is None else mask[:, 1:]
    else:
        mask = jnp.ones(labels.shape, jnp.float32) if mask is None else mask
    return chunked_ce_loss(x, _head_w(params), labels, mask)


# ---------------------------------------------------------------------------
# serving: cache, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """Stacked-over-layers decode cache.  Sliding-window archs get ring
    buffers of length W; MLA gets latent caches; hybrids add SSM state."""
    n_scan = cfg.n_layers - cfg.first_dense_layers
    s = min(max_len, cfg.window) if cfg.window else max_len
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.attn == "mla":
        cache["latent"] = jnp.zeros((n_scan, batch, s, cfg.kv_lora_rank), dtype)
        cache["rope"] = jnp.zeros((n_scan, batch, s, cfg.qk_rope_dim), dtype)
    elif cfg.attn == "gqa":
        cache["k"] = jnp.zeros((n_scan, batch, cfg.kv_heads, s, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros((n_scan, batch, cfg.kv_heads, s, cfg.head_dim), dtype)
    if cfg.first_dense_layers:
        fd = cfg.first_dense_layers
        if cfg.attn == "mla":
            cache["dense_latent"] = jnp.zeros((fd, batch, s, cfg.kv_lora_rank), dtype)
            cache["dense_rope"] = jnp.zeros((fd, batch, s, cfg.qk_rope_dim), dtype)
        else:
            cache["dense_k"] = jnp.zeros((fd, batch, cfg.kv_heads, s, cfg.head_dim), dtype)
            cache["dense_v"] = jnp.zeros((fd, batch, cfg.kv_heads, s, cfg.head_dim), dtype)
    if cfg.parallel_ssm:
        scfg = cfg.ssm_config()
        cache["ssm_conv"] = jnp.zeros(
            (n_scan, batch, scfg.conv_kernel - 1, scfg.d_inner), jnp.float32)
        cache["ssm_h"] = jnp.zeros(
            (n_scan, batch, scfg.d_inner, scfg.d_state), jnp.float32)
    return cache


def _ring_align(data: jax.Array, s: int, t: int) -> jax.Array:
    """Place the last ``s`` of ``t`` positions so that absolute position a
    sits at slot a mod s (ring invariant).  data seq axis = -2."""
    if t > s:
        data = data[..., t - s :, :]
        data = jnp.roll(data, t % s, axis=-2)
    elif t < s:
        pad = [(0, 0)] * data.ndim
        pad[-2] = (0, s - t)
        data = jnp.pad(data, pad)
    return data


def _block_decode(bp: dict, x, lc: dict, pos, cfg: ArchConfig, mesh):
    """One block's decode step.  lc: this layer's cache slices (no 'pos')."""
    acfg = cfg.attn_config()
    h = apply_norm(cfg.norm, bp["attn_norm"], x)
    new: dict = {}
    if cfg.attn == "mla":
        a, c2 = attn_lib.mla_decode_step(
            bp["attn"], h, {"latent": lc["latent"], "rope": lc["rope"], "pos": pos},
            cfg.mla_config(),
        )
        new["latent"], new["rope"] = c2["latent"], c2["rope"]
    elif cfg.attn == "gqa":
        step = attn_lib.attention_decode_ring if cfg.window else attn_lib.attention_decode_step
        a, c2 = step(bp["attn"], h, {"k": lc["k"], "v": lc["v"], "pos": pos}, acfg)
        new["k"], new["v"] = c2["k"], c2["v"]
    else:
        a = 0.0
    if cfg.parallel_ssm:
        s, st = ssm_lib.ssm_decode_step(
            bp["ssm"], h, {"conv": lc["ssm_conv"], "h": lc["ssm_h"]}, cfg.ssm_config()
        )
        new["ssm_conv"], new["ssm_h"] = st["conv"], st["h"]
        a = 0.5 * (rmsnorm(bp["attn_out_norm"], a.astype(x.dtype)) +
                   rmsnorm(bp["ssm_out_norm"], s.astype(x.dtype)))
    x = (x + a).astype(x.dtype)
    h = apply_norm(cfg.norm, bp["mlp_norm"], x)
    if cfg.mlp == "moe" and "router" in bp["mlp"]:
        m = moe_lib.moe_apply(bp["mlp"], h, cfg.moe_config(), mesh=mesh)
    elif cfg.mlp == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    return (x + m).astype(x.dtype), new


def decode_step(params: Params, tokens: jax.Array, cache: dict, cfg: ArchConfig,
                mesh=None) -> tuple[jax.Array, dict]:
    """tokens: (B, 1) -> (logits (B, V) f32, updated cache)."""
    cdt = _dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens).astype(cdt)
    pos = cache["pos"]
    new_cache = dict(cache)

    dense_keys = ("latent", "rope") if cfg.attn == "mla" else ("k", "v")
    for i, bp in enumerate(params.get("dense_blocks", [])):
        lc = {k: cache[f"dense_{k}"][i] for k in dense_keys}
        x, new = _block_decode(bp, x, lc, pos, cfg, mesh)
        for k in dense_keys:
            new_cache[f"dense_{k}"] = new_cache[f"dense_{k}"].at[i].set(new[k])

    layer_keys = [k for k in ("latent", "rope", "k", "v", "ssm_conv", "ssm_h")
                  if k in cache]

    lcs = {k: cache[k] for k in layer_keys}

    def body(x, inp):
        bp, lc = inp
        return _block_decode(bp, x, lc, pos, cfg, mesh)

    x, new_layers = jax.lax.scan(body, x, (params["blocks"], lcs))
    new_cache.update(new_layers)
    new_cache["pos"] = pos + 1

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits_head(params, x[:, 0])
    return logits, new_cache


def prefill(params: Params, batch: dict, cfg: ArchConfig, max_len: int,
            mesh=None, cache_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """Single-pass prompt processing: last-token logits + filled cache."""
    x = _embed_input(params, batch, cfg)
    b, t = x.shape[:2]
    cdt = _dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    positions = batch.get("positions")
    cache = init_cache(cfg, b, max_len, cache_dtype)
    s = min(max_len, cfg.window) if cfg.window else max_len

    dense_keys = ("latent", "rope") if cfg.attn == "mla" else ("k", "v")
    for i, bp in enumerate(params.get("dense_blocks", [])):
        with observers.scope("dense_blocks", i):
            x, e = _block_forward(bp, x, cfg, positions, mesh, want_cache=True)
        from repro.nn.attention import _to_cache as _tc
        for k in dense_keys:
            cache[f"dense_{k}"] = cache[f"dense_{k}"].at[i].set(
                _tc(_ring_align(e[k], s, t), cache_dtype))

    def body(carry, bp):
        out, entry = _block_forward(bp, carry, cfg, positions, mesh, want_cache=True)
        return out, entry

    x, entries = jax.lax.scan(body, x, params["blocks"])

    from repro.nn.attention import _to_cache

    for key in ("latent", "rope", "k", "v"):
        if key in entries:
            cache[key] = _to_cache(_ring_align(entries[key], s, t), cache_dtype)
    if cfg.parallel_ssm:
        cache["ssm_conv"] = entries["ssm_conv"].astype(jnp.float32)
        cache["ssm_h"] = entries["ssm_h"].astype(jnp.float32)
    cache["pos"] = jnp.asarray(t, jnp.int32)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits_head(params, x[:, -1])
    return logits, cache


# ---------------------------------------------------------------------------
# serving: slot-indexed decode (continuous batching)
# ---------------------------------------------------------------------------
#
# The serving engine keeps ONE pooled cache of shape (slots, ...) with a
# per-slot write cursor ("lengths") instead of the single shared "pos"
# scalar.  ``decode_slots`` processes a fixed-shape (slots, C) token block
# where each row advances by its own ``n_valid[b] <= C`` tokens:
#
#   * C == 1           -> continuous decode over heterogeneous sequences;
#   * C == chunk size  -> one bounded-shape chunk of a prompt (chunked
#                         prefill) — and, in a MIXED batch, decode rows
#                         riding the same call with n_valid == 1, so a
#                         running decode never stalls behind a prefill turn.
#
# n_valid is fully per-row: any mix of 0 (idle padding), 1 (decode) and C
# (whole prompt chunk) is valid in one call.  Rows with n_valid == 0 are
# padding: their K/V writes land beyond their cursor (never attended,
# overwritten by the slot's next real tokens) and their cursor does not
# move — so the jitted step only ever sees the two shapes (slots, 1) and
# (slots, chunk) and never recompiles mid-serve.


def _slot_unsupported(cfg: ArchConfig) -> str | None:
    if cfg.window is not None:
        return "sliding-window ring caches have no per-slot phase yet"
    if cfg.parallel_ssm:
        return "parallel-SSM state is not slot-managed yet"
    if cfg.attn == "none":
        return "arch has no attention cache"
    return None


def init_slot_cache(cfg: ArchConfig, slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    """Pooled (slots, ...) decode cache with per-slot write cursors."""
    reason = _slot_unsupported(cfg)
    if reason is not None:
        raise NotImplementedError(f"slot decode for {cfg.name}: {reason}")
    cache = init_cache(cfg, slots, max_len, dtype)
    del cache["pos"]
    cache["lengths"] = jnp.zeros((slots,), jnp.int32)
    return cache


def init_paged_slot_cache(cfg: ArchConfig, num_blocks: int, block_size: int,
                          slots: int, dtype=jnp.bfloat16) -> dict:
    """Block-pool decode cache: KV leaves are indexed by PHYSICAL block id
    on axis 1 — ``(L, num_blocks, ..., block_size, d)`` — instead of by
    slot.  Per-slot block tables (an input to ``decode_slots``, managed by
    ``repro.serving.paged``) map logical token positions onto pool rows;
    ``lengths`` stays the per-slot write cursor.  Row 0 of the pool is the
    reserved NULL block that padding table entries point at — it is never
    allocated, so stale gathers from it are masked and stale scatters to
    it rewrite its own unchanged content."""
    reason = _slot_unsupported(cfg)
    if reason is not None:
        raise NotImplementedError(f"paged decode for {cfg.name}: {reason}")
    n_scan = cfg.n_layers - cfg.first_dense_layers
    cache: dict = {"lengths": jnp.zeros((slots,), jnp.int32)}
    if cfg.attn == "mla":
        cache["latent"] = jnp.zeros(
            (n_scan, num_blocks, block_size, cfg.kv_lora_rank), dtype)
        cache["rope"] = jnp.zeros(
            (n_scan, num_blocks, block_size, cfg.qk_rope_dim), dtype)
    elif cfg.attn == "gqa":
        cache["k"] = jnp.zeros(
            (n_scan, num_blocks, cfg.kv_heads, block_size, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros(
            (n_scan, num_blocks, cfg.kv_heads, block_size, cfg.head_dim), dtype)
    if cfg.first_dense_layers:
        fd = cfg.first_dense_layers
        if cfg.attn == "mla":
            cache["dense_latent"] = jnp.zeros(
                (fd, num_blocks, block_size, cfg.kv_lora_rank), dtype)
            cache["dense_rope"] = jnp.zeros(
                (fd, num_blocks, block_size, cfg.qk_rope_dim), dtype)
        else:
            cache["dense_k"] = jnp.zeros(
                (fd, num_blocks, cfg.kv_heads, block_size, cfg.head_dim), dtype)
            cache["dense_v"] = jnp.zeros(
                (fd, num_blocks, cfg.kv_heads, block_size, cfg.head_dim), dtype)
    return cache


def rollback_slots(cache: dict, new_lengths) -> dict:
    """Retreat per-slot write cursors (speculative-decode rollback).

    Moving ``lengths`` back is a complete rollback for every cache layout
    this module builds: the attention mask hides entries at positions
    ``>= lengths[b]``, and ``_slot_update`` writes a slot's next tokens
    over those positions BEFORE attention reads the cache — so stale
    K/V from rejected speculative tokens is never attended and is
    overwritten before it can be.  Works identically for the contiguous
    and paged layouts (``lengths`` is slot-indexed in both; the paged
    block tables are position-stable so no block bookkeeping changes).
    """
    out = dict(cache)
    out["lengths"] = jnp.asarray(new_lengths, jnp.int32)
    return out


def _paged_gather(pool: jax.Array, bt: jax.Array) -> jax.Array:
    """Assemble each slot's logically-contiguous KV view from the block
    pool.  pool: (num_blocks, ..., block_size, d), block axis -2;
    bt: (slots, nb) physical ids.  Returns (slots, ..., nb*block_size, d)
    — exactly the contiguous slot-cache layout, so the attention math and
    the clamp-aware ``_slot_update`` run unchanged on the view."""
    g = pool[bt]  # (slots, nb, ..., bs, d)
    g = jnp.moveaxis(g, 1, -3)  # (slots, ..., nb, bs, d)
    return g.reshape(g.shape[:-3] + (g.shape[-3] * g.shape[-2], g.shape[-1]))


def _paged_scatter(pool: jax.Array, bt: jax.Array, view: jax.Array) -> jax.Array:
    """Write each slot's updated contiguous view back into its blocks.
    Duplicate ids across rows (shared prefix blocks, NULL-block padding)
    are safe: shared blocks are frozen — every row's cursor is past them,
    so all duplicates carry bit-identical content and scatter order cannot
    matter.  The serving layer guarantees writable blocks are uniquely
    owned (copy-on-write happens host-side before the step)."""
    nb = bt.shape[1]
    bs = pool.shape[-2]
    blocks = view.reshape(view.shape[:-2] + (nb, bs, view.shape[-1]))
    return pool.at[bt].set(jnp.moveaxis(blocks, -3, 1))


def _slot_update(cache_arr: jax.Array, update: jax.Array, starts: jax.Array,
                 n_valid: jax.Array):
    """Per-row write: row b's first ``n_valid[b]`` update columns land at
    [starts[b], starts[b]+n_valid[b]) on the -2 axis of row b.

    Padding columns (>= n_valid[b]) are blended back to the OLD cache
    values, so they never write.  This matters beyond hygiene:
    ``dynamic_update_slice`` CLAMPS out-of-range starts.  A padding row
    (n_valid == 0) whose cursor exceeds S - C would otherwise have its
    block write clamped back over valid, attended entries — and a MIXED
    batch legitimately carries short rows deep in their stripe (a decode
    row with n_valid == 1 riding a chunk-shaped call can sit anywhere up
    to S - 1).  The write is therefore clamp-aware: the update block is
    rolled by the clamp displacement so its valid head still lands at
    [starts, starts + n_valid), and the blend mask is expressed in the
    clamped coordinates.  For rows that do not clamp this reduces to the
    plain masked blend."""
    c_len = update.shape[-2]

    def write(c, u, st, nv):
        s = c.shape[-2]
        if c_len > 1:
            # where dynamic_update_slice will actually place the block
            st_eff = jnp.clip(st, 0, max(s - c_len, 0))
            shift = st - st_eff  # > 0 only when the raw start would clamp
            u = jnp.roll(u, shift, axis=-2)  # u[0] realigns to cache col st
            idx = jnp.arange(c_len)
            mask = (idx >= shift) & (idx < shift + nv)
            st = st_eff
        else:
            # static fast path: a one-column write can never clamp (every
            # cursor is <= S - 1), so skip the dynamic roll on the thin
            # (slots, 1) decode step — the hottest per-layer write
            mask = jnp.arange(c_len) < nv
        start = (0,) * (c.ndim - 2) + (st, 0)
        old = jax.lax.dynamic_slice(c, start, u.shape)
        mask = mask.reshape((1,) * (u.ndim - 2) + (c_len, 1))
        return jax.lax.dynamic_update_slice(c, jnp.where(mask, u, old), start)

    return jax.vmap(write)(cache_arr, update, starts, n_valid)


def _gqa_slots(bp, h, lc: dict, lengths, n_valid, cfg: ArchConfig, positions):
    """Multi-token slot attention.  h: (B, C, D); lc k/v: (B, Hkv, S, hd);
    positions: (B, C) absolute positions lengths[b] + i."""
    from repro.nn.attention import _from_cache, _to_cache

    acfg = cfg.attn_config()
    b, c, _ = h.shape
    q, k, v = attn_lib._project_qkv(bp, h, acfg, attn_lib._angles(acfg, positions))
    k_c = _slot_update(lc["k"], _to_cache(jnp.moveaxis(k, 1, 2), lc["k"].dtype),
                       lengths, n_valid)
    v_c = _slot_update(lc["v"], _to_cache(jnp.moveaxis(v, 1, 2), lc["v"].dtype),
                       lengths, n_valid)
    hq, hkv, d = acfg.n_heads, acfg.kv_heads, acfg.head_dim
    g = hq // hkv
    qg = q.reshape(b, c, hkv, g, d)
    logits = jnp.einsum("bqhgd,bhkd->bhgqk", qg, _from_cache(k_c, q.dtype)) * (
        d**-0.5)
    s = k_c.shape[2]
    # causal + filled-cache combined: key j visible to query i iff j <= pos_i
    mask = jnp.arange(s)[None, None, :] <= positions[:, :, None]  # (B, C, S)
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bhkd->bqhgd", probs, _from_cache(v_c, q.dtype))
    y = dense(bp["o"], ctx.reshape(b, c, acfg.q_dim), name="o")
    return y, {"k": k_c, "v": v_c}


def _mla_slots(bp, h, lc: dict, lengths, n_valid, cfg: ArchConfig, positions):
    """Weight-absorbed MLA slot attention over the pooled latent cache."""
    mcfg = cfg.mla_config()
    b, c, _ = h.shape
    q_nope, q_rope = attn_lib._mla_q(bp, h, mcfg, positions)
    latent_t, k_rope_t = attn_lib._mla_latent(bp, h, mcfg, positions)
    lat_c = _slot_update(lc["latent"], latent_t.astype(lc["latent"].dtype),
                         lengths, n_valid)
    rope_c = _slot_update(lc["rope"], k_rope_t.astype(lc["rope"].dtype),
                          lengths, n_valid)

    w_b = bp["kv_b"]["w"].reshape(
        mcfg.kv_lora_rank, mcfg.n_heads, mcfg.qk_nope_dim + mcfg.v_head_dim
    )
    w_uk, w_uv = w_b[..., : mcfg.qk_nope_dim], w_b[..., mcfg.qk_nope_dim :]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = mcfg.qk_head_dim**-0.5
    lat = lat_c.astype(h.dtype)
    logits = (
        jnp.einsum("bqhr,bkr->bhqk", q_lat, lat)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, rope_c.astype(h.dtype))
    ) * scale
    s = lat_c.shape[1]
    mask = jnp.arange(s)[None, None, :] <= positions[:, :, None]  # (B, C, S)
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(h.dtype)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs, lat)
    ctx = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_uv)
    y = dense(bp["o"], ctx.reshape(b, c, -1), name="o")
    return y, {"latent": lat_c, "rope": rope_c}


def _block_decode_slots(bp: dict, x, lc: dict, lengths, n_valid,
                        cfg: ArchConfig, positions, mesh, block_tables=None):
    h = apply_norm(cfg.norm, bp["attn_norm"], x)
    pool_lc = None
    if block_tables is not None:
        # paged layout: gather each slot's blocks into the contiguous view
        # the slot attention expects, run it unchanged, scatter back
        pool_lc = lc
        lc = {k: _paged_gather(v, block_tables) for k, v in lc.items()}
    if cfg.attn == "mla":
        a, new = _mla_slots(bp["attn"], h, lc, lengths, n_valid, cfg, positions)
    else:
        a, new = _gqa_slots(bp["attn"], h, lc, lengths, n_valid, cfg, positions)
    if pool_lc is not None:
        new = {k: _paged_scatter(pool_lc[k], block_tables, v)
               for k, v in new.items()}
    x = (x + a).astype(x.dtype)
    h = apply_norm(cfg.norm, bp["mlp_norm"], x)
    if cfg.mlp == "moe" and "router" in bp["mlp"]:
        m = moe_lib.moe_apply(bp["mlp"], h, cfg.moe_config(), mesh=mesh)
    elif cfg.mlp == "gelu":
        m = gelu_mlp(bp["mlp"], h)
    else:
        m = swiglu(bp["mlp"], h)
    return (x + m).astype(x.dtype), new


def decode_slots(params: Params, tokens: jax.Array, cache: dict,
                 cfg: ArchConfig, n_valid: jax.Array,
                 mesh=None, block_tables=None,
                 unroll_layers: bool = False) -> tuple[jax.Array, dict]:
    """Fixed-shape continuous-batching step.

    tokens: (slots, C) int32 — row b's first ``n_valid[b]`` entries are real
    (its next prompt chunk, or its one decode token), the rest padding.
    Returns (logits (slots, C, V) f32, cache with per-row cursors advanced
    by ``n_valid``).  The caller reads row b's logits at column
    ``n_valid[b] - 1``.

    ``block_tables`` selects the PAGED cache layout: a (slots, nb) int32
    map from each slot's logical block index to a physical row of the
    block-pool cache (``init_paged_slot_cache``).  Each layer gathers the
    slot's blocks into the contiguous view, runs the identical attention +
    clamp-aware cursor write, and scatters the touched blocks back — so the
    paged step is token-identical to the contiguous one by construction.
    Table shape is fixed, so each layout keeps its own two compiled shapes.

    Speculative decode (:mod:`repro.serving.speculative`) runs this same
    step twice per round with two parameter sets over ONE cache: k thin
    ``(slots, 1)`` calls with the approximate draft params (writing draft
    K/V at [L, L+k)), then one chunk-shaped call with the exact params
    whose verify rows carry ``n_valid = k+1`` and overwrite [L, L+k] with
    exact K/V.  Rollback between and after the phases is
    :func:`rollback_slots` — a pure cursor move, sound because writes land
    before attention and positions past the cursor are masked.  The C == 1
    fast path in ``_slot_update`` asserts no clamping, so draft cursors
    must stay ``<= max_len - 1``; the serving layer guarantees it by
    capping k at the request's remaining generation budget minus one.

    Kernel decode specialization: the packed-dense fast path keys its tile
    choice on the flattened row count slots*C, so continuous decode (C == 1,
    slots <= repro.kernels.ops.DECODE_M_MAX) runs thin-M single-K-step
    launches while prefill chunks (C == prefill_chunk) keep prefill tiles —
    both from the same jitted step, one compiled shape each.

    ``unroll_layers`` replaces the layer ``lax.scan`` with a python loop
    (per-layer ``observers.scope``d) — ``lax.scan`` traces its body even
    when run eagerly, so concrete per-layer values only exist unrolled.
    The approximation-error probe (:mod:`repro.quant.error_probe`) runs
    its eager single-row forwards this way; the jitted serving step never
    sets it (the scan keeps HLO size O(1) in depth).
    """
    reason = _slot_unsupported(cfg)
    if reason is not None:
        raise NotImplementedError(f"slot decode for {cfg.name}: {reason}")
    b, c = tokens.shape
    cdt = _dtype(cfg.compute_dtype)
    lengths = cache["lengths"]
    n_valid = jnp.asarray(n_valid, jnp.int32)
    if block_tables is not None:
        block_tables = jnp.asarray(block_tables, jnp.int32)
    positions = lengths[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    x = embed(params["embed"], tokens).astype(cdt)
    new_cache = dict(cache)

    dense_keys = ("latent", "rope") if cfg.attn == "mla" else ("k", "v")
    for i, bp in enumerate(params.get("dense_blocks", [])):
        lc = {k: cache[f"dense_{k}"][i] for k in dense_keys}
        with observers.scope("dense_blocks", i):
            x, new = _block_decode_slots(bp, x, lc, lengths, n_valid, cfg,
                                         positions, mesh, block_tables)
        for k in dense_keys:
            new_cache[f"dense_{k}"] = new_cache[f"dense_{k}"].at[i].set(new[k])

    layer_keys = [k for k in ("latent", "rope", "k", "v") if k in cache]
    lcs = {k: cache[k] for k in layer_keys}

    if unroll_layers:
        n_layers = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        acc: dict[str, list] = {k: [] for k in layer_keys}
        for i in range(n_layers):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            lc = {k: lcs[k][i] for k in layer_keys}
            with observers.scope("blocks", i):
                x, new = _block_decode_slots(bp, x, lc, lengths, n_valid,
                                             cfg, positions, mesh,
                                             block_tables)
            for k in layer_keys:
                acc[k].append(new[k])
        new_layers = {k: jnp.stack(acc[k]) for k in layer_keys}
    else:
        def body(x, inp):
            bp, lc = inp
            return _block_decode_slots(bp, x, lc, lengths, n_valid, cfg,
                                       positions, mesh, block_tables)

        x, new_layers = jax.lax.scan(body, x, (params["blocks"], lcs))
    new_cache.update(new_layers)
    new_cache["lengths"] = lengths + n_valid

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = _logits_head(params, x)
    return logits, new_cache


def _ssm_with_state(p, x, scfg):
    """SSM prefill that also returns the final (conv, h) state."""
    y = ssm_lib.ssm_prefill(p, x, scfg)
    # re-derive final state (cheap relative to the scan; shares projections
    # would need scan surgery — conv tail + one more scan over h only)
    xz = dense(p["in_proj"], x, name="in_proj")
    xin, _ = jnp.split(xz, 2, axis=-1)
    conv_state = xin[:, -(scfg.conv_kernel - 1):, :]
    xc = jax.nn.silu(ssm_lib._causal_conv(p, xin))
    dt, bmat, _ = ssm_lib._ssm_inputs(p, scfg, xc)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    def step(h, inp):
        xc_t, dt_t, b_t = inp
        da = jnp.exp(dt_t[..., None] * a)
        return da * h + (dt_t * xc_t)[..., None] * b_t[:, None, :], None

    h0 = jnp.zeros((x.shape[0], scfg.d_inner, scfg.d_state), jnp.float32)
    h, _ = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bmat, 1, 0)),
    )
    return y, {"conv": conv_state, "h": h}
