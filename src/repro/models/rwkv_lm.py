"""RWKV6 (Finch) language model — the attention-free assigned architecture.

Same public surface as models/lm.py (init/forward/train_loss/prefill/
decode_step/init_cache).  Layers are scanned; the decode "cache" is the
constant-size recurrent state (per-layer shift vectors + WKV matrices),
which is what makes long_500k decode O(1) in sequence length.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import apply_norm, embed, init_embedding, init_norm
from repro.nn import rwkv as rwkv_lib

Params = Any


def _dtype(name):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _init_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    rcfg = cfg.rwkv_config()
    return {
        "ln1": init_norm("layernorm", cfg.d_model, dtype),
        "tm": rwkv_lib.init_time_mix(k1, rcfg, dtype),
        "ln2": init_norm("layernorm", cfg.d_model, dtype),
        "cm": rwkv_lib.init_channel_mix(k2, rcfg, dtype),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    return {
        "embed": init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype),
        "ln0": init_norm("layernorm", cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(block_keys),
        "final_norm": init_norm("layernorm", cfg.d_model, dtype),
        "lm_head": {
            "w": (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                  * cfg.d_model**-0.5).astype(dtype)
        },
    }


def _block(bp, x, cfg: ArchConfig, states=None, mesh=None):
    """states: None (zero init) or dict(shift_tm, shift_cm, wkv)."""
    rcfg = cfg.rwkv_config()
    x = _constrain(x, mesh, shard_d=False)  # one bf16 gather per block
    h = apply_norm("layernorm", bp["ln1"], x)
    tm_out, shift_tm, wkv = rwkv_lib.time_mix(
        bp["tm"], h, rcfg,
        shift_state=None if states is None else states["shift_tm"],
        wkv_state=None if states is None else states["wkv"],
    )
    x = x + tm_out
    h = apply_norm("layernorm", bp["ln2"], x)
    cm_out, shift_cm = rwkv_lib.channel_mix(
        bp["cm"], h, shift_state=None if states is None else states["shift_cm"]
    )
    x = x + cm_out
    return x, {"shift_tm": shift_tm, "shift_cm": shift_cm, "wkv": wkv}


def _constrain(x, mesh, shard_d: bool):
    """Residual-stream sharding control (EXPERIMENTS.md §Perf, rwkv6).

    The carry between blocks stays D-SHARDED (channel-parallel residual:
    16x smaller saved activations, and the out-proj all-reduce can lower to
    a reduce-scatter).  Each block then performs ONE explicit bf16
    all-gather at entry.  Without this pinning, GSPMD gathered the f32
    layernorm upcast instead — 16 (B, T, D) f32 gathers per layer."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    spec = P(dp, None, "model") if shard_d else P(dp, None, None)
    if shard_d and x.shape[-1] % mesh.shape["model"] != 0:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def backbone(params, x, cfg: ArchConfig, want_states: bool = False, mesh=None):
    cdt = _dtype(cfg.compute_dtype)
    x = apply_norm("layernorm", params["ln0"], x.astype(cdt))

    def body(carry, bp):
        out, st = _block(bp, carry, cfg, mesh=mesh)
        out = _constrain(out, mesh, shard_d=True)  # D-sharded carry
        return out, st if want_states else None

    if cfg.remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.checkpoint_dots)
    x, states = jax.lax.scan(body, x, params["blocks"])
    x = apply_norm("layernorm", params["final_norm"], x)
    return x, states


def _logits_head(params, x):
    from repro.core.approx_linear import QuantizedDense, dense

    head = params["lm_head"]
    if isinstance(head, QuantizedDense):
        return dense(head, x, name="lm_head").astype(jnp.float32)
    return jnp.matmul(x, head["w"].astype(x.dtype)).astype(jnp.float32)


def forward(params, batch, cfg: ArchConfig, mesh=None):
    x, _ = backbone(params, embed(params["embed"], batch["tokens"]), cfg, mesh=mesh)
    return _logits_head(params, x)


def train_loss(params, batch, cfg: ArchConfig, mesh=None):
    from repro.models.lm import chunked_ce_loss

    x, _ = backbone(params, embed(params["embed"], batch["tokens"]), cfg, mesh=mesh)
    labels = batch["labels"][:, 1:]
    mask = batch.get("mask")
    mask = jnp.ones(labels.shape, jnp.float32) if mask is None else mask[:, 1:]
    return chunked_ce_loss(x[:, :-1], params["lm_head"]["w"], labels, mask)


def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
    """Recurrent state — constant size, independent of max_len."""
    rcfg = cfg.rwkv_config()
    L, d = cfg.n_layers, cfg.d_model
    h, hd = rcfg.n_heads, rcfg.head_dim
    cdt = _dtype(cfg.compute_dtype)
    return {
        "shift_tm": jnp.zeros((L, batch, d), cdt),
        "shift_cm": jnp.zeros((L, batch, d), cdt),
        "wkv": jnp.zeros((L, batch, h, hd, hd), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg: ArchConfig, max_len: int = 0, mesh=None,
            cache_dtype=jnp.bfloat16):
    t = batch["tokens"].shape[1]
    x, states = backbone(params, embed(params["embed"], batch["tokens"]), cfg,
                         want_states=True, mesh=mesh)
    logits = _logits_head(params, x[:, -1])
    cache = {
        "shift_tm": states["shift_tm"].astype(_dtype(cfg.compute_dtype)),
        "shift_cm": states["shift_cm"].astype(_dtype(cfg.compute_dtype)),
        "wkv": states["wkv"],
        "pos": jnp.asarray(t, jnp.int32),
    }
    return logits, cache


def init_slot_cache(cfg: ArchConfig, slots: int, max_len: int = 0,
                    dtype=jnp.bfloat16) -> dict:
    """Per-slot recurrent state (the RWKV 'KV pool' is O(1) per slot)."""
    cache = init_cache(cfg, slots, max_len, dtype)
    del cache["pos"]
    cache["lengths"] = jnp.zeros((slots,), jnp.int32)
    return cache


def decode_slots(params, tokens, cache, cfg: ArchConfig, n_valid,
                 mesh=None):
    """Fixed-shape continuous-batching step for the recurrent arch.

    tokens: (slots, C); row b consumes its first ``n_valid[b]`` tokens.  The
    chunk is a scan of C single-token steps whose state writes are masked
    per row by ``i < n_valid[b]`` — rows past their valid length (and idle
    slots, n_valid == 0) keep their state bit-exact.  Returns
    (logits (slots, C, V) f32, advanced cache).
    """
    b, c = tokens.shape
    cdt = _dtype(cfg.compute_dtype)
    rcfg = cfg.rwkv_config()
    n_valid = jnp.asarray(n_valid, jnp.int32)
    states0 = {k: cache[k] for k in ("shift_tm", "shift_cm", "wkv")}

    def block_body(x, inp):
        bp, st = inp
        h = apply_norm("layernorm", bp["ln1"], x)
        tm_out, shift_tm, wkv = rwkv_lib.time_mix_step(
            bp["tm"], h, rcfg, st["shift_tm"], st["wkv"]
        )
        x = x + tm_out
        h = apply_norm("layernorm", bp["ln2"], x)
        cm_out, shift_cm = rwkv_lib.channel_mix(bp["cm"], h, st["shift_cm"])
        x = x + cm_out
        return x, {"shift_tm": shift_tm.astype(st["shift_tm"].dtype),
                   "shift_cm": shift_cm.astype(st["shift_cm"].dtype),
                   "wkv": wkv}

    def time_step(states, i):
        tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
        x = embed(params["embed"], tok).astype(cdt)
        x = apply_norm("layernorm", params["ln0"], x)
        x, new_states = jax.lax.scan(block_body, x, (params["blocks"], states))
        x = apply_norm("layernorm", params["final_norm"], x)
        logits = _logits_head(params, x[:, 0])
        keep = i < n_valid  # (B,) — leaves are (L, B, ...)
        merged = jax.tree.map(
            lambda new, old: jnp.where(
                keep.reshape((1, -1) + (1,) * (new.ndim - 2)), new, old),
            new_states, states)
        return merged, logits

    states, logits = jax.lax.scan(time_step, states0,
                                  jnp.arange(c, dtype=jnp.int32))
    new_cache = dict(states)
    new_cache["lengths"] = cache["lengths"] + n_valid
    return jnp.moveaxis(logits, 0, 1), new_cache


def decode_step(params, tokens, cache, cfg: ArchConfig, mesh=None):
    cdt = _dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens).astype(cdt)
    x = apply_norm("layernorm", params["ln0"], x)
    rcfg = cfg.rwkv_config()

    def body(x, inp):
        bp, st = inp
        h = apply_norm("layernorm", bp["ln1"], x)
        tm_out, shift_tm, wkv = rwkv_lib.time_mix_step(
            bp["tm"], h, rcfg, st["shift_tm"], st["wkv"]
        )
        x = x + tm_out
        h = apply_norm("layernorm", bp["ln2"], x)
        cm_out, shift_cm = rwkv_lib.channel_mix(bp["cm"], h, st["shift_cm"])
        x = x + cm_out
        return x, {"shift_tm": shift_tm.astype(st["shift_tm"].dtype),
                   "shift_cm": shift_cm.astype(st["shift_cm"].dtype),
                   "wkv": wkv}

    states = {k: cache[k] for k in ("shift_tm", "shift_cm", "wkv")}
    x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
    x = apply_norm("layernorm", params["final_norm"], x)
    logits = _logits_head(params, x[:, 0])
    new_cache = dict(new_states)
    new_cache["pos"] = cache["pos"] + 1
    return logits, new_cache
