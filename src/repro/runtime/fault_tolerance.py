"""Fault tolerance for the training loop.

Components (designed for thousands of nodes; exercised in-process here):

  Heartbeat        background thread stamping liveness to a file an external
                   supervisor (or co-trainer) watches; stale stamp => kill &
                   reschedule the worker.
  StragglerMonitor per-step timing with EMA + MAD threshold; flags slow
                   steps/hosts so the launcher can trigger mitigation
                   (checkpoint-and-replace, or exclude the host at the next
                   elastic re-mesh).  At pod scale stragglers dominate tail
                   latency, so detection is step-granular and cheap.
  RetryPolicy /    restart-from-checkpoint driver: wraps the step loop,
  run_resilient    catches worker failures, restores the latest checkpoint
                   (optionally under a NEW mesh => elastic), skips the data
                   stream ahead deterministically, and resumes.  Simulated
                   failures are injected in tests via a hook.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Callable


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 5.0) -> None:
        self.path = path
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "Heartbeat":
        def run():
            while not self._stop.wait(self.interval):
                self.beat()

        self.beat()
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return self

    def beat(self) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(time.time()))
        os.replace(tmp, self.path)

    def age(self) -> float:
        try:
            with open(self.path) as f:
                return time.time() - float(f.read())
        except FileNotFoundError:
            return float("inf")

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


class StragglerMonitor:
    """EMA + deviation threshold over step wall-times."""

    def __init__(self, threshold: float = 2.0, warmup: int = 5) -> None:
        self.threshold = threshold
        self.warmup = warmup
        self.ema: float | None = None
        self.dev: float = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.ema is None:
            self.ema, self.dev = seconds, seconds * 0.1
            return False
        is_straggler = (
            self.n > self.warmup
            and seconds > self.ema + self.threshold * max(self.dev, 1e-6)
            and seconds > 1.5 * self.ema
        )
        if is_straggler:
            self.flagged.append((step, seconds))
        else:  # only track the typical distribution
            a = 0.1
            self.dev = (1 - a) * self.dev + a * abs(seconds - self.ema)
            self.ema = (1 - a) * self.ema + a * seconds
        return is_straggler


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_failures: int = 3
    backoff_s: float = 0.1
    checkpoint_every: int = 50


def run_resilient(
    *,
    init_state: Callable[[], Any],  # () -> (state pytree)
    step_fn: Callable[[Any, dict, int], Any],  # (state, batch, step) -> state
    loader,  # ShardedLoader
    manager,  # CheckpointManager
    total_steps: int,
    policy: RetryPolicy = RetryPolicy(),
    monitor: StragglerMonitor | None = None,
    on_step: Callable[[int, Any], None] | None = None,
    failure_hook: Callable[[int], None] | None = None,  # tests inject faults
) -> Any:
    """Checkpoint/restart step loop.  Any exception from step_fn (or the
    injected failure hook) triggers restore-from-latest + deterministic data
    skip-ahead; state survives worker death up to policy.max_failures."""
    state = init_state()
    start = 0
    try:
        state, start = manager.restore(state)
        start += 1
    except FileNotFoundError:
        pass
    loader.skip_to(start)

    failures = 0
    step = start
    it = iter(loader)
    while step < total_steps:
        try:
            batch = next(it)
            if failure_hook is not None:
                failure_hook(step)
            t0 = time.time()
            state = step_fn(state, batch, step)
            if monitor is not None:
                monitor.record(step, time.time() - t0)
            if on_step is not None:
                on_step(step, state)
            if (step + 1) % policy.checkpoint_every == 0 or step + 1 == total_steps:
                manager.save(state, step, blocking=False)
            step += 1
        except (FileNotFoundError, KeyboardInterrupt):
            raise
        except Exception:
            failures += 1
            if failures > policy.max_failures:
                raise
            time.sleep(policy.backoff_s * (2 ** (failures - 1)))
            manager.wait()
            try:
                state, last = manager.restore(init_state())
                step = last + 1
            except FileNotFoundError:
                state, step = init_state(), 0
            loader.skip_to(step)
            it = iter(loader)
    manager.wait()
    return state
