"""Distributed runtime: fault tolerance (heartbeat, straggler detection,
resilient step loop), and compute/communication overlap helpers."""

from repro.runtime.fault_tolerance import (
    Heartbeat,
    StragglerMonitor,
    run_resilient,
    RetryPolicy,
)
from repro.runtime.overlap import ag_matmul_overlapped, compressed_psum

__all__ = [
    "Heartbeat",
    "StragglerMonitor",
    "run_resilient",
    "RetryPolicy",
    "ag_matmul_overlapped",
    "compressed_psum",
]
