"""Compute/communication overlap + wire-compressed collectives (shard_map).

  rs_matmul_overlapped   row-parallel matmul with a hand-scheduled ring
                         reduce-scatter + all-gather, chunked so each ring
                         hop's ppermute overlaps the NEXT chunk's dot.
                         Semantically y = x @ W with x, W sharded on the
                         contraction axis; the baseline GSPMD form is
                         dot + all-reduce, which serializes all ICI behind
                         the full matmul.  Here the matmul is emitted as n
                         independent (K/n x N/n) dots interleaved with the
                         ring permutes — the classic latency-hiding
                         collective-matmul decomposition.

  compressed_psum        data-parallel gradient combine that moves int8 on
                         the wire (pairs with optim.grad_compress error
                         feedback): quantize leaf -> all_gather(int8 +
                         f32 scale) -> dequantized mean.  Intended for the
                         cross-pod ("pod") axis where DCN bandwidth, not
                         ICI, is the bottleneck.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_map_compat
from jax.sharding import PartitionSpec as P


def rs_matmul_overlapped(x: jax.Array, w: jax.Array, mesh, axis: str) -> jax.Array:
    """y = x @ W.  x: (..., K) sharded on K over ``axis``; w: (K, N) sharded
    on K.  Returns y replicated over ``axis``.

    Ring schedule per device i (n = ring size, N split into n chunks):
      reduce-scatter phase, n-1 steps: the traveling accumulator for output
      chunk c = (i - s) mod n picks up this device's partial
      x_i @ W_i[:, c] and moves on; the ppermute of step s overlaps the dot
      of step s+1 (no data dependence).
      all-gather phase, n-1 steps: the finished chunks circulate back.
    """
    n = mesh.shape[axis]
    nn = w.shape[1]
    assert nn % n == 0, (nn, n)
    chunk = nn // n

    def shard_fn(xs, ws):
        idx = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % n) for i in range(n)]

        def local_part(c):
            wsc = jax.lax.dynamic_slice_in_dim(ws, c * chunk, chunk, axis=1)
            return jax.lax.dot_general(
                xs, wsc, (((xs.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        # reduce-scatter: after n-1 hops, device i holds the summed chunk
        # (i + 1) mod n.
        acc = local_part((idx - 0) % n)
        for s in range(1, n):
            acc = jax.lax.ppermute(acc, axis, fwd)
            acc = acc + local_part((idx - s) % n)
        own = (idx - (n - 1)) % n  # chunk id now resident on this device

        # all-gather the n finished chunks (ring broadcast).
        pieces = [(own, acc)]
        blk = acc
        for _ in range(n - 1):
            blk = jax.lax.ppermute(blk, axis, fwd)
            pieces.append((None, blk))
        # chunk resident after hop h came from device i-h => chunk (own - h).
        out = jnp.zeros(xs.shape[:-1] + (nn,), jnp.float32)
        for h, (_, piece) in enumerate(pieces):
            c = (own - h) % n
            out = jax.lax.dynamic_update_slice_in_dim(
                out, piece, c * chunk, axis=out.ndim - 1
            )
        return out.astype(xs.dtype)

    return shard_map_compat(
        shard_fn,
        mesh=mesh,
        in_specs=(P(*((None,) * (x.ndim - 1) + (axis,))), P(axis, None)),
        out_specs=P(),
    )(x, w)


# kept under both names: ag_* was the working title used in DESIGN notes
ag_matmul_overlapped = rs_matmul_overlapped


def compressed_psum(grads: Any, mesh, axis: str) -> Any:
    """Data-parallel mean of gradient pytrees with int8 wire format.

    Each leaf: quantize locally (absmax/127) -> all_gather(int8) +
    all_gather(scale f32) -> dequantized mean.  ~4x fewer wire bytes than a
    f32 all-reduce; pair with optim.grad_compress error feedback so the
    quantization bias vanishes across steps.
    """
    n = mesh.shape[axis]

    def leaf_fn(g):
        def shard_fn(gl):
            scale = jnp.maximum(jnp.max(jnp.abs(gl)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gl / scale), -127, 127).astype(jnp.int8)
            qs = jax.lax.all_gather(q, axis)  # int8 on the wire
            ss = jax.lax.all_gather(scale, axis)
            deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * gl.ndim)
            return jnp.mean(deq, axis=0).astype(gl.dtype)

        return shard_map_compat(
            shard_fn,
            mesh=mesh,
            in_specs=P(*((None,) * g.ndim)),
            out_specs=P(*((None,) * g.ndim)),
        )(g)

    return jax.tree.map(leaf_fn, grads)
