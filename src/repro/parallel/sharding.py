"""PartitionSpec rules for every architecture in the zoo.

Megatron-style TP on the "model" axis (column-parallel QKV/up projections,
row-parallel O/down), vocab-parallel embeddings/heads, expert-parallel MoE
stacks, head- or sequence-sharded decode caches, and optional FSDP (2D
weight sharding over ("data", "model")) for the large dense train cells.

The engine is shape-aware: `fit_spec` drops any sharding a dimension cannot
honor (e.g. hymba's 32001 vocab is not divisible by 16 -> the embedding
falls back to replicated), so one rule set serves all 10 architectures and
every mesh, including the reduced CPU meshes used in tests.

Batch ("data") sharding composes ("pod", "data") on the multi-pod mesh —
DP across pods (gradient all-reduce over DCN), TP inside a pod (ICI).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _dp_axes(mesh: Mesh):
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    return tuple(axes) if axes else None


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version
    (new: top-level + ``check_vma``; old: experimental + ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dims the shape cannot honor (non-divisible/too small)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % n == 0 and shape[i] >= n else None)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                    for k in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (regex on path, spec for the LAST ndims of the leaf).  First match wins.
# Leading (layer-stack / expert) dims are padded with the stack spec.
_COL = "COL"  # (in, out) -> P(maybe_fsdp, "model")
_ROW = "ROW"  # (in, out) -> P("model", maybe_fsdp)

_PARAM_RULES: list[tuple[str, Any]] = [
    # embeddings / heads (vocab-parallel)
    (r"embed/table$", P("model", None)),
    (r"lm_head/w$", P(None, "model")),
    # MoE: router replicated; expert stacks sharded on the expert dim
    (r"router/", P(None, None)),
    (r"experts/(gate|up)/w$", P("model", None, None)),
    (r"experts/down/w$", P("model", None, None)),
    # attention projections (qkv = the fan-out-fused Q|K|V group: its
    # concatenated output axis is column-parallel exactly like the members)
    (r"attn/(q|k|v|qkv)/w$", _COL),
    (r"attn/o/w$", _ROW),
    (r"attn/(q|k|v|qkv)/b$", P("model")),
    (r"attn/kv_a/", P(None, None)),  # tiny latent projection: replicate
    (r"attn/kv_b/w$", _COL),
    # MLPs (gateup = the fused gate|up group, column-parallel like members)
    (r"(mlp|shared)/(gate|up|gateup)/w$", _COL),
    (r"(mlp|shared)/down/w$", _ROW),
    (r"(mlp|shared)/(up|gateup)/b$", P("model")),
    (r"(mlp|shared)/down/b$", P(None)),
    # SSM (d_inner sharded on model)
    (r"ssm/in_proj/w$", _COL),
    (r"ssm/out_proj/w$", _ROW),
    (r"ssm/conv_w$", P(None, "model")),
    (r"ssm/conv_b$", P("model")),
    (r"ssm/x_proj/w$", P("model", None)),
    (r"ssm/dt_proj/w$", P(None, "model")),
    (r"ssm/dt_proj/b$", P("model")),
    (r"ssm/a_log$", P("model", None)),
    (r"ssm/d_skip$", P("model")),
    # RWKV time/channel mix
    (r"tm/(r|k|v|g)/w$", _COL),
    (r"tm/out/w$", _ROW),
    (r"tm/bonus$", P("model", None)),
    # decay-LoRA output + per-head norm scales sharded on "model": keeps the
    # (B, T, D) f32 decay tensors/cotangents head-sharded end to end — the
    # replicated versions forced ~22 (B,T,D) f32 all-gathers per layer
    # (EXPERIMENTS.md §Perf, rwkv6 iteration 3)
    (r"tm/decay_w2$", P(None, "model")),
    (r"tm/ln_x_(scale|bias)$", P("model")),
    (r"cm/key/w$", _COL),
    (r"cm/value/w$", _ROW),
    (r"cm/receptance/w$", _COL),
]


def _base_spec(path: str, ndim: int, fsdp: bool):
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            if spec == _COL:
                return P("data" if fsdp else None, "model")
            if spec == _ROW:
                return P("model", "data" if fsdp else None)
            return spec
    return None  # replicate


def _packed_leaf_spec(path: str, ndim: int, fsdp: bool):
    """Specs for QuantizedDense / PackedLinear leaves: derive from the parent
    linear's (in, out) rule.  Weight-shaped operands (w_q, the blocked
    serving codes, folded A/B matrices) shard like w; per-output vectors
    (c, c0, sum_qw, bias, epilogue table, fold delta) shard like the out
    dim; scalars/meta replicate."""
    m = re.search(
        r"(.*)/(pack|a_qp|blocked|fold)/"
        r"(w_q|sum_qw|c|c0|bias|w_scale|w_zp|scale|zero_point"
        r"|w_qb|epilogue|meta|A|B|delta|sa|za)$", path)
    if not m:
        return None
    parent, _, leaf = m.groups()
    base = _base_spec(parent + "/w", 2, fsdp)
    if base is None:
        return P()
    out_axis = base[1] if len(base) > 1 else None
    if leaf in ("w_q", "A"):
        return base
    if leaf == "w_qb" or leaf == "B":
        # K axis is padded/stacked in tile multiples: shard the out dim only
        return P(None, out_axis)
    if leaf in ("sum_qw", "c", "c0", "bias", "delta"):
        return P(out_axis)
    if leaf == "epilogue":
        return P(None, out_axis)
    return P()  # scalars / meta


def param_shardings(abstract_params: Any, mesh: Mesh, cfg: ArchConfig | None = None,
                    fsdp: bool = False, dp_only: bool = False) -> Any:
    """NamedSharding tree for a (possibly packed/stacked) parameter tree.

    dp_only: ZeRO-3 layout — every weight 1D-sharded over ALL mesh axes
    combined, no tensor parallelism.  The right layout for small
    attention-free models where TP activation collectives dominate
    (EXPERIMENTS.md §Perf, rwkv6 cell)."""
    all_axes = tuple(mesh.axis_names)

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        ndim = len(leaf.shape)
        if dp_only:
            if ndim >= 2:
                # shard the largest trailing dim over the flat mesh
                dims = list(leaf.shape)
                target = int(np.argmax(dims))
                spec = P(*(all_axes if i == target else None for i in range(ndim)))
            elif ndim == 1:
                spec = P(all_axes)
            else:
                spec = P()
            return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
        spec = _packed_leaf_spec(pstr, ndim, fsdp)
        if spec is None:
            spec = _base_spec(pstr, ndim, fsdp)
        if spec is None:
            spec = P()
        # pad leading stacked dims (layer stacks / per-layer packs)
        if len(spec) < ndim:
            spec = P(*((None,) * (ndim - len(spec)) + tuple(spec)))
        spec = fit_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    return jax.tree_util.tree_unflatten(treedef, [leaf_spec(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(abstract_batch: Any, mesh: Mesh, dp_only: bool = False) -> Any:
    """Shard the leading (batch) dim over ("pod","data") — or over ALL axes
    in dp_only (ZeRO-3) mode; positions for M-RoPE are (3, B, T) -> batch is
    dim 1."""
    dp = tuple(mesh.axis_names) if dp_only else _dp_axes(mesh)

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if dp is None or not shape:
            return NamedSharding(mesh, P())
        if pstr.endswith("positions") and len(shape) == 3:
            spec = P(None, dp, None)
        else:
            spec = P(*((dp,) + (None,) * (len(shape) - 1)))
        return NamedSharding(mesh, fit_spec(spec, shape, mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_batch)
    return jax.tree_util.tree_unflatten(treedef, [leaf_spec(p, l) for p, l in flat])


def cache_shardings(abstract_cache: Any, mesh: Mesh, cfg: ArchConfig) -> Any:
    """Decode-cache shardings.

    GQA k/v (L, B, H, S, d): heads on "model" when divisible, else the
    SEQUENCE is sharded on "model" (attention then computes partial scores
    per shard and GSPMD inserts the softmax all-reduces — the
    collective-bound decode baseline discussed in EXPERIMENTS.md).
    MLA latent (L, B, S, r): sequence on "model" (no head dim exists).
    SSM / RWKV states: inner/head dims on "model".
    """
    dp = _dp_axes(mesh)
    msize = mesh.shape["model"]
    heads_ok = cfg.kv_heads % msize == 0

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        if re.search(r"(dense_)?(k|v)$", pstr) and len(shape) == 5:
            spec = (P(None, dp, "model", None, None) if heads_ok
                    else P(None, dp, None, "model", None))
        elif re.search(r"(dense_)?latent$", pstr):
            spec = P(None, dp, "model", None)
        elif re.search(r"(dense_)?rope$", pstr):
            spec = P(None, dp, "model", None)
        elif pstr.endswith("ssm_conv"):
            spec = P(None, dp, None, "model")
        elif pstr.endswith("ssm_h"):
            spec = P(None, dp, "model", None)
        elif pstr.endswith("wkv"):
            spec = P(None, dp, "model", None, None)
        elif pstr.endswith("shift_tm") or pstr.endswith("shift_cm"):
            spec = P(None, dp, None)
        else:
            spec = P()
        return NamedSharding(mesh, fit_spec(spec, shape, mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    return jax.tree_util.tree_unflatten(treedef, [leaf_spec(p, l) for p, l in flat])
