"""Parallelism: PartitionSpec rule engine mapping parameter/cache/batch trees
to mesh shardings (TP + DP/FSDP + EP + sequence sharding for decode)."""

from repro.parallel.sharding import (
    param_shardings,
    batch_shardings,
    cache_shardings,
    fit_spec,
)

__all__ = ["param_shardings", "batch_shardings", "cache_shardings", "fit_spec"]
