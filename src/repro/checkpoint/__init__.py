"""Checkpointing: atomic, async, shard-per-process tensor store with
elastic re-mesh restore."""

from repro.checkpoint.manager import (CheckpointManager, load_pytree,
                                      read_meta, save_pytree)
from repro.checkpoint.elastic import restore_with_sharding

__all__ = [
    "CheckpointManager",
    "save_pytree",
    "load_pytree",
    "read_meta",
    "restore_with_sharding",
]
