"""Tensor-store checkpointing: msgpack + optional zstd, atomic renames,
async saves.

Layout:  <dir>/step_<N>/shard_<process>.ckpt  +  <dir>/step_<N>/DONE
Each shard file holds the process-local (addressable) values of every leaf;
in this single-process container that is the full tree — the format and the
commit protocol (write tmp -> fsync -> rename -> DONE marker) are the
multi-host ones.  Restores pick the newest step with a DONE marker, so a
failure mid-save can never corrupt the restore point (crash-consistency is
tested by killing a save halfway).

Compression is negotiable: shard files carry a 4-byte magic plus a codec
tag ("zstd" | "zlib" | "none"), so a container without the ``zstandard``
wheel falls back to stdlib zlib (or raw) and checkpoints stay portable
between environments.  Legacy headerless zstd frames are still readable.

Shard files also carry a free-form metadata dict (the ``__meta__`` record):
packed serving checkpoints persist their :class:`~repro.numerics.NumericsSpec`
there, so the exact per-layer approximation recipe travels with the weights
(``read_meta`` / ``CheckpointManager.numerics`` recover it without needing a
template tree; the shard is still decompressed/decoded to reach the header,
so treat it as a per-restore audit, not a hot-path fleet poll).
"""

from __future__ import annotations

import os
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: best ratio/speed, but not baked into every container
    import zstandard
except ImportError:  # pragma: no cover - exercised where the wheel is absent
    zstandard = None

#: shard-file header: magic + 4-char codec tag, then the compressed payload
_MAGIC = b"RPK1"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy headerless files


def _default_codec() -> str:
    return "zstd" if zstandard is not None else "zlib"


def _flatten(tree: Any) -> tuple[list[tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        items.append((key, np.asarray(leaf)))
    return items, treedef


def _pack(items: list[tuple[str, np.ndarray]], codec: str | None = None,
          meta: dict | None = None) -> bytes:
    codec = codec or _default_codec()
    payload = {
        key: {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
        for key, arr in items
    }
    # codec tag rides in the msgpack metadata too, so tooling that only sees
    # the decoded payload still knows how the shard was written; callers may
    # attach extra metadata (e.g. the NumericsSpec the tree was packed under)
    raw = msgpack.packb({"__meta__": {"codec": codec, **(meta or {})},
                         "leaves": payload},
                        use_bin_type=True)
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError("codec 'zstd' requested but zstandard is not installed")
        body = zstandard.ZstdCompressor(level=3).compress(raw)
    elif codec == "zlib":
        body = zlib.compress(raw, 3)
    elif codec == "none":
        body = raw
    else:
        raise ValueError(f"unknown checkpoint codec {codec!r}")
    return _MAGIC + codec.encode("ascii").ljust(4) + body


def _decode(blob: bytes) -> dict:
    """Shard bytes -> the decoded msgpack payload (meta + leaves)."""
    if blob[:4] == _MAGIC:
        codec = blob[4:8].rstrip().decode("ascii")
        body = blob[8:]
        if codec == "zstd":
            if zstandard is None:
                raise RuntimeError(
                    "checkpoint was written with zstd but zstandard is not "
                    "installed; re-save with codec='zlib' or install the wheel")
            raw = zstandard.ZstdDecompressor().decompress(body)
        elif codec == "zlib":
            raw = zlib.decompress(body)
        elif codec == "none":
            raw = body
        else:
            raise ValueError(f"unknown checkpoint codec {codec!r}")
    elif blob[:4] == _ZSTD_FRAME_MAGIC:  # pre-header files (always zstd)
        if zstandard is None:
            raise RuntimeError(
                "legacy zstd checkpoint but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:  # pre-header uncompressed msgpack
        raw = blob
    payload = msgpack.unpackb(raw, raw=False)
    if "__meta__" not in payload:  # pre-header layout: leaves at top level
        payload = {"__meta__": {}, "leaves": payload}
    return payload


def _unpack(blob: bytes) -> dict[str, np.ndarray]:
    out = {}
    for key, rec in _decode(blob)["leaves"].items():
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        out[key] = arr.reshape(rec["shape"])
    return out


def read_meta(path: str) -> dict:
    """Shard metadata (codec tag plus anything save_pytree attached, e.g.
    ``{"numerics": <NumericsSpec dict>}``).  Needs no template tree, but
    does decompress/decode the shard to reach the header."""
    with open(path, "rb") as f:
        return _decode(f.read())["__meta__"]


def save_pytree(tree: Any, path: str, codec: str | None = None,
                meta: dict | None = None) -> None:
    """Atomic single-file save (library-level; the manager adds steps/async).

    ``codec`` is "zstd" | "zlib" | "none"; default prefers zstd when the
    wheel is available and falls back to stdlib zlib otherwise.  ``meta``
    is an optional JSON-safe dict stored in the shard header (recovered by
    :func:`read_meta`); "codec" is a reserved key.
    """
    if meta and "codec" in meta:
        raise ValueError("'codec' is a reserved metadata key")
    items, _ = _flatten(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_pack(items, codec, meta))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_pytree(template: Any, path: str) -> Any:
    """Load into the structure of ``template`` (dtypes/shapes verified)."""
    with open(path, "rb") as f:
        stored = _unpack(f.read())
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = stored[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != template {want_shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Step-scoped checkpoints with retention, async commit, and resume."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- paths ---------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "DONE")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------

    def _save_sync(self, tree: Any, step: int, meta: dict | None = None) -> None:
        sdir = self._step_dir(step)
        tmp_dir = sdir + ".tmp"
        shutil.rmtree(tmp_dir, ignore_errors=True)
        os.makedirs(tmp_dir, exist_ok=True)
        shard = jax.process_index()
        save_pytree(tree, os.path.join(tmp_dir, f"shard_{shard:05d}.ckpt"),
                    meta=meta)
        os.replace(tmp_dir, sdir)
        with open(os.path.join(sdir, "DONE"), "w") as f:
            f.write(str(time.time()))
        self._gc()

    def save(self, tree: Any, step: int, blocking: bool = True,
             numerics: Any = None) -> None:
        """``numerics`` (a NumericsSpec, or its dict form) is persisted in
        the shard metadata so a packed serving checkpoint carries the exact
        per-layer approximation recipe it was built under."""
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        meta = None
        if numerics is not None:
            spec_d = numerics.to_dict() if hasattr(numerics, "to_dict") else dict(numerics)
            meta = {"numerics": spec_d}
        # snapshot to host memory first (donated/async-safe)
        host_tree = jax.tree.map(np.asarray, tree)
        if blocking:
            self._save_sync(host_tree, step, meta)
            return
        self.wait()

        def run():
            try:
                self._save_sync(host_tree, step, meta)
            except BaseException as e:  # surfaced on next save()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, int]:
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {self.dir}")
        shard = jax.process_index()
        path = os.path.join(self._step_dir(step), f"shard_{shard:05d}.ckpt")
        return load_pytree(template, path), step

    def numerics(self, step: int | None = None):
        """The NumericsSpec persisted with a step (None when the checkpoint
        was saved without one)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {self.dir}")
        shard = jax.process_index()
        path = os.path.join(self._step_dir(step), f"shard_{shard:05d}.ckpt")
        spec_d = read_meta(path).get("numerics")
        if spec_d is None:
            return None
        from repro.numerics import NumericsSpec

        return NumericsSpec.from_dict(spec_d)
