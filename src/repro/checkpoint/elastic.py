"""Elastic restore: load a checkpoint saved under mesh A onto mesh B.

The store keeps full (unsharded) host values per leaf; re-mesh restore is
then a `jax.device_put` against the NEW sharding tree.  This is what makes
the framework elastic: after losing a pod (512 -> 256 chips) or growing one,
training resumes from the same step with re-laid-out parameters — tested in
tests/test_checkpoint.py by saving under a (2, 2) mesh and restoring under
(4, 1) and (1, 1).
"""

from __future__ import annotations

from typing import Any

import jax

from repro.checkpoint.manager import CheckpointManager


def restore_with_sharding(
    manager: CheckpointManager,
    template: Any,
    sharding_tree: Any,
    step: int | None = None,
) -> tuple[Any, int]:
    """Restore and place each leaf with its (new-mesh) sharding."""
    host_tree, step = manager.restore(template, step)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host_tree, sharding_tree
    )
    return placed, step
