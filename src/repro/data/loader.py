"""Sharded, prefetching, deterministically-resumable host data loader.

Production pattern: each host builds only its shard of the global batch
(shard = process_index), a background thread keeps a bounded prefetch queue
ahead of the training loop, and `skip_to(step)` makes restart-after-failure
deterministic (the synthetic sources are pure functions of (step, shard), so
skip-ahead is O(1); a file-backed source would seek).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class ShardedLoader:
    def __init__(
        self,
        batch_fn: Callable[[int, int, int], dict],  # (step, shard, n_shards) -> batch
        *,
        prefetch: int = 2,
        shard: int | None = None,
        n_shards: int | None = None,
    ) -> None:
        self._batch_fn = batch_fn
        self._shard = jax.process_index() if shard is None else shard
        self._n_shards = jax.process_count() if n_shards is None else n_shards
        self._step = 0
        self._prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- deterministic resume ------------------------------------------------

    def skip_to(self, step: int) -> None:
        """Position the stream at ``step`` (restart path)."""
        self._drain()
        self._step = step

    # -- iteration -----------------------------------------------------------

    def _worker(self, start: int) -> None:
        step = start
        while not self._stop.is_set():
            batch = self._batch_fn(step, self._shard, self._n_shards)
            batch = dict(batch)
            batch["_step"] = step
            self._q.put(batch)
            step += 1

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._worker, args=(self._step,), daemon=True
            )
            self._thread.start()

    def _drain(self) -> None:
        self._stop.set()
        if self._thread is not None:
            while True:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=5)
            self._thread = None
        # recreate queue: any in-flight put lands in the old one
        self._q = queue.Queue(maxsize=self._prefetch)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        self._ensure_thread()
        batch = self._q.get()
        self._step = batch["_step"] + 1
        batch.pop("_step")
        return batch

    def close(self) -> None:
        self._drain()
