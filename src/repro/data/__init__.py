"""Data pipeline: procedural datasets (offline container — no downloads) and
a sharded, prefetching, deterministically-resumable host loader."""

from repro.data.synthetic import lm_batch_stream, SyntheticLMConfig
from repro.data.vision import make_vision_dataset, VisionConfig
from repro.data.loader import ShardedLoader

__all__ = [
    "lm_batch_stream",
    "SyntheticLMConfig",
    "make_vision_dataset",
    "VisionConfig",
    "ShardedLoader",
]
