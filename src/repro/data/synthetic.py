"""Procedural LM token streams with learnable structure.

The stream is a mixture of (a) a first-order Markov chain over a small
state alphabet with low-entropy transitions and (b) repeated motifs (copy
tasks): both give a clear, monotonically decreasing loss signal for the
integration tests ("training on this data reduces loss"), which pure-uniform
tokens cannot.  Everything is seeded + stateless per (shard, step), so the
loader can deterministically skip ahead after restart.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab: int
    seq_len: int
    batch: int  # per-host batch
    motif_len: int = 16
    n_motifs: int = 64
    markov_states: int = 0  # 0 -> min(vocab, 256)
    seed: int = 0


def _motif_table(cfg: SyntheticLMConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed + 1000)
    return rng.integers(0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))


def _markov(cfg: SyntheticLMConfig):
    k = cfg.markov_states or min(cfg.vocab, 256)
    rng = np.random.default_rng(cfg.seed + 2000)
    # peaky transitions: each state strongly prefers ~4 successors
    trans = np.zeros((k, k))
    for s in range(k):
        nxt = rng.choice(k, size=4, replace=False)
        trans[s, nxt] = rng.dirichlet(np.ones(4) * 0.5)
    trans = trans + 1e-3
    trans /= trans.sum(1, keepdims=True)
    return trans


_CACHE: dict = {}


def lm_batch(cfg: SyntheticLMConfig, step: int, shard: int = 0,
             n_shards: int = 1) -> dict:
    """One (batch, seq_len) token batch for (step, shard).  Pure function of
    its arguments — restart-safe and shard-disjoint by construction."""
    key = ("tbl", cfg.seed, cfg.vocab, cfg.n_motifs, cfg.motif_len)
    if key not in _CACHE:
        _CACHE[key] = (_motif_table(cfg), _markov(cfg))
    motifs, trans = _CACHE[key]
    k = trans.shape[0]

    rng = np.random.default_rng(
        (cfg.seed * 1_000_003 + step) * 65_537 + shard * 7_919
    )
    b, t = cfg.batch, cfg.seq_len
    out = np.empty((b, t), np.int64)
    state = rng.integers(0, k, size=b)
    i = 0
    # vectorized block generation: alternate markov runs and motif copies
    while i < t:
        run = int(rng.integers(8, 32))
        run = min(run, t - i)
        if rng.random() < 0.3:  # motif copy
            m = rng.integers(0, cfg.n_motifs, size=b)
            block = motifs[m][:, :run]
            if block.shape[1] < run:
                reps = -(-run // cfg.motif_len)
                block = np.tile(motifs[m], (1, reps))[:, :run]
            out[:, i : i + run] = block
        else:  # markov steps (vectorized via per-step categorical)
            for j in range(run):
                u = rng.random(b)
                cdf = np.cumsum(trans[state], axis=1)
                state = (u[:, None] < cdf).argmax(1)
                out[:, i + j] = state
        i += run
    return {"tokens": out.astype(np.int32), "labels": out.astype(np.int32)}


def lm_batch_stream(cfg: SyntheticLMConfig, start_step: int = 0, shard: int = 0,
                    n_shards: int = 1):
    step = start_step
    while True:
        yield lm_batch(cfg, step, shard, n_shards)
        step += 1
