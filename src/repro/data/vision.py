"""Procedural image classification dataset (the offline CIFAR stand-in).

Classes are parametric texture/shape generators — oriented stripes, checkers,
radial blobs, gradients, crosses, rings, noise scales — rendered at 32x32x3
with per-sample jitter (phase, frequency, color, noise).  10-class mode uses
the 10 base generators; 100-class mode crosses them with 10 color/frequency
variants (the CIFAR-100-is-harder analogue: same budget, finer classes).

Deterministic per (split, index): restart-safe, no storage.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    num_classes: int = 10
    img_size: int = 32
    seed: int = 0


def _grid(n):
    y, x = np.mgrid[0:n, 0:n].astype(np.float64) / n
    return x, y


def _base_pattern(kind: int, x, y, rng) -> np.ndarray:
    f = 2 + rng.integers(0, 3)
    ph = rng.random() * 2 * np.pi
    if kind == 0:  # horizontal stripes
        return np.sin(2 * np.pi * f * y + ph)
    if kind == 1:  # vertical stripes
        return np.sin(2 * np.pi * f * x + ph)
    if kind == 2:  # diagonal stripes
        return np.sin(2 * np.pi * f * (x + y) / np.sqrt(2) + ph)
    if kind == 3:  # checkerboard
        return np.sign(np.sin(2 * np.pi * f * x + ph) * np.sin(2 * np.pi * f * y + ph))
    if kind == 4:  # radial blob
        cx, cy = 0.3 + 0.4 * rng.random(2)
        r = np.hypot(x - cx, y - cy)
        return np.exp(-((r * (3 + f)) ** 2)) * 2 - 1
    if kind == 5:  # ring
        cx, cy = 0.35 + 0.3 * rng.random(2)
        r = np.hypot(x - cx, y - cy)
        return np.cos(2 * np.pi * f * r + ph)
    if kind == 6:  # gradient
        ang = rng.random() * 2 * np.pi
        return 2 * (np.cos(ang) * x + np.sin(ang) * y) - 1
    if kind == 7:  # cross
        cx, cy = 0.3 + 0.4 * rng.random(2)
        w = 0.06 + 0.04 * rng.random()
        return ((np.abs(x - cx) < w) | (np.abs(y - cy) < w)).astype(np.float64) * 2 - 1
    if kind == 8:  # square patch
        cx, cy = 0.25 + 0.4 * rng.random(2)
        s = 0.15 + 0.1 * rng.random()
        return ((np.abs(x - cx) < s) & (np.abs(y - cy) < s)).astype(np.float64) * 2 - 1
    # kind == 9: band-limited noise texture
    coarse = rng.standard_normal((4 + f, 4 + f))
    reps = -(-x.shape[0] // coarse.shape[0])
    img = np.kron(coarse, np.ones((reps, reps)))[: x.shape[0], : x.shape[1]]
    return img / max(np.abs(img).max(), 1e-6)


def make_sample(cfg: VisionConfig, split: str, index: int):
    """-> (img (H, W, 3) float32 in [0, 1], label int)."""
    salt = {"train": 0, "test": 1_000_000_007}[split]
    rng = np.random.default_rng(cfg.seed * 77_003 + salt + index)
    label = int(rng.integers(0, cfg.num_classes))
    if cfg.num_classes <= 10:
        kind, variant = label, label  # color mapping tied to the class
    else:  # 100-class: (pattern, variant) product
        kind, variant = label % 10, label // 10
    x, y = _grid(cfg.img_size)
    base = _base_pattern(kind, x, y, rng)
    # variant controls the color mapping (class-defining); per-sample jitter
    vr = np.random.default_rng(cfg.seed * 13 + variant)
    color_pos = 0.25 + 0.75 * vr.random(3)
    color_neg = 0.25 + 0.75 * vr.random(3)
    jitter = 1.0 + rng.normal(0, 0.08, 3)
    img = np.empty((cfg.img_size, cfg.img_size, 3))
    t = (base + 1) / 2
    for c in range(3):
        img[..., c] = (t * color_pos[c] + (1 - t) * color_neg[c]) * jitter[c]
    img += rng.standard_normal(img.shape) * 0.06
    return np.clip(img, 0, 1).astype(np.float32), label


def make_vision_dataset(cfg: VisionConfig, split: str, n: int):
    """-> (images (n, H, W, 3), labels (n,))."""
    imgs = np.empty((n, cfg.img_size, cfg.img_size, 3), np.float32)
    labels = np.empty((n,), np.int32)
    for i in range(n):
        imgs[i], labels[i] = make_sample(cfg, split, i)
    return imgs, labels
