"""gemmlowp/TFLite-style uint8 asymmetric quantization substrate.

The paper's accuracy evaluation runs on TFApprox, which emulates approximate
multipliers inside TFLite-style uint8 quantized inference: real values are
``r = S * (q - Z)`` with uint8 codes q, float scale S, integer zero-point Z.
Only the *code product* ``q_w * q_a`` runs on the (approximate) multiplier;
the zero-point corrections are exact adder-side arithmetic.  This package
provides exactly that substrate.
"""

from repro.quant.quantize import (
    QuantParams,
    quantize,
    quantize_i32,
    dequantize,
    calibrate_minmax,
    calibrate_tensor,
    quantized_linear,
    pack_linear,
    PackedLinear,
    BlockedPack,
    build_blocked_layout,
    build_fold,
    concat_packs,
    folded_linear,
    serving_blocks,
)

__all__ = [
    "QuantParams",
    "quantize",
    "quantize_i32",
    "dequantize",
    "calibrate_minmax",
    "calibrate_tensor",
    "quantized_linear",
    "pack_linear",
    "PackedLinear",
    "BlockedPack",
    "build_blocked_layout",
    "build_fold",
    "concat_packs",
    "folded_linear",
    "serving_blocks",
]
