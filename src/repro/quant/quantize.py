"""uint8 asymmetric quantization + approximate quantized linear algebra.

Quantization scheme (gemmlowp):  r = S * (q - Z),  q in [0, 255].

For a linear layer  y = A @ W + b  with activation codes qa (za, sa) and
weight codes qw (zw, sw):

    y = sa*sw * [ sum_k qa*qw  - zw*sum_k qa - za*sum_k qw + k*za*zw ] + b

Only the first term runs on the multiplier array; with an approximate
multiplier it becomes ``sum_k AM(qw, qa)`` and the paper's control variate V
is added to it (still inside the sa*sw rescale).  The zero-point corrections
stay exact (adder-side in hardware).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import control_variate as cv
from repro.core import multipliers as am

QMIN, QMAX = 0, 255


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization parameters.  scale/zero_point broadcast against the
    quantized tensor (scalars for per-tensor, vectors for per-channel)."""

    scale: jax.Array  # float32
    zero_point: jax.Array  # int32

    @staticmethod
    def identity() -> "QuantParams":
        return QuantParams(jnp.float32(1.0), jnp.int32(0))


def calibrate_minmax(lo, hi) -> QuantParams:
    """Affine parameters covering [lo, hi] (forced to include 0, per TFLite,
    so that zero pads/ReLU zeros are exactly representable)."""
    lo = jnp.minimum(jnp.asarray(lo, jnp.float32), 0.0)
    hi = jnp.maximum(jnp.asarray(hi, jnp.float32), 0.0)
    scale = jnp.maximum((hi - lo) / (QMAX - QMIN), 1e-12)
    zp = jnp.clip(jnp.round(QMIN - lo / scale), QMIN, QMAX).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp)


def calibrate_tensor(x, axis: int | None = None) -> QuantParams:
    """Min/max calibration over a tensor (per-tensor, or per-channel along
    ``axis`` — the non-reduced axis keeps its extent)."""
    if axis is None:
        return calibrate_minmax(jnp.min(x), jnp.max(x))
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    return calibrate_minmax(
        jnp.min(x, axis=reduce_axes), jnp.max(x, axis=reduce_axes)
    )


def quantize(x, qp: QuantParams) -> jax.Array:
    """Real -> uint8 codes (stored uint8)."""
    return quantize_i32(x, qp).astype(jnp.uint8)


def quantize_i32(x, qp: QuantParams) -> jax.Array:
    """Real -> codes held directly in int32 (skips the uint8 round-trip;
    identical code values to :func:`quantize`, one fewer cast on hot paths)."""
    q = jnp.round(jnp.asarray(x, jnp.float32) / qp.scale) + qp.zero_point
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int32)


def dequantize(q, qp: QuantParams) -> jax.Array:
    return (jnp.asarray(q, jnp.int32) - qp.zero_point).astype(jnp.float32) * qp.scale


# ---------------------------------------------------------------------------
# Packed (offline-prepared) approximate linear layers
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """Serving-time parameter pack for one approximate quantized linear.

    Produced offline by :func:`pack_linear` from float weights; consumed by
    :func:`quantized_linear` (and by the fused Pallas kernel path).

    w_q        (k, n) uint8 weight codes
    w_scale/w_zp   weight quant params (per-tensor scalars, or per-column
               (n,) vectors for fan-out-fused packs — see :func:`concat_packs`)
    sum_qw     (n,)  int32   column sums of codes (zero-point correction)
    c, c0      (n,) / (groups, n) float32 control-variate constants
    bias       (n,) float32 (or None)

    The CPU-serving fast path additionally folds the pack (+ activation
    quant params) into dense float matrices at pack time — see
    :func:`build_fold` — stored on the QuantizedDense wrapper, not here.
    """

    w_q: jax.Array
    w_scale: jax.Array
    w_zp: jax.Array
    sum_qw: jax.Array
    c: jax.Array
    c0: jax.Array
    bias: jax.Array | None


def pack_linear(
    w: jax.Array,
    bias: jax.Array | None,
    mode: am.Mode,
    m: int,
    groups: int = 1,
) -> PackedLinear:
    """Quantize float weights (k, n) and precompute CV constants offline."""
    qp = calibrate_tensor(w)
    w_q = quantize(w, qp)
    w_i = jnp.asarray(w_q, jnp.int32)
    if groups == 1:
        const = cv.cv_constants(w_i, mode, m, reduce_axis=0)
    else:
        const = cv.cv_constants_grouped(w_i, mode, m, groups, reduce_axis=0)
    return PackedLinear(
        w_q=w_q,
        w_scale=qp.scale,
        w_zp=qp.zero_point,
        sum_qw=jnp.sum(w_i, axis=0, dtype=jnp.int32),
        c=const.c,
        c0=const.c0,
        bias=None if bias is None else jnp.asarray(bias, jnp.float32),
    )


def concat_packs(packs: list[PackedLinear]) -> PackedLinear:
    """Fan-out fusion: concatenate sibling packs along the output axis.

    The members must share the fan-in ``k`` (they consume the same
    activations).  Per-tensor weight quant params become per-COLUMN vectors,
    so :func:`quantized_linear` on the fused pack computes, column for
    column, exactly the arithmetic of the separate member calls — the fused
    output is bit-identical to concatenating the member outputs (asserted in
    tests/test_serving_fastpath.py).
    """
    widths = [p.w_q.shape[-1] for p in packs]

    def per_col(v, n, dtype):
        v = jnp.asarray(v, dtype)
        # scalar (or per-layer-stacked scalar) -> one value per output column
        return jnp.broadcast_to(v[..., None], v.shape + (n,))

    has_bias = [p.bias is not None for p in packs]
    if any(has_bias) and not all(has_bias):
        raise ValueError("cannot fuse packs with mixed bias presence")
    return PackedLinear(
        w_q=jnp.concatenate([p.w_q for p in packs], axis=-1),
        w_scale=jnp.concatenate(
            [per_col(p.w_scale, n, jnp.float32) for p, n in zip(packs, widths)],
            axis=-1),
        w_zp=jnp.concatenate(
            [per_col(p.w_zp, n, jnp.int32) for p, n in zip(packs, widths)],
            axis=-1),
        sum_qw=jnp.concatenate([p.sum_qw for p in packs], axis=-1),
        c=jnp.concatenate([p.c for p in packs], axis=-1),
        c0=jnp.concatenate([p.c0 for p in packs], axis=-1),
        bias=(jnp.concatenate([p.bias for p in packs], axis=-1)
              if all(has_bias) else None),
    )


# ---------------------------------------------------------------------------
# Offline-blocked serving layout (zero per-call padding / meta assembly)
# ---------------------------------------------------------------------------

#: Serving-layout tile defaults (MXU-aligned; mirrored by the runtime block
#: picker in repro.kernels.ops).
SERVE_BN, SERVE_BK = 128, 512

#: Epilogue-table row indices (the single aligned operand the kernel's
#: epilogue reads): CV constants, zero-point corrections, per-column weight
#: quant params, bias.  Rows padded to 8 for sublane alignment.
EPI_C, EPI_C0, EPI_SUM_QW, EPI_BIAS, EPI_SW, EPI_ZW = range(6)
EPI_ROWS = 8

#: Meta-vector slots (per-tensor scalars the fused kernel needs).
META_SA, META_ZA, META_TRUE_K = range(3)
META_LEN = 8


def shrink_block(size: int, block: int, floor: int) -> int:
    """Halve ``block`` toward ``floor`` while the operand is smaller than it
    — THE block-picking rule, shared by the offline layout (here) and the
    runtime picker (repro.kernels.ops._pick_blocks) so pad granularity and
    tile choice can never silently diverge."""
    while block > floor and size < block:
        block //= 2
    return max(block, floor)


def serving_blocks(k: int, n: int) -> tuple[int, int]:
    """(bn, bk) tile sizes the offline layout pads to, fixed at pack time."""
    return (
        shrink_block(n, SERVE_BN, 128 if n >= 128 else 8),
        shrink_block(k, SERVE_BK, 128 if k >= 128 else 8),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BlockedPack:
    """Offline-blocked serving layout for one (possibly fused) linear.

    Everything the fused Pallas kernel consumes, already tiled and aligned
    at pack time — the forward pass does zero padding, zero concatenation,
    and zero scalar scatter:

    w_qb      (Kb, Nb) uint8 codes, padded to (bk, bn) multiples
    epilogue  (EPI_ROWS, Nb) f32 table, rows indexed by ``EPI_*``
    meta      (1, META_LEN) f32 per-tensor scalars, slots ``META_*``
    ``k``/``n`` are the true (unpadded) operand extents; ``bk``/``bn`` the
    pad granularity (the runtime may still *merge* K tiles for decode).
    """

    w_qb: jax.Array
    epilogue: jax.Array
    meta: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    bk: int = dataclasses.field(metadata=dict(static=True))
    bn: int = dataclasses.field(metadata=dict(static=True))


def build_blocked_layout(pack: PackedLinear, a_qp: QuantParams,
                         bn: int | None = None,
                         bk: int | None = None) -> BlockedPack:
    """Pad/assemble a pack into the serving layout, once, offline.

    Only defined for single-CV packs (``c`` of shape (n,)); grouped CV uses
    the jnp path.  ``sum_qw`` is stored as f32 — exact while 255*k < 2^24.
    """
    k, n = pack.w_q.shape[-2:]
    if pack.c.ndim != pack.sum_qw.ndim:
        raise ValueError("blocked layout requires groups == 1 CV constants")
    if 255 * k >= (1 << 24):
        raise ValueError(f"fan-in {k} overflows f32-exact sum_qw storage")
    if bn is None or bk is None:
        bn_d, bk_d = serving_blocks(k, n)
        bn = bn or bn_d
        bk = bk or bk_d
    kb, nb = -(-k // bk) * bk, -(-n // bn) * bn

    w_qb = jnp.pad(pack.w_q, ((0, kb - k), (0, nb - n)))

    def row(v, fill_n=n):
        v = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (fill_n,))
        return jnp.pad(v, (0, nb - fill_n))

    epi = jnp.stack([
        row(pack.c),
        row(pack.c0),
        row(pack.sum_qw),
        row(pack.bias if pack.bias is not None else jnp.zeros((n,), jnp.float32)),
        row(pack.w_scale),
        row(pack.w_zp),
    ] + [jnp.zeros((nb,), jnp.float32)] * (EPI_ROWS - 6))

    meta = jnp.zeros((META_LEN,), jnp.float32)
    meta = meta.at[META_SA].set(jnp.asarray(a_qp.scale, jnp.float32))
    meta = meta.at[META_ZA].set(jnp.asarray(a_qp.zero_point, jnp.float32))
    meta = meta.at[META_TRUE_K].set(jnp.float32(k))
    return BlockedPack(w_qb=w_qb, epilogue=epi, meta=meta.reshape(1, META_LEN),
                       k=k, n=n, bk=bk, bn=bn)


def _f32_dot(a_f: jax.Array, w_f: jax.Array) -> jax.Array:
    # Precision.HIGHEST: true f32 multiplies (TPU's default bf16-pass dot
    # would round the products and void the ulp-agreement contract)
    return jax.lax.dot_general(
        a_f, w_f, (((a_f.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST)


def build_fold(pack: PackedLinear, a_qp: QuantParams, mode: am.Mode, m: int,
               use_cv: bool) -> dict | None:
    """Fold the ENTIRE serving epilogue into dense float matrices, offline.

    The quantized-linear identity

        y = sa*sw * [ acc + V - zw*sumqa - za*sum_qw + k*za*zw ] + b

    is linear in the runtime quantities (the code-product accumulator and
    the per-row sums), all of which are themselves linear in the activation
    CODES and their mode transform.  So the whole layer collapses to

        y = codes @ A  (+ op2 @ B)  + delta

    with A/B/delta precomputed here: A carries alpha*W plus the sumqa
    coefficient folded into every column; B carries the mode's subtractive
    slice (perforated: W, recursive: W&mask, truncated: bitplanes) scaled by
    -alpha, with the CV constant C*alpha folded into the same operand (the
    CV statistic sumx is linear in op2 too); delta collects every
    activation-independent term (C0, za corrections, bias).  ``op2`` is
    ``codes mod 2^m`` (perforated/recursive) or the activation bitplanes
    [+ nonzero-low indicator] (truncated) — pure f32 elementwise work at
    run time, no int round-trips.

    This is the jnp/CPU analogue of the Pallas blocked layout: serving
    becomes plain float GEMMs against offline-prepared operands (exact-int8
    is literally ONE dot plus a constant).  Products are no longer integer-
    exact — results agree with the reference integer path to float ulps,
    far below quantization error.  Built only for single-CV packs at
    fan-ins where the f32 staging copy is cheap (k <= 258); deep fan-ins
    are matmul-dominated and keep the exact integer path.
    """
    k, n = pack.w_q.shape[-2:]
    if pack.c.ndim != pack.sum_qw.ndim:  # grouped CV: no fold
        return None
    if k > am._F32_EXACT_K:
        return None

    w_f = jnp.asarray(pack.w_q, jnp.float32)
    sum_qw = pack.sum_qw.astype(jnp.float32)

    def col(v):
        """Align per-tensor / per-layer / per-column values to (..., n)."""
        v = jnp.asarray(v, jnp.float32)
        return v if v.ndim == sum_qw.ndim else v[..., None]

    za = col(a_qp.zero_point)
    zw = col(pack.w_zp)
    alpha = col(a_qp.scale) * col(pack.w_scale)
    beta = -(zw * alpha)  # sumqa coefficient
    delta = (k * za) * zw - za * sum_qw
    has_cv = use_cv and mode != "exact" and m > 0
    if has_cv:
        delta = delta + pack.c0
    delta = delta * alpha
    if pack.bias is not None:
        delta = delta + pack.bias

    def row(v):  # (..., n) -> (..., 1, n) to broadcast over the k axis
        return v[..., None, :] if v.ndim == sum_qw.ndim else v[..., None]

    fold = {
        "sa": jnp.asarray(a_qp.scale, jnp.float32),
        "za": jnp.asarray(a_qp.zero_point, jnp.float32),
        "A": w_f * row(alpha) + row(beta),
        "delta": delta,
    }
    if mode == "exact" or m == 0:
        return fold
    cv_row = row(pack.c * alpha) if has_cv else None
    if mode in ("perforated", "recursive"):
        w_slice = w_f if mode == "perforated" else (
            jnp.asarray(pack.w_q, jnp.int32) & ((1 << m) - 1)
        ).astype(jnp.float32)
        b_mat = -w_slice * row(alpha)
        if has_cv:
            b_mat = b_mat + cv_row
        fold["B"] = b_mat
        return fold
    # truncated: op2 = [bitplanes (m*k) | nonzero-low indicator (k, CV only)]
    planes = jnp.concatenate(
        [am.low_bits(pack.w_q, m - i) for i in range(m)],
        axis=-2).astype(jnp.float32)
    b_mat = -planes * row(alpha)
    if has_cv:
        b_mat = jnp.concatenate(
            [b_mat, jnp.broadcast_to(cv_row, w_f.shape)], axis=-2)
    fold["B"] = b_mat
    return fold


def folded_linear(a: jax.Array, fold: dict, mode: am.Mode, m: int,
                  use_cv: bool) -> jax.Array:
    """Serving fast path: float in -> float out via the folded operands.

    One fused elementwise pass (quantize + mode transform, all f32 —
    mod-by-power-of-two is exact on small integer floats), one or two float
    GEMMs, one constant add.  Semantics match :func:`quantized_linear` to
    float ulps (see :func:`build_fold`).
    """
    codes = jnp.clip(
        jnp.round(jnp.asarray(a, jnp.float32) / fold["sa"]) + fold["za"],
        QMIN, QMAX)
    y = _f32_dot(codes, fold["A"])
    if "B" in fold:
        scale = float(1 << m)
        lo = codes - scale * jnp.floor(codes / scale)  # codes mod 2^m
        if mode in ("perforated", "recursive"):
            op2 = lo
        else:  # truncated bitplanes (bit i scaled by 2^i), peeled bottom-up
            planes = []
            rest = codes
            for i in range(m):
                p2 = float(1 << (i + 1))
                b = rest - p2 * jnp.floor(rest / p2)
                planes.append(b)
                rest = rest - b
            if use_cv:
                planes.append(jnp.where(lo != 0, 1.0, 0.0))
            op2 = jnp.concatenate(planes, axis=-1)
        y = y + _f32_dot(op2, fold["B"])
    return y + fold["delta"]


def quantized_linear(
    a: jax.Array,
    pack: PackedLinear,
    a_qp: QuantParams,
    mode: am.Mode,
    m: int,
    use_cv: bool = True,
    groups: int = 1,
) -> jax.Array:
    """Approximate quantized linear: float in -> float out.

    a: (..., k) float activations, quantized on the fly with ``a_qp``
    (calibrated offline, as in TFLite).  The code-product sum uses the
    bit-slice matmul forms of :mod:`repro.core.multipliers`; the control
    variate V is the paper's rank-1 correction.

    ``pack`` may be a fan-out-fused pack (per-column ``w_scale``/``w_zp``
    from :func:`concat_packs`) — every correction broadcasts per column, so
    the math per output column is unchanged.

    This is the exact-integer reference path (and the grouped-CV path);
    serving goes through :func:`folded_linear` when the packed layer
    carries fold operands.
    """
    k = a.shape[-1]
    a_i = quantize_i32(a, a_qp)
    acc = am.approx_matmul(a_i, pack.w_q, mode, m).astype(jnp.float32)
    if use_cv and mode != "exact" and m > 0:
        const = cv.CVConstants(c=pack.c, c0=pack.c0)
        if groups == 1:
            acc = acc + cv.cv_term(a_i, const, mode, m)
        else:
            acc = acc + cv.cv_term_grouped(a_i, const, mode, m, groups)
    # Exact zero-point corrections (gemmlowp adder-side arithmetic).
    sum_qa = jnp.sum(a_i, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    zw = pack.w_zp.astype(jnp.float32)
    za = a_qp.zero_point.astype(jnp.float32)
    acc = (
        acc
        - zw * sum_qa[..., None]
        - za * pack.sum_qw.astype(jnp.float32)
        + k * za * zw
    )

    y = acc * (a_qp.scale * pack.w_scale)
    if pack.bias is not None:
        y = y + pack.bias
    return y
