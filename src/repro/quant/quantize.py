"""uint8 asymmetric quantization + approximate quantized linear algebra.

Quantization scheme (gemmlowp):  r = S * (q - Z),  q in [0, 255].

For a linear layer  y = A @ W + b  with activation codes qa (za, sa) and
weight codes qw (zw, sw):

    y = sa*sw * [ sum_k qa*qw  - zw*sum_k qa - za*sum_k qw + k*za*zw ] + b

Only the first term runs on the multiplier array; with an approximate
multiplier it becomes ``sum_k AM(qw, qa)`` and the paper's control variate V
is added to it (still inside the sa*sw rescale).  The zero-point corrections
stay exact (adder-side in hardware).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import control_variate as cv
from repro.core import multipliers as am

QMIN, QMAX = 0, 255


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization parameters.  scale/zero_point broadcast against the
    quantized tensor (scalars for per-tensor, vectors for per-channel)."""

    scale: jax.Array  # float32
    zero_point: jax.Array  # int32

    @staticmethod
    def identity() -> "QuantParams":
        return QuantParams(jnp.float32(1.0), jnp.int32(0))


def calibrate_minmax(lo, hi) -> QuantParams:
    """Affine parameters covering [lo, hi] (forced to include 0, per TFLite,
    so that zero pads/ReLU zeros are exactly representable)."""
    lo = jnp.minimum(jnp.asarray(lo, jnp.float32), 0.0)
    hi = jnp.maximum(jnp.asarray(hi, jnp.float32), 0.0)
    scale = jnp.maximum((hi - lo) / (QMAX - QMIN), 1e-12)
    zp = jnp.clip(jnp.round(QMIN - lo / scale), QMIN, QMAX).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp)


def calibrate_tensor(x, axis: int | None = None) -> QuantParams:
    """Min/max calibration over a tensor (per-tensor, or per-channel along
    ``axis`` — the non-reduced axis keeps its extent)."""
    if axis is None:
        return calibrate_minmax(jnp.min(x), jnp.max(x))
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    return calibrate_minmax(
        jnp.min(x, axis=reduce_axes), jnp.max(x, axis=reduce_axes)
    )


def quantize(x, qp: QuantParams) -> jax.Array:
    """Real -> uint8 codes (stored uint8)."""
    q = jnp.round(jnp.asarray(x, jnp.float32) / qp.scale) + qp.zero_point
    return jnp.clip(q, QMIN, QMAX).astype(jnp.uint8)


def dequantize(q, qp: QuantParams) -> jax.Array:
    return (jnp.asarray(q, jnp.int32) - qp.zero_point).astype(jnp.float32) * qp.scale


# ---------------------------------------------------------------------------
# Packed (offline-prepared) approximate linear layers
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """Serving-time parameter pack for one approximate quantized linear.

    Produced offline by :func:`pack_linear` from float weights; consumed by
    :func:`quantized_linear` (and by the fused Pallas kernel path).

    w_q        (k, n) uint8 weight codes
    w_scale/w_zp   weight quant params (per-tensor)
    sum_qw     (n,)  int32   column sums of codes (zero-point correction)
    c, c0      (n,) / (groups, n) float32 control-variate constants
    bias       (n,) float32 (or None)
    """

    w_q: jax.Array
    w_scale: jax.Array
    w_zp: jax.Array
    sum_qw: jax.Array
    c: jax.Array
    c0: jax.Array
    bias: jax.Array | None


def pack_linear(
    w: jax.Array,
    bias: jax.Array | None,
    mode: am.Mode,
    m: int,
    groups: int = 1,
) -> PackedLinear:
    """Quantize float weights (k, n) and precompute CV constants offline."""
    qp = calibrate_tensor(w)
    w_q = quantize(w, qp)
    w_i = jnp.asarray(w_q, jnp.int32)
    if groups == 1:
        const = cv.cv_constants(w_i, mode, m, reduce_axis=0)
    else:
        const = cv.cv_constants_grouped(w_i, mode, m, groups, reduce_axis=0)
    return PackedLinear(
        w_q=w_q,
        w_scale=qp.scale,
        w_zp=qp.zero_point,
        sum_qw=jnp.sum(w_i, axis=0, dtype=jnp.int32),
        c=const.c,
        c0=const.c0,
        bias=None if bias is None else jnp.asarray(bias, jnp.float32),
    )


def quantized_linear(
    a: jax.Array,
    pack: PackedLinear,
    a_qp: QuantParams,
    mode: am.Mode,
    m: int,
    use_cv: bool = True,
    groups: int = 1,
) -> jax.Array:
    """Approximate quantized linear: float in -> float out.

    a: (..., k) float activations, quantized on the fly with ``a_qp``
    (calibrated offline, as in TFLite).  The code-product sum uses the
    bit-slice matmul forms of :mod:`repro.core.multipliers`; the control
    variate V is the paper's rank-1 correction.
    """
    a_q = quantize(a, a_qp)
    a_i = jnp.asarray(a_q, jnp.int32)
    k = a_i.shape[-1]

    acc = am.approx_matmul(a_i, pack.w_q, mode, m).astype(jnp.float32)
    if use_cv and mode != "exact" and m > 0:
        const = cv.CVConstants(c=pack.c, c0=pack.c0)
        if groups == 1:
            acc = acc + cv.cv_term(a_i, const, mode, m)
        else:
            acc = acc + cv.cv_term_grouped(a_i, const, mode, m, groups)

    # Exact zero-point corrections (gemmlowp adder-side arithmetic).
    sum_qa = jnp.sum(a_i, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    zw = pack.w_zp.astype(jnp.float32)
    za = a_qp.zero_point.astype(jnp.float32)
    acc = (
        acc
        - zw * sum_qa[..., None]
        - za * pack.sum_qw.astype(jnp.float32)
        + k * za * zw
    )

    y = acc * (a_qp.scale * pack.w_scale)
    if pack.bias is not None:
        y = y + pack.bias
    return y
