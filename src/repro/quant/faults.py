"""Deterministic fault injection for the approximate-matmul serving path.

The governor (:mod:`repro.serving.governor`) and the engine's quarantine
machinery exist to survive a *misbehaving approximate multiplier* — a MAC
array drifting out of its calibrated envelope, a stuck-at bit, a transient
upset.  Testing that story needs faults on demand, reproducibly.  This
module provides seedable injectors with two corruption surfaces:

  * **step surface** (kinds ``nan`` / ``inf`` / ``spike``): the engine
    corrupts the *host-side logits* of deterministically chosen batch rows
    after the jitted dispatch, modeling a transient corruption of the
    step's output.  These are what the engine-side NaN/divergence
    detector catches: the row is quarantined, its KV cursor rolled back,
    and the step replayed on the exact pack before any token is emitted.
  * **dense surface** (kind ``dense-noise``): a thread-local hook in
    :func:`repro.core.approx_linear.dense` / ``dense_group`` adds
    deterministic Gaussian noise to the APPROXIMATE output of packed
    layers matching a path pattern — but only on eager probe forwards
    (tracers are never touched, so the jitted serving step is unaffected
    and the hook costs nothing when off, exactly like
    :mod:`repro.quant.error_probe`).  This models a degraded MAC array as
    the error probe observes it: the probe's approx-vs-exact delta
    variance breaches the SLO and drives the governor's ladder.

Determinism contract: row/layer choices derive from
``np.random.default_rng((seed, step))`` — the same seed and step sequence
injects the same faults regardless of KV layout (contiguous vs paged),
wall time, or host.  Every injection appends to ``FaultInjector.log`` so
tests can compare campaigns structurally.
"""

from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import threading
import zlib

import numpy as np

_STATE = threading.local()

#: logit magnitude on the consumed column above which a row is treated as
#: divergent even when finite (trained logits are O(10); a stuck-at-style
#: offset spike lands far outside this)
DIVERGENCE_ABS = 1e3

KINDS = ("nan", "inf", "spike", "dense-noise")


def active():
    """The thread-local armed :class:`FaultInjector`, or None (the common
    case — consulted only on eager probe forwards, never inside jit)."""
    return getattr(_STATE, "injector", None)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault campaign.

    ``kind``   — ``nan`` | ``inf`` | ``spike`` (step surface: corrupt
                 chosen rows' logits) or ``dense-noise`` (dense surface:
                 Gaussian noise on matching packed layers' probe outputs).
    ``every``  — fire on engine steps where ``(step - start) % every == 0``.
    ``start``/``stop`` — half-open step window ``[start, stop)`` the
                 campaign is live in (``stop=None`` = forever).
    ``rows``   — max batch rows corrupted per fired step (step surface).
    ``scale``  — spike offset magnitude / dense-noise sigma.
    ``layers`` — ``fnmatch`` pattern over layer paths (dense surface).
    """

    kind: str = "nan"
    every: int = 8
    seed: int = 0
    start: int = 0
    stop: int | None = None
    rows: int = 1
    scale: float = 1e4
    layers: str = "*"

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"fault kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.every < 1:
            raise ValueError(f"fault every must be >= 1, got {self.every}")
        if self.rows < 1:
            raise ValueError(f"fault rows must be >= 1, got {self.rows}")
        if self.stop is not None and self.stop <= self.start:
            raise ValueError("fault window is empty: "
                             f"start={self.start} stop={self.stop}")

    @property
    def surface(self) -> str:
        return "dense" if self.kind == "dense-noise" else "step"

    @staticmethod
    def parse(text: str, seed: int = 0) -> "FaultSpec":
        """Parse the CLI form ``KIND@EVERY[@START-STOP][@LAYERS]``.

        Examples: ``nan@5`` (NaN a row every 5th step), ``spike@7@20-60``
        (offset spikes every 7th step between steps 20 and 60),
        ``dense-noise@1@10-30`` (probe-visible layer noise, steps 10-30),
        ``dense-noise@1@blocks/0/*`` (noise confined to one block's
        layers — the single-layer fault the per-layer SLO demo injects),
        ``dense-noise@1@10-30@blocks/0/o`` (both).

        The third segment is a STEP RANGE when it looks like one
        (``N-M``/``N-``/``-M``, digits only) and a layer pattern
        otherwise; a 4-segment spec pins range then pattern explicitly.
        """
        import re

        parts = text.split("@")
        if not 2 <= len(parts) <= 4:
            raise ValueError(f"fault spec {text!r} is not "
                             "KIND@EVERY[@START-STOP][@LAYERS]")
        kind, every = parts[0], int(parts[1])
        start, stop = 0, None
        layers = "*"
        rest = parts[2:]
        if rest and re.fullmatch(r"\d*-\d*", rest[0]) and rest[0] != "-":
            lo, _, hi = rest[0].partition("-")
            start = int(lo) if lo else 0
            stop = int(hi) if hi else None
            rest = rest[1:]
        if rest:
            if len(rest) > 1:
                raise ValueError(f"fault spec {text!r}: at most one layer "
                                 "pattern segment")
            layers = rest[0]
        return FaultSpec(kind=kind, every=every, seed=seed,
                         start=start, stop=stop, layers=layers)


class FaultInjector:
    """Stateful executor of one :class:`FaultSpec` campaign.

    The engine owns one injector; replayed (quarantine) dispatches never
    consult it, so a corrupted step's exact replay is always clean.
    """

    def __init__(self, spec: FaultSpec) -> None:
        self.spec = spec
        self.injected_steps = 0
        self.injected_rows = 0
        #: structural campaign record — step surface entries are
        #: ``("step", step, kind, (rows...))``, dense surface entries
        #: ``("dense", step, layer_key)`` — comparable across engines
        self.log: list[tuple] = []
        self._armed_step: int | None = None

    # -- schedule ------------------------------------------------------------

    def fires(self, step: int) -> bool:
        s = self.spec
        if step < s.start or (s.stop is not None and step >= s.stop):
            return False
        return (step - s.start) % s.every == 0

    def _rng(self, step: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng((self.spec.seed, step, salt))

    def plan_rows(self, step: int, live_rows) -> list[int]:
        """Deterministic subset of live batch rows to corrupt this step."""
        live = sorted(int(r) for r in live_rows)
        if not live:
            return []
        k = min(self.spec.rows, len(live))
        picked = self._rng(step).choice(len(live), size=k, replace=False)
        return sorted(live[int(i)] for i in picked)

    # -- step surface (host-side logits corruption) --------------------------

    def corrupt_logits(self, step: int, logits, rows: list[int]) -> np.ndarray:
        """Return a corrupted host copy of ``logits`` (slots, cols, vocab)
        with the chosen rows overwritten per the campaign kind."""
        lg = np.array(logits)  # host copy; the device value is untouched
        s = self.spec
        for r in rows:
            if s.kind == "nan":
                lg[r] = np.nan
            elif s.kind == "inf":
                lg[r] = np.inf
            else:  # spike: stuck-at-style constant offset, still finite
                lg[r] = lg[r] + s.scale
        self.injected_steps += 1
        self.injected_rows += len(rows)
        self.log.append(("step", step, s.kind, tuple(rows)))
        return lg

    # -- dense surface (probe-forward hook) ----------------------------------

    @contextlib.contextmanager
    def armed(self, step: int):
        """Arm the thread-local hook for one probe forward.  No-op (but
        still a valid context) when the campaign does not fire on
        ``step`` or is not dense-surface."""
        if self.spec.surface != "dense" or not self.fires(step):
            yield self
            return
        if active() is not None:
            raise RuntimeError("nested FaultInjector arming")
        _STATE.injector = self
        self._armed_step = step
        try:
            yield self
        finally:
            _STATE.injector = None
            self._armed_step = None

    def corrupt_dense(self, path: str, name: str, y):
        """Called from the dense() probe hook: add deterministic Gaussian
        noise to a matching packed layer's approximate output."""
        key = f"{path}/{name}" if path else name
        if not fnmatch.fnmatch(key, self.spec.layers):
            return y
        step = self._armed_step or 0
        rng = self._rng(step, salt=zlib.crc32(key.encode()))
        noise = rng.normal(0.0, self.spec.scale, np.shape(y))
        self.injected_rows += 1
        self.log.append(("dense", step, key))
        return y + np.asarray(noise, np.asarray(y).dtype)


def suspect_rows(cols: np.ndarray) -> np.ndarray:
    """Boolean mask over rows of ``cols`` (rows, vocab) — each row's
    consumed logits column — flagging non-finite or divergent rows.

    This is the engine-side detection predicate: it runs on values the
    postprocess already pulls to the host, so detection adds no device
    round-trip beyond the gather.
    """
    cols = np.asarray(cols, np.float32)
    finite = np.isfinite(cols)
    nonfinite = ~finite.all(axis=-1)
    magnitude = np.abs(np.where(finite, cols, 0.0)).max(axis=-1)
    return nonfinite | (magnitude > DIVERGENCE_ABS)
