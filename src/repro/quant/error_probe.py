"""Online approximation-error probe: approximate-vs-exact output deltas.

The paper's headline claim is a *bounded* accuracy cost: the perforated
multiplier plus the control-variate correction keeps the output error
small.  This module makes that quantity observable in a RUNNING engine
instead of an offline eval: every N steps the engine re-runs one
already-scheduled batch row through the model twice —

  1. the normal approximate path, with a thread-local recorder active
     that, at every packed dense layer, also computes the **exact-int8
     reference on the same quantized codes**
     (:func:`repro.quant.quantize.quantized_linear` with ``mode="exact"``)
     and accumulates elementwise error moments of ``y_approx - y_exact``
     per layer path;
  2. the exact-override path, where every packed dense *returns* the
     exact reference, so the final logits are the exact-int8 logits.

The deltas isolate APPROXIMATION error from quantization error (both
passes share the uint8 codes and quant params), which is exactly the CV
residual of Zervakis et al.: under ``exact`` numerics the per-layer error
variance is ~0 (float-ulp disagreement between the folded fast path and
the integer reference), under ``perforated`` without CV it is strictly
larger than with CV.

Mechanics:

  * The hooks live in :func:`repro.core.approx_linear.dense` /
    ``dense_group`` and are a thread-local ``None`` check that ignores
    tracers — so the jitted serving step records nothing and pays nothing.
  * Probe forwards run EAGERLY with ``unroll_layers=True``
    (:func:`repro.models.lm.decode_slots`): ``lax.scan`` traces its body
    even outside jit, so the scanned layer stack must be unrolled into a
    python loop for the recorder to see concrete values.
  * The probed row is sliced out of the batch (contiguous layout: slot
    axis of every cache leaf; paged: the row's lengths + block-table row
    against the whole pool), so the probe re-runs ONE row, not the batch.
  * Cost: two eager single-row forwards per probe (amortized by
    ``error_probe_every``); the serving path itself is untouched.

Results aggregate into :class:`~repro.serving.metrics.EngineMetrics`
(``record_probe``) and a ``probe`` span event per run.
"""

from __future__ import annotations

import inspect
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.quantize import quantized_linear

_STATE = threading.local()


def active():
    """The thread-local :class:`ProbeRecorder`, or None (the common case —
    this is the only check on the serving hot path)."""
    return getattr(_STATE, "probe", None)


def exact_dense(p, x: jax.Array) -> jax.Array:
    """Exact-int8 reference output for a packed layer (or fused group):
    the same quantized codes through the exact multiplier, no CV."""
    return quantized_linear(x, p.pack, p.a_qp, "exact", 0, use_cv=False)


class ProbeRecorder:
    """Thread-local probe context for ONE eager forward.

    mode ``"observe"``: packed dense layers run normally; each also
    computes the exact reference and accumulates elementwise moments of
    the delta under its layer path.  mode ``"exact"``: packed dense
    layers RETURN the exact reference (the forward produces exact-int8
    logits).  Nested recorders are a bug, not a feature.
    """

    def __init__(self, mode: str) -> None:
        if mode not in ("observe", "exact"):
            raise ValueError(f"probe mode must be observe|exact, got {mode!r}")
        self.mode = mode
        #: layer path -> (n, mean, var) over elementwise deltas
        self.layers: dict[str, tuple[int, float, float]] = {}

    def observe(self, path: str, name: str, delta) -> None:
        d = np.asarray(delta, np.float64).ravel()
        if d.size == 0:
            return
        key = f"{path}/{name}" if path else name
        from repro.serving.metrics import _merge_moments

        self.layers[key] = _merge_moments(
            self.layers.get(key, (0, 0.0, 0.0)),
            (int(d.size), float(d.mean()), float(d.var())))

    def __enter__(self) -> "ProbeRecorder":
        if active() is not None:
            raise RuntimeError("nested ProbeRecorder")
        _STATE.probe = self
        return self

    def __exit__(self, *exc) -> None:
        _STATE.probe = None


def _slice_contiguous(cache: dict, row: int) -> dict:
    """One slot's view of a contiguous slot cache: ``lengths`` is (slots,),
    every other leaf carries the slot axis at position 1 (leading axis is
    the stacked layer axis)."""
    return {k: (v[row:row + 1] if k == "lengths" else v[:, row:row + 1])
            for k, v in cache.items()}


def _slice_paged(cache: dict, row: int) -> dict:
    """Paged layout: block-pool leaves are SHARED across slots (the sliced
    block-table row selects the probe slot's blocks); only ``lengths`` is
    per-slot."""
    return {k: (v[row:row + 1] if k == "lengths" else v)
            for k, v in cache.items()}


class ErrorProbe:
    """Engine-side driver: slice one scheduled row, run the two probe
    forwards, return ``{layers, logits, row}`` moment report."""

    def __init__(self, decode_slots, mesh=None, paged: bool = False) -> None:
        if not self.supports(decode_slots):
            raise ValueError(
                "error probe requires a decode_slots that accepts "
                "unroll_layers (the scanned layer stack must unroll for "
                "the recorder to see concrete per-layer values); this "
                "model's serving step does not")
        self._decode = decode_slots
        self._mesh = mesh
        self._paged = paged

    @staticmethod
    def supports(decode_slots) -> bool:
        try:
            return "unroll_layers" in inspect.signature(
                decode_slots).parameters
        except (TypeError, ValueError):
            return False

    def run(self, params, tokens, n_valid, cache, block_tables=None,
            row: int | None = None) -> dict | None:
        """Probe one row of a scheduled batch against its PRE-STEP cache.

        ``tokens``/``n_valid`` are the batch arrays, ``cache`` the cache
        the jitted step consumed (JAX arrays are immutable, so holding the
        pre-update reference is free).  Returns None when no row is
        active.
        """
        nv = np.asarray(n_valid)
        if row is None:
            live = np.nonzero(nv > 0)[0]
            if live.size == 0:
                return None
            row = int(live[0])
        elif nv[row] <= 0:
            return None
        toks = jnp.asarray(np.asarray(tokens)[row:row + 1])
        nv_row = jnp.asarray(nv[row:row + 1])
        sliced = (_slice_paged if self._paged else _slice_contiguous)(
            cache, row)
        kw = {"mesh": self._mesh, "unroll_layers": True}
        if block_tables is not None:
            kw["block_tables"] = jnp.asarray(
                np.asarray(block_tables)[row:row + 1])
        with ProbeRecorder("observe") as rec:
            logits_a, _ = self._decode(params, toks, sliced, nv_row, **kw)
        with ProbeRecorder("exact"):
            logits_e, _ = self._decode(params, toks, sliced, nv_row, **kw)
        col = int(nv[row]) - 1
        d = (np.asarray(logits_a, np.float64)[0, col]
             - np.asarray(logits_e, np.float64)[0, col])
        return {
            "row": row,
            "layers": {path: {"n": n, "mean": mean, "var": var}
                       for path, (n, mean, var) in rec.layers.items()},
            "logits": {"n": int(d.size), "mean": float(d.mean()),
                       "var": float(d.var()),
                       "max_abs": float(np.abs(d).max())},
        }
