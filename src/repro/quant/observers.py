"""Activation-range calibration (the offline pass TFLite/TFApprox also run).

Usage:

    with CalibrationRecorder() as rec:
        for batch in calib_batches:
            model_apply(params, batch)          # float path, UNJITTED
    ranges = rec.ranges()                        # {"layer/path": (lo, hi)}
    packed = pack_params(params, policy_fn, act_ranges=ranges)

Model code cooperates via :func:`scope`/:func:`record`: the framework's
``dense()`` float path records input min/max when a recorder is active; model
layers push readable path components with ``scope("blocks", i)``.  Recording
is a no-op during jitted execution (tracers are ignored), so training speed
is unaffected.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np

_STATE = threading.local()


def _stack() -> list[str]:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


def _recorder():
    return getattr(_STATE, "recorder", None)


@contextlib.contextmanager
def scope(*names):
    """Push path components for calibration bookkeeping."""
    st = _stack()
    n = len(st)
    st.extend(str(x) for x in names)
    try:
        yield
    finally:
        del st[n:]


def current_path() -> str:
    return "/".join(_stack())


def record(x) -> None:
    """Record min/max of a concrete activation under the current scope."""
    rec = _recorder()
    if rec is None:
        return
    if isinstance(x, jax.core.Tracer):  # jitted — nothing concrete to record
        return
    arr = np.asarray(x)
    rec._update(current_path(), float(arr.min()), float(arr.max()))


class CalibrationRecorder:
    """Accumulates per-scope activation ranges across calibration batches."""

    def __init__(self) -> None:
        self._ranges: dict[str, tuple[float, float]] = {}

    def _update(self, path: str, lo: float, hi: float) -> None:
        if path in self._ranges:
            plo, phi = self._ranges[path]
            self._ranges[path] = (min(plo, lo), max(phi, hi))
        else:
            self._ranges[path] = (lo, hi)

    def ranges(self) -> dict[str, tuple[float, float]]:
        return dict(self._ranges)

    def __enter__(self) -> "CalibrationRecorder":
        if _recorder() is not None:
            raise RuntimeError("nested CalibrationRecorder")
        _STATE.recorder = self
        return self

    def __exit__(self, *exc) -> None:
        _STATE.recorder = None
