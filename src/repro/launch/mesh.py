"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first jax use).

Single pod:  (16, 16) over ("data", "model") = 256 chips (TPU v5e pod slice).
Multi-pod:   (2, 16, 16) over ("pod", "data", "model") = 512 chips; the
"pod" axis composes with "data" for batch/gradient parallelism (DCN-friendly
— one gradient all-reduce per step crosses pods), while "model" (TP/EP)
stays inside a pod on ICI.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (device count set by the test's XLA_FLAGS)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
