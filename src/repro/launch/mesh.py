"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first jax use).

Single pod:  (16, 16) over ("data", "model") = 256 chips (TPU v5e pod slice).
Multi-pod:   (2, 16, 16) over ("pod", "data", "model") = 512 chips; the
"pod" axis composes with "data" for batch/gradient parallelism (DCN-friendly
— one gradient all-reduce per step crosses pods), while "model" (TP/EP)
stays inside a pod on ICI.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 spells explicit/auto sharding via AxisType
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:  # older jax: meshes are Auto by default, no kwarg
    _AXIS_KW = lambda n: {}  # noqa: E731


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (device count set by the test's XLA_FLAGS)."""
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def use_mesh(mesh):
    """Context manager entering ``mesh``: ``jax.set_mesh`` on new jax, the
    Mesh object's own context manager on versions that predate it."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
