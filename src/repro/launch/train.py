"""Training driver: step builder (loss + grad + AdamW, sharded) and a CLI
that trains a reduced model on the synthetic stream with the full
fault-tolerance stack (checkpoint/restart, straggler monitor, heartbeat).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b-reduced \
        --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine
from repro.optim.grad_compress import compress_decompress, compressor_init
from repro.parallel import batch_shardings, param_shardings


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    base_lr: float = 3e-4
    warmup_steps: int = 20
    total_steps: int = 1000
    grad_compress: bool = False  # int8 error-feedback DP gradients
    fsdp: bool = False
    microbatches: int = 1  # gradient accumulation (activation-memory lever)


def cast_params(params: Any, dtype_name: str, shardings: Any = None) -> Any:
    """Mixed precision: f32 master weights -> compute-dtype copies at use.
    Differentiating through the cast routes grads back to the f32 masters.

    When ``shardings`` (the FSDP sharding tree) is given, the bf16 copy is
    constrained to the SAME sharding as the master — forcing XLA to convert
    BEFORE the FSDP all-gather, so weight gathers move bf16, not f32 (halves
    the per-microbatch re-gather bytes; EXPERIMENTS.md §Perf)."""
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[dtype_name]

    def leaf(p, sh=None):
        if hasattr(p, "dtype") and p.dtype == jnp.float32:
            c = p.astype(dt)
            if sh is not None:
                c = jax.lax.with_sharding_constraint(c, sh)
            return c
        return p

    if shardings is None:
        return jax.tree.map(leaf, params)
    return jax.tree.map(leaf, params, shardings)


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, mesh=None,
                    param_sh: Any = None):
    """Returns step(state, batch) -> (state, metrics).  state = dict(params,
    opt, [ef]).  Pure; jit/shard outside.  ``param_sh``: optional parameter
    sharding tree enabling convert-before-gather mixed precision."""
    api = build_model(cfg)
    lr_fn = warmup_cosine(tcfg.base_lr, tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        return api.train_loss(
            cast_params(params, cfg.compute_dtype, param_sh),
            batch, mesh=mesh)

    def step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            batches = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_fn(carry, mbatch):
                loss_sum, gacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_sum + l, gacc), None

            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0.0), zero), batches)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if tcfg.grad_compress:
            grads, ef = compress_decompress(grads, state["ef"])
        lr = lr_fn(state["opt"]["step"])
        new_params, new_opt = adamw_update(params, grads, state["opt"],
                                           tcfg.optimizer, lr)
        new_state = {"params": new_params, "opt": new_opt}
        if tcfg.grad_compress:
            new_state["ef"] = ef
        return new_state, {"loss": loss, "lr": lr}

    return step


def init_train_state(cfg: ArchConfig, tcfg: TrainConfig, key) -> dict:
    api = build_model(cfg)
    params = api.init(key)
    state = {"params": params, "opt": adamw_init(params, tcfg.optimizer)}
    if tcfg.grad_compress:
        state["ef"] = compressor_init(params)
    return state


def train_state_shardings(cfg: ArchConfig, tcfg: TrainConfig, mesh,
                          dp_only: bool = False):
    """Sharding tree matching init_train_state's structure (via eval_shape)."""
    abstract = jax.eval_shape(
        lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    )
    p_sh = param_shardings(abstract["params"], mesh, cfg, fsdp=tcfg.fsdp,
                           dp_only=dp_only)
    out = {"params": p_sh,
           "opt": {"m": p_sh, "v": p_sh,
                   "step": jax.sharding.NamedSharding(
                       mesh, jax.sharding.PartitionSpec())}}
    if tcfg.grad_compress:
        out["ef"] = p_sh
    return out


# ---------------------------------------------------------------------------
# CLI driver (CPU-scale; the full-scale path is exercised by the dry-run)
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b-reduced")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args(argv)

    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticLMConfig, ShardedLoader
    from repro.data.synthetic import lm_batch
    from repro.runtime import StragglerMonitor, run_resilient, RetryPolicy

    cfg = get_config(args.arch)
    tcfg = TrainConfig(base_lr=args.lr, total_steps=args.steps,
                       grad_compress=args.grad_compress)
    dcfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    monitor = StragglerMonitor()
    manager = CheckpointManager(args.ckpt_dir)
    loader = ShardedLoader(lambda s, sh, ns: lm_batch(dcfg, s, sh, ns))
    losses: list[float] = []

    def wrapped(state, batch, step):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:5d}  loss {losses[-1]:.4f}")
        return state

    t0 = time.time()
    run_resilient(
        init_state=lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0)),
        step_fn=wrapped,
        loader=loader,
        manager=manager,
        total_steps=args.steps,
        policy=RetryPolicy(checkpoint_every=args.ckpt_every),
        monitor=monitor,
    )
    loader.close()
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers={len(monitor.flagged)}")


if __name__ == "__main__":
    main()
