import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory/cost/roofline analyses.

THE TWO LINES ABOVE MUST STAY FIRST — jax locks the device count at first
initialization, and the production meshes need 512 placeholder host devices.
This module is the ONLY place that flag is set (tests/benches see 1 device).

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*abstract_inputs)
        compiled = lowered.compile()
        memory_analysis(), cost_analysis(), collective parse -> roofline

Cells: 10 archs x 4 shapes, minus the assigned skips (encoder-only decode,
full-attention long_500k) = 31 runnable cells, each on the single-pod
(16, 16) mesh (roofline table) AND the multi-pod (2, 16, 16) mesh (proves
the "pod" axis shards).  Results append to artifacts/dryrun/*.json so the
sweep is resumable.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --multi-pod both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.base import ArchConfig
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.roofline import model_flops_estimate, roofline_from_compiled
from repro.launch.serve import ServeConfig, build_serving_params, make_decode_step, make_prefill_step
from repro.launch.train import TrainConfig, init_train_state, make_train_step, train_state_shardings
from repro.models import build_model
from repro.models.registry import SHAPES, input_specs, shape_applicable
from repro.parallel import batch_shardings, cache_shardings, param_shardings

ARTIFACT_DIR = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 "..", "..", "..", "artifacts", "dryrun"))


def _arch_for_run(cfg: ArchConfig, mesh, kind: str) -> ArchConfig:
    """Launch-time overrides: EP MoE on the mesh; bf16 compute."""
    over = {}
    if cfg.mlp == "moe":
        over["moe_impl"] = "ep_psum"
    if kind == "train" and cfg.name in ("deepseek-67b",):
        pass  # fsdp flag handled in TrainConfig
    return dataclasses.replace(cfg, **over) if over else cfg


def _serving_abstract_params(cfg: ArchConfig, scfg: ServeConfig):
    """Abstract packed serving params via eval_shape (no allocation)."""
    api = build_model(cfg)

    def build():
        params = api.init(jax.random.PRNGKey(0))
        return build_serving_params(params, cfg, scfg)

    return jax.eval_shape(build)


def run_cell(arch: str, shape: str, multi_pod: bool, approx_mode: str = "perforated",
             approx_m: int = 2, overrides: dict | None = None) -> dict:
    """Lower + compile one cell; returns the record for EXPERIMENTS.md.

    ``overrides`` replaces ArchConfig fields (perf variants, e.g.
    sequence_parallel=True) — variant artifacts are kept separate from the
    baselines."""
    t_start = time.time()
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    base_cfg = get_config(arch)
    cfg = _arch_for_run(base_cfg, mesh, spec.kind)
    overrides = dict(overrides or {})
    microbatches = int(overrides.pop("microbatches", 1))
    moments_bf16 = bool(overrides.pop("moments_bf16", False))
    dp_only = bool(overrides.pop("dp_only", False))
    cache_dtype = overrides.pop("cache_dtype", "bfloat16")
    arch_overrides = overrides
    if arch_overrides:
        cfg = dataclasses.replace(cfg, **arch_overrides)

    record: dict = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "chips": int(n_chips), "kind": spec.kind,
        "overrides": {**arch_overrides, **({"microbatches": microbatches} if microbatches > 1 else {}), **({"moments_bf16": True} if moments_bf16 else {}), **({"dp_only": True} if dp_only else {}), **({"cache_dtype": cache_dtype} if cache_dtype != "bfloat16" else {})},
    }

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skip", reason=reason)
        return record

    with use_mesh(mesh):
        if spec.kind == "train":
            fsdp = cfg.name in ("deepseek-67b", "granite-8b")
            from repro.optim import AdamWConfig

            tcfg = TrainConfig(
                fsdp=fsdp, microbatches=microbatches,
                optimizer=AdamWConfig(
                    moment_dtype="bfloat16" if moments_bf16 else "float32"))
            abstract_state = jax.eval_shape(
                lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0)))
            state_sh = train_state_shardings(cfg, tcfg, mesh, dp_only=dp_only)
            step = make_train_step(cfg, tcfg, mesh=mesh,
                                   param_sh=state_sh["params"])
            batch_abs = input_specs(cfg, shape)["batch"]
            batch_sh = batch_shardings(batch_abs, mesh, dp_only=dp_only)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(abstract_state, batch_abs)
        else:
            from repro.core.policy import ApproxPolicy
            from repro.numerics import get_preset

            num_spec = get_preset("serve-default",
                                  policy=ApproxPolicy(approx_mode, approx_m,
                                                      use_cv=True))
            scfg = ServeConfig(spec=num_spec, cache_dtype=cache_dtype)
            params_abs = _serving_abstract_params(cfg, scfg)
            params_sh = param_shardings(params_abs, mesh, cfg)
            if spec.kind == "prefill":
                step = make_prefill_step(cfg, max_len=spec.seq_len, mesh=mesh, scfg=scfg)
                batch_abs = input_specs(cfg, shape)["batch"]
                batch_sh = batch_shardings(batch_abs, mesh)
                api = build_model(cfg)
                cache_abs = jax.eval_shape(
                    lambda: api.init_cache(spec.global_batch, spec.seq_len, jnp.bfloat16))
                cache_sh = cache_shardings(cache_abs, mesh, cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, batch_sh),
                    out_shardings=(None, cache_sh),
                )
                lowered = jitted.lower(params_abs, batch_abs)
            else:  # decode
                step = make_decode_step(cfg, mesh=mesh, scfg=scfg)
                specs = input_specs(cfg, shape)
                cache_abs = specs["cache"]
                if cache_dtype == "int8":
                    api = build_model(cfg)
                    cache_abs = jax.eval_shape(
                        lambda: api.init_cache(spec.global_batch, spec.seq_len,
                                               jnp.int8))
                cache_sh = cache_shardings(cache_abs, mesh, cfg)
                tok_abs = specs["tokens"]
                tok_sh = batch_shardings(tok_abs, mesh)
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, tok_sh, cache_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(params_abs, tok_abs, cache_abs)

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        mem = compiled.memory_analysis()
        terms = roofline_from_compiled(compiled)

    mf = model_flops_estimate(base_cfg, spec.kind, spec.seq_len, spec.global_batch)
    mf_per_chip = mf / n_chips
    record.update(
        status="ok",
        lower_s=round(t_lower - t_start, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory={
            k: int(getattr(mem, k, 0))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
        },
        roofline=terms.as_dict(),
        model_flops_global=mf,
        model_flops_per_chip=mf_per_chip,
        useful_flops_ratio=(mf_per_chip / terms.flops) if terms.flops else None,
    )
    return record


def _out_path(arch: str, shape: str, multi_pod: bool, variant: str = "") -> str:
    base = ARTIFACT_DIR if not variant else os.path.join(
        os.path.dirname(ARTIFACT_DIR), "perf")
    os.makedirs(base, exist_ok=True)
    pod = "multipod" if multi_pod else "singlepod"
    suffix = f"__{variant}" if variant else ""
    return os.path.join(base, f"{arch}__{shape}__{pod}{suffix}.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[None, *SHAPES])
    ap.add_argument("--multi-pod", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--approx-mode", default="perforated")
    ap.add_argument("--approx-m", type=int, default=2)
    ap.add_argument("--variant", default="", help="perf-variant artifact label")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override, e.g. --set sequence_parallel=true")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                path = _out_path(arch, shape, mp, args.variant)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} {shape} multi_pod={mp}")
                    continue
                label = f"{arch} {shape} multi_pod={mp}"
                if args.variant:
                    label += f" variant={args.variant}"
                print(f"[run] {label} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp,
                                   approx_mode=args.approx_mode, approx_m=args.approx_m,
                                   overrides=overrides or None)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(label)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.2e}s"
                             f" mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s"
                             f" compile={rec['compile_s']}s")
                elif status == "skip":
                    extra = f" ({rec['reason']})"
                print(f"[{status}] {label}{extra}", flush=True)
    if failures:
        print(f"\nFAILED cells ({len(failures)}):")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells complete")


if __name__ == "__main__":
    main()
