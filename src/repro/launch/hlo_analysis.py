"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

Why this exists: `compiled.cost_analysis()` counts each while-loop BODY
exactly once, but our models deliberately emit layer stacks / q-chunks /
loss-chunks as `lax.scan` (compile-time compactness at 95 layers) — so the
built-in numbers under-count a 36-layer model by ~36x, and collectives
inside FSDP scan bodies vanish from the totals.  This module parses the
optimized HLO, resolves the computation call graph (while bodies x inferred
trip count, fusion/call bodies x 1 per call site), and aggregates:

  flops            dots: 2 * prod(result dims) * prod(contracting dims)
                   (batch dims included via the result shape); elementwise
                   ops: 1 flop/element; data-movement ops: 0.
  bytes            operands + results of ops at computation level, where
                   fusion internals count ZERO (the fusion op's own
                   operands/results are the post-fusion traffic) — a closer
                   HBM model than the built-in sum-over-all-ops.
  collective bytes all-reduce / all-gather / reduce-scatter / all-to-all /
                   collective-permute result bytes, x multiplicity.

Trip counts come from the while condition (compare(iv, constant(N)) with
LT/GT direction, jax's canonical scan lowering); a condition we cannot
parse contributes multiplicity 1 and is flagged in the result.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")

_DATA_MOVEMENT = {
    "parameter", "constant", "iota", "tuple", "get-tuple-element", "bitcast",
    "copy", "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "reverse", "pad", "gather",
    "scatter", "convert", "after-all", "custom-call", "copy-start",
    "copy-done", "rng-bit-generator", "partition-id", "replica-id",
    "optimization-barrier", "infeed", "outfeed",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_CALL_ATTRS = ("calls", "body", "condition", "to_apply", "branch_computations",
               "true_computation", "false_computation")


def _shape_elems_bytes(txt: str):
    elems, byts = 0, 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class _Op:
    name: str
    opname: str
    line: str
    result_txt: str
    operand_txt: str
    callees: list  # (attr, computation_name)


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list


_OP_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
# computation headers start at column 0: "%name (params) -> type {" / "ENTRY ..."
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLEE_RE = re.compile(
    r"\b(calls|body|condition|to_apply|true_computation|false_computation)=%?([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_computations(text: str):
    comps: dict[str, _Computation] = {}
    entry = None
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if line and not line[0].isspace():
            hdr = _COMP_HDR.match(line)
            if hdr and "=" not in line.split("->")[0].split("(")[0]:
                cur = _Computation(hdr.group(2), [])
                comps[cur.name] = cur
                if hdr.group(1):
                    entry = cur.name
                continue
            if line.strip() == "}":
                cur = None
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        _, name, result_txt, opname = m.groups()
        paren = line.find(opname + "(") + len(opname)
        # operands run to the matching close paren; attributes follow after
        depth, i = 0, paren
        while i < len(line):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        operand_txt = line[paren : i + 1]
        attr_txt = line[i + 1 :]
        callees = [(a, c) for a, c in _CALLEE_RE.findall(attr_txt)]
        bm = _BRANCHES_RE.search(attr_txt)
        if bm:
            for c in bm.group(1).split(","):
                callees.append(("branch", c.strip().lstrip("%")))
        cur.ops.append(_Op(name, opname, line, result_txt, operand_txt, callees))
    return comps, entry


_TRIP_RE = re.compile(r"constant\((\d+)\)")
_CMP_DIR_RE = re.compile(r"direction=(LT|GT|LE|GE|NE)")


def _trip_count(comps, cond_name: str) -> int | None:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = []
    direction = None
    for op in cond.ops:
        if op.opname == "constant":
            m = _TRIP_RE.search(op.line)
            if m:
                consts.append(int(m.group(1)))
        if op.opname == "compare":
            d = _CMP_DIR_RE.search(op.line)
            if d:
                direction = d.group(1)
            m2 = _TRIP_RE.findall(op.line)
            if m2:
                consts.extend(int(x) for x in m2)
    if direction in ("LT", "GT", "NE") and consts:
        return max(consts)
    if consts:
        return max(consts)
    return None


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _operand_shapes(op: _Op, symtab: dict[str, str]) -> list[str]:
    """Shape texts of an op's operands via the module symbol table (operand
    references in optimized HLO carry no inline shapes)."""
    out = []
    for name in _OPERAND_NAME_RE.findall(op.operand_txt):
        txt = symtab.get(name)
        if txt is not None:
            out.append(txt)
    return out


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(op.result_txt)
    opshapes = _operand_shapes(op, symtab)
    if not opshapes:
        return 2.0 * res_elems  # unknown K: count as elementwise-ish
    lhs_matches = _SHAPE_RE.findall(opshapes[0])
    if not lhs_matches:
        return 2.0 * res_elems
    lhs = [int(d) for d in lhs_matches[0][1].split(",")] if lhs_matches[0][1] else []
    m = _DOT_CONTRACT_RE.search(op.line)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs):
                k *= lhs[di]
    return 2.0 * res_elems * k


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: dict
    collective_counts: dict
    unknown_trip_counts: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze_hlo(text: str, debug_top: int = 0) -> HLOCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None

    # symbol tables: HLO names are unique PER COMPUTATION (param_0.X etc.
    # repeat across fusions), so operand resolution must be local-first.
    local_symtab: dict[str, dict[str, str]] = {
        name: {op.name: op.result_txt for op in comp.ops}
        for name, comp in comps.items()
    }

    # resolve multiplicities from ENTRY
    mult: dict[str, float] = defaultdict(float)
    unknown = [0]

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for op in comp.ops:
            body = dict(op.callees)
            if op.opname == "while":
                # prefer XLA's own annotation on the while line
                cfg = _TRIP_CFG_RE.search(op.line)
                trip = int(cfg.group(1)) if cfg else None
                if trip is None and "condition" in body:
                    trip = _trip_count(comps, body["condition"])
                if trip is None:
                    trip = 1
                    unknown[0] += 1
                if "body" in body:
                    visit(body["body"], m * trip)
                if "condition" in body:
                    visit(body["condition"], m * (trip + 1))
            else:
                for attr, callee in op.callees:
                    visit(callee, m)

    if entry:
        visit(entry, 1.0)

    flops = 0.0
    byts = 0.0
    coll_b: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_n: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opname == "fusion":
                for attr, callee in op.callees:
                    if attr == "calls":
                        fusion_bodies.add(callee)

    # per fusion body: largest internal dynamic-slice result (when a fusion
    # receives a full scan-stacked buffer + index, it only READS the slice)
    ds_max: dict[str, int] = {}
    for cname in fusion_bodies:
        body = comps.get(cname)
        if body is None:
            continue
        best = 0
        for op in body.ops:
            if op.opname == "dynamic-slice":
                _, b = _shape_elems_bytes(op.result_txt)
                best = max(best, b)
        ds_max[cname] = best

    debug_rows: list = []

    def _note(b, m, op, cname):
        if debug_top:
            debug_rows.append((b, m, op.opname, op.name, cname))

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fusion_bodies
        symtab = local_symtab[name]
        for op in comp.ops:
            kind = None
            for k in _COLLECTIVES:
                if op.opname == k or op.opname.startswith(k + "-") or op.opname.startswith(k + "."):
                    kind = k
                    break
            if kind:
                _, rb = _shape_elems_bytes(op.result_txt)
                coll_b[kind] += m * rb
                coll_n[kind] += m
                _b_ = m * rb * 2
                byts += _b_
                _note(_b_, m, op, name)
                continue
            if op.opname == "dot":
                flops += m * _dot_flops(op, symtab)
                if not in_fusion:
                    _, rb = _shape_elems_bytes(op.result_txt)
                    ob = sum(_shape_elems_bytes(s)[1] for s in _operand_shapes(op, symtab))
                    _b_ = m * (rb + ob)
                    byts += _b_
                    _note(_b_, m, op, name)
                continue
            if op.opname == "convolution":
                # rough: 2 * result_elems * (kernel elems) — kernel is operand 2
                res_e, _ = _shape_elems_bytes(op.result_txt)
                opshapes = _operand_shapes(op, symtab)
                k_e = 1
                if len(opshapes) > 1:
                    km = _SHAPE_RE.findall(opshapes[1])
                    if km and km[0][1]:
                        for d in km[0][1].split(","):
                            k_e *= int(d)
                flops += m * 2.0 * res_e * k_e
                if not in_fusion:
                    _, rb = _shape_elems_bytes(op.result_txt)
                    ob = sum(_shape_elems_bytes(s)[1] for s in _operand_shapes(op, symtab))
                    _b_ = m * (rb + ob)
                    byts += _b_
                    _note(_b_, m, op, name)
                continue
            if op.opname in ("while", "call", "conditional"):
                continue  # callee costs attributed via multiplicity
            if op.opname == "fusion":
                # fusion boundary = the real traffic, with in-place / output-
                # driven semantics:
                #   * dynamic-update-slice fusions write only the slice (the
                #     aliased full-size buffer is not re-read);
                #   * reduce-like fusions read operands in full;
                #   * loop (elementwise/slice/copy) fusions read at most
                #     result-size bytes per operand — a full stacked scan
                #     buffer passed in is only sliced, not streamed.
                _, rb = _shape_elems_bytes(op.result_txt)
                opb = [_shape_elems_bytes(s)[1] for s in _operand_shapes(op, symtab)]
                ob = sum(opb)
                tokens = set(re.split(r"[._\-]", op.name))
                body_name = dict(op.callees).get("calls", "")
                internal_ds = ds_max.get(body_name, 0)
                if "dynamic-update-slice" in op.name:
                    small = ob - (max(opb) if opb else 0)
                    _b_ = m * 2 * small
                    byts += _b_
                    _note(_b_, m, op, name)
                elif "dynamic-slice" in op.name:
                    _b_ = m * 2 * rb
                    byts += _b_
                    _note(_b_, m, op, name)
                elif tokens & {"reduce", "dot", "convolution", "window"}:
                    if internal_ds:
                        cap = max(rb, internal_ds)
                        _b_ = m * (rb + sum(min(b, cap) for b in opb))
                        byts += _b_
                        _note(_b_, m, op, name)
                    else:
                        _b_ = m * (rb + ob)  # true full-operand reads
                        byts += _b_
                        _note(_b_, m, op, name)
                else:
                    cap = max(rb, internal_ds)
                    _b_ = m * (rb + sum(min(b, cap) for b in opb))
                    byts += _b_
                    _note(_b_, m, op, name)
                # flops of internal dots are counted inside the body (dots
                # keep flop accounting even inside fusions); elementwise
                # internals approximated by result elements:
                res_e, _ = _shape_elems_bytes(op.result_txt)
                flops += m * res_e
                continue
            if op.opname in _DATA_MOVEMENT:
                if not in_fusion:
                    _, rb = _shape_elems_bytes(op.result_txt)
                    if op.opname == "dynamic-update-slice":
                        opb = [_shape_elems_bytes(s)[1]
                               for s in _operand_shapes(op, symtab)]
                        _b_ = m * 2 * (sum(opb) - (max(opb) if opb else 0))
                        byts += _b_
                        _note(_b_, m, op, name)
                    elif op.opname in ("dynamic-slice", "slice", "gather"):
                        _b_ = m * rb * 2
                        byts += _b_
                        _note(_b_, m, op, name)
                    elif op.opname in ("scatter", "concatenate", "copy",
                                       "transpose", "reshape", "pad"):
                        _b_ = m * rb * 2
                        byts += _b_
                        _note(_b_, m, op, name)
                continue
            # generic elementwise / reduce
            res_e, rb = _shape_elems_bytes(op.result_txt)
            flops += m * res_e
            if not in_fusion:
                ob = sum(_shape_elems_bytes(s)[1] for s in _operand_shapes(op, symtab))
                _b_ = m * (rb + ob)
                byts += _b_
                _note(_b_, m, op, name)

    if debug_top:
        debug_rows.sort(reverse=True)
        for b, m, opname, oname, cname in debug_rows[:debug_top]:
            print(f"  {b:.2e}  m={m:5.0f}  {opname:10s} {oname[:48]:48s} in {cname[:40]}")

    return HLOCost(
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=sum(coll_b.values()),
        collectives=coll_b,
        collective_counts=coll_n,
        unknown_trip_counts=unknown[0],
    )
