"""Serving driver: the paper's technique as a first-class deployment mode.

`build_serving_params` turns trained float parameters into the approximate
int8 + control-variate representation (uint8 weight codes, per-layer CV
constants, bf16 for the non-array parts) via one parameter transformation —
exactly the paper's deployment story (same network, different MAC array).

`make_prefill_step` / `make_decode_step` build the sharded serving steps the
dry-run lowers for the prefill_32k / decode_32k / long_500k cells.

The CLI drives the continuous-batching engine (repro.serving) on a reduced
model with a mixed-length request trace:

    PYTHONPATH=src python -m repro.launch.serve --engine --requests 8 \
        --arch olmo-1b-reduced --mode perforated --m 2

``--legacy`` keeps the old lock-step rectangular-batch loop for comparison.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig, EngineConfig
from repro.core.approx_linear import pack_params
from repro.core.policy import ApproxPolicy, uniform_policy
from repro.models import build_model

# layers kept float in serving: embeddings (lookup, not a GEMM), norms,
# router (control logic), kv_b (absorbed-decode einsums, DESIGN.md), and
# tiny lora/mix projections.
SERVE_SKIP = ("embed", "router", "kv_a", "kv_b", "q_norm", "k_norm", "norm",
              "dt_proj", "x_proj")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    policy: ApproxPolicy = ApproxPolicy("perforated", 2, use_cv=True)
    act_range: tuple[float, float] = (-8.0, 8.0)  # default when uncalibrated
    cache_dtype: str = "bfloat16"


def build_serving_params(params: Any, cfg: ArchConfig, scfg: ServeConfig,
                         act_ranges: dict | None = None) -> Any:
    """float params -> packed approximate serving params (+ bf16 float rest)."""
    policy_fn = uniform_policy(scfg.policy, skip=SERVE_SKIP)
    packed = pack_params(params, policy_fn, act_ranges=act_ranges,
                         default_range=scfg.act_range)

    def to_bf16(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 1:
            return x.astype(jnp.bfloat16)
        return x

    # only float leaves OUTSIDE packs go bf16 (pack internals stay exact)
    from repro.core.approx_linear import QuantizedDense

    def walk(node):
        if isinstance(node, QuantizedDense):
            return node
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return to_bf16(node)

    return walk(packed)


def _cache_dt(scfg: ServeConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "int8": jnp.int8}[scfg.cache_dtype]


def make_prefill_step(cfg: ArchConfig, max_len: int, mesh=None,
                      scfg: ServeConfig = ServeConfig()):
    api = build_model(cfg)

    def step(params, batch):
        return api.prefill(params, batch, max_len, mesh=mesh,
                           cache_dtype=_cache_dt(scfg))

    return step


def make_decode_step(cfg: ArchConfig, mesh=None, scfg: ServeConfig = ServeConfig()):
    api = build_model(cfg)

    def step(params, tokens, cache):
        return api.decode_step(params, tokens, cache, mesh=mesh)

    return step


# ---------------------------------------------------------------------------
# CLI: continuous-batching engine (default) / legacy lock-step demo
# ---------------------------------------------------------------------------


def _prepare_params(cfg: ArchConfig, args):
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.mode == "float":
        return params, "float"
    scfg = ServeConfig(
        policy=ApproxPolicy(args.mode, 0 if args.mode == "exact" else args.m,
                            use_cv=not args.no_cv)
    )
    return build_serving_params(params, cfg, scfg), scfg.policy.label()


def mixed_trace(cfg: ArchConfig, n_requests: int, max_len: int,
                prefill_chunk: int, seed: int = 0):
    """A heterogeneous request trace: short chat turns + long documents."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        if i % 3 == 2:  # long-document request
            plen = int(rng.integers(max_len // 2, max(max_len - 16, max_len // 2 + 1)))
        else:  # short chat turn
            plen = int(rng.integers(2, max(prefill_chunk, 3)))
        gen = int(rng.integers(4, 17))
        gen = min(gen, max_len - plen)
        plen = min(plen, max_len - gen)
        trace.append((rng.integers(0, cfg.vocab, plen).tolist(), gen))
    return trace


def run_engine(args) -> dict:
    from repro.serving import ServingEngine

    cfg = get_config(args.arch)
    params, label = _prepare_params(cfg, args)
    ecfg = EngineConfig(slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.chunk, cache_dtype=args.cache_dtype)
    eng = ServingEngine(cfg, params, ecfg)
    print(f"arch={cfg.name} numerics={label} slots={ecfg.slots} "
          f"max_len={ecfg.max_len} chunk={ecfg.prefill_chunk} "
          f"kv={ecfg.cache_dtype}")

    trace = mixed_trace(cfg, args.requests, ecfg.max_len, ecfg.prefill_chunk)
    for prompt, gen in trace:
        r = eng.submit(prompt, gen)
        if r.state.value == "rejected":
            print(f"  request {r.rid} rejected: {r.reject_reason}")
    finished = eng.run()
    snap = eng.metrics.snapshot()
    print(f"finished {len(finished)}/{len(trace)} requests, "
          f"{eng.compile_count()} compiled shapes")
    print(json.dumps(snap, indent=2))
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt {r.prompt_len:4d} -> gen "
              f"{len(r.generated):3d} [{r.finish_reason}] "
              f"sample {r.generated[:8]}")
    return snap


def run_legacy(args) -> None:
    cfg = get_config(args.arch)
    params, label = _prepare_params(cfg, args)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} numerics={label}")
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:16].tolist())


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b-reduced")
    ap.add_argument("--mode", default="perforated",
                    choices=["exact", "perforated", "truncated", "recursive", "float"])
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--no-cv", action="store_true")
    # engine path (default)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine (default path)")
    ap.add_argument("--legacy", action="store_true",
                    help="old lock-step rectangular batch loop")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "float32", "int8"])
    # legacy path knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    if args.legacy:
        run_legacy(args)
    else:
        run_engine(args)


if __name__ == "__main__":
    main()
