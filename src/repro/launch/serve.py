"""Serving driver: the paper's technique as a first-class deployment mode.

Numerics are configured declaratively: a :class:`~repro.numerics.NumericsSpec`
(preset, JSON file, or built in code) resolves against the parameter tree
into a :class:`~repro.numerics.PackPlan`, and `build_serving_params` executes
that plan — float params become the approximate int8 + control-variate
representation (uint8 weight codes, per-layer CV constants, bf16 for the
non-array parts) in one parameter transformation, exactly the paper's
deployment story (same network, different MAC array).

`make_prefill_step` / `make_decode_step` build the sharded serving steps the
dry-run lowers for the prefill_32k / decode_32k / long_500k cells.

The CLI drives the continuous-batching engine (repro.serving) on a reduced
model with a mixed-length request trace:

    PYTHONPATH=src python -m repro.launch.serve --engine --requests 8 \
        --arch olmo-1b-reduced --mode perforated --m 2

``--kv-layout paged`` serves through the block-granular paged KV cache
(``--block-size``/``--kv-blocks``/``--no-prefix-cache`` knobs), and
``--shared-prefix-pair`` prepends a warmed shared-prefix request pair that
asserts the prefix-cache hit (the CI paged smoke).

``--speculative-k K`` turns on self-verifying speculative decode
(repro.serving.speculative): the SAME float init is packed twice — the
numerics flags (or ``--draft-spec``, a preset name or spec-JSON path)
describe the APPROXIMATE draft parameters, the verifier is always exact
int8 — and the engine emits bit-identical exact output while the cheap
path proposes.  ``--assert-acceptance`` fails the run unless the verifier
accepted at least one draft (the CI speculative smoke).

and `plan` prints the resolved per-layer assignment table without packing
anything (shapes only, runs in milliseconds):

    PYTHONPATH=src python -m repro.launch.serve plan --arch olmo-1b-reduced
    PYTHONPATH=src python -m repro.launch.serve plan --preset int8 --json

``plan --diff-checkpoint PATH`` additionally resolves the NumericsSpec
persisted in that checkpoint's metadata against the same abstract
parameters and exits nonzero if any layer's assignment drifted from the
CLI spec — the deploy-time guard against serving a checkpoint under
different numerics than it was saved with.

``--legacy`` keeps the old lock-step rectangular-batch loop for comparison;
``--spec-json FILE`` serves under a spec shipped as JSON (the same payload
checkpoints and engine metadata carry).

Robustness (PR 8): ``--governor --slo-err-var V`` attaches the accuracy-SLO
numerics governor (repro.serving.governor) — the error probe's running
variance estimate walks the degradation ladder CLI-spec -> int8 -> float,
hot-swapping the live pack; ``--inject-faults KIND@EVERY[@START-STOP]``
arms the deterministic fault injector (repro.quant.faults) and engine-side
quarantine (NaN rows are rolled back and replayed on the exact pack, so no
corrupted token is emitted — the run asserts it); ``--deadline-ms`` gives
every request a latency SLO; queue-full submissions retry with exponential
backoff (``--submit-retries``).

Fleet serving (PR 9): ``--fleet --tier SPEC=COUNT ...`` serves through
heterogeneous-numerics replica tiers behind the spec-aware router
(repro.serving.fleet) — one float init, one pack per tier, latency
traffic on exact tiers, bulk on approximate ones, cross-replica
prefix-cache sharing (``--share-prefixes-every``,
``--assert-prefix-share`` is the CI fleet smoke), per-replica traces
(``--trace-dir``).

Observability (PR 10): ``--shadow-spec NAME_OR_FILE --shadow-fraction F``
runs A/B shadow serving (repro.serving.shadow) — a deterministic sample
of finished requests is replayed teacher-forced through a second pack and
diffed token-by-token; the run prints the accuracy-vs-power verdict and
``--assert-shadow`` makes it a CI gate.  ``--layer-slo PATTERN=VAR``
(repeatable) gives the governor per-layer err-var ceilings on top of the
global SLO; ``--assert-layer-breach [PATTERN]`` asserts a matching layer
was named in a ``layer_slo_breach`` escalation AND is visible in the
windowed per-layer err-var time-series.  ``--inject-faults`` accepts a
fourth ``@LAYERS`` fnmatch segment (``dense-noise@1@blocks/0/*``) to
confine dense-surface noise to chosen layers.  ``--prom-out FILE``
exports the final metrics snapshot (engine or merged fleet) as
OpenMetrics text (repro.serving.prom), and ``tools/obs_dashboard.py``
renders the JSONL trace into a static HTML dashboard.
"""

from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import json
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig, EngineConfig
from repro.core.policy import ApproxPolicy
from repro.models import build_model
from repro.numerics import (NumericsSpec, PackPlan, apply_numerics,
                            get_preset)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving numerics + cache configuration.

    ``spec`` is the source of truth.  ``policy`` is a convenience shorthand
    — when ``spec`` is None, the policy is wrapped into the ``serve-default``
    preset (its documented keep-float rule-set plus this policy everywhere
    else), which reproduces the old uniform-policy behavior.
    """

    spec: NumericsSpec | None = None
    policy: ApproxPolicy | None = None
    act_range: tuple[float, float] = (-8.0, 8.0)  # default when uncalibrated
    cache_dtype: str = "bfloat16"
    fuse: bool = True  # fan-out fusion (Q|K|V, gate|up groups)
    fold: bool = True  # folded f32 serving operands (CPU fast path)

    def numerics_spec(self) -> NumericsSpec:
        if self.spec is not None:
            return self.spec
        return get_preset("serve-default", policy=self.policy)


def build_serving_params(params: Any, cfg: ArchConfig, scfg: ServeConfig,
                         act_ranges: dict | None = None,
                         plan: PackPlan | None = None) -> Any:
    """float params -> packed approximate serving params (+ bf16 float rest).

    ``plan`` short-circuits resolution when the caller already has one (e.g.
    printed/audited via the `plan` CLI, or restored from a checkpoint).
    """
    if plan is None:
        plan = scfg.numerics_spec().resolve(params)
    packed = apply_numerics(params, plan, act_ranges=act_ranges,
                            default_range=scfg.act_range,
                            fuse=scfg.fuse, fold=scfg.fold)

    def to_bf16(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 1:
            return x.astype(jnp.bfloat16)
        return x

    # only float leaves OUTSIDE packs go bf16 (pack internals stay exact)
    from repro.core.approx_linear import QuantizedDense

    def walk(node):
        if isinstance(node, QuantizedDense):
            return node
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return to_bf16(node)

    return walk(packed)


_CACHE_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                 "int8": jnp.int8}


def _cache_dt(scfg: ServeConfig):
    try:
        return _CACHE_DTYPES[scfg.cache_dtype]
    except KeyError:
        raise ValueError(
            f"unknown cache_dtype {scfg.cache_dtype!r}; "
            f"valid choices: {sorted(_CACHE_DTYPES)}") from None


def make_prefill_step(cfg: ArchConfig, max_len: int, mesh=None,
                      scfg: ServeConfig = ServeConfig()):
    api = build_model(cfg)

    def step(params, batch):
        return api.prefill(params, batch, max_len, mesh=mesh,
                           cache_dtype=_cache_dt(scfg))

    return step


def make_decode_step(cfg: ArchConfig, mesh=None, scfg: ServeConfig = ServeConfig()):
    api = build_model(cfg)

    def step(params, tokens, cache):
        return api.decode_step(params, tokens, cache, mesh=mesh)

    return step


# ---------------------------------------------------------------------------
# CLI: continuous-batching engine (default) / legacy lock-step demo / plan
# ---------------------------------------------------------------------------


def _spec_from_args(args) -> NumericsSpec | None:
    """Spec from CLI flags: --spec-json wins, then --preset, then --mode/--m
    shorthand.  Returns None for float serving (no packing at all)."""
    if getattr(args, "spec_json", None):
        with open(args.spec_json) as f:
            return NumericsSpec.from_json(f.read())
    if getattr(args, "preset", None):
        return get_preset(args.preset)
    if args.mode == "float":
        return None
    policy = ApproxPolicy(args.mode, 0 if args.mode == "exact" else args.m,
                          use_cv=not args.no_cv)
    return get_preset("serve-default", policy=policy)


def _prepare_params(cfg: ArchConfig, args):
    """Returns ``(serving_params, label, float_params, spec)`` — the float
    init and spec ride along so the robustness layer can build further
    packs (governor ladder rungs, the exact quarantine-replay pack) from
    the SAME weights."""
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    spec = _spec_from_args(args)
    if spec is None:
        return params, "float", params, None
    scfg = ServeConfig(spec=spec)
    return build_serving_params(params, cfg, scfg), spec.name, params, spec


def _draft_spec_from_args(args) -> NumericsSpec:
    """The draft spec under speculation.  ``--draft-spec`` names a preset
    or a spec-JSON file; otherwise the regular numerics flags
    (--mode/--m/--preset/--spec-json) describe the draft — the verifier
    is always exact int8, so under speculation those flags stop choosing
    the serving numerics and start choosing the proposer's."""
    from repro.numerics.presets import PRESETS

    ds = getattr(args, "draft_spec", None)
    if ds:
        if ds in PRESETS:
            return get_preset(ds)
        with open(ds) as f:
            return NumericsSpec.from_json(f.read())
    spec = _spec_from_args(args)
    if spec is None:
        raise SystemExit(
            "--speculative-k needs an approximate draft spec: float "
            "drafting buys nothing (pass --draft-spec, or --mode/--m)")
    return spec


def _spec_by_name_or_file(text: str) -> NumericsSpec:
    """A preset name or a spec-JSON file path -> NumericsSpec."""
    from repro.numerics.presets import PRESETS

    if text in PRESETS:
        return get_preset(text)
    with open(text) as f:
        return NumericsSpec.from_json(f.read())


def _parse_layer_slos(items: list[str] | None) -> dict[str, float]:
    """``--layer-slo PATTERN=VAR`` (repeatable) -> {pattern: ceiling}."""
    out: dict[str, float] = {}
    for item in items or []:
        pattern, sep, var = item.partition("=")
        if not sep or not pattern:
            raise SystemExit(f"--layer-slo {item!r}: expected PATTERN=VAR "
                             "(e.g. 'blocks/0/*=1e-4')")
        try:
            out[pattern] = float(var)
        except ValueError:
            raise SystemExit(
                f"--layer-slo {item!r}: VAR must be a float") from None
    return out


def _prepare_speculative_params(cfg: ArchConfig, args):
    """Pack the SAME float init twice: exact int8 for verification (and
    prefill), the draft spec for proposing — the one-checkpoint
    speculative pair (zero extra parameter memory at rest; both packs
    derive from one set of weights)."""
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    verify_spec = get_preset("int8")
    draft_spec = _draft_spec_from_args(args)
    verify = build_serving_params(params, cfg, ServeConfig(spec=verify_spec))
    draft = build_serving_params(params, cfg, ServeConfig(spec=draft_spec))
    return verify, verify_spec.name, draft, draft_spec.name


def mixed_trace(cfg: ArchConfig, n_requests: int, max_len: int,
                prefill_chunk: int, seed: int = 0):
    """A heterogeneous request trace: short chat turns + long documents."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        if i % 3 == 2:  # long-document request
            plen = int(rng.integers(max_len // 2, max(max_len - 16, max_len // 2 + 1)))
        else:  # short chat turn
            plen = int(rng.integers(2, max(prefill_chunk, 3)))
        gen = int(rng.integers(4, 17))
        gen = min(gen, max_len - plen)
        plen = min(plen, max_len - gen)
        trace.append((rng.integers(0, cfg.vocab, plen).tolist(), gen))
    return trace


def run_engine(args) -> dict:
    from repro.serving import ServingEngine

    cfg = get_config(args.arch)
    spec_k = getattr(args, "speculative_k", 0)
    if spec_k:
        params, label, draft_params, draft_label = (
            _prepare_speculative_params(cfg, args))
        params_float = spec = None
    else:
        params, label, params_float, spec = _prepare_params(cfg, args)
        draft_params = draft_label = None

    # -- robustness layer (repro.serving.governor / repro.quant.faults) ------
    governor = injector = pack_fn = exact_params = None
    probe_every = args.error_probe_every
    if getattr(args, "governor", False):
        if spec is None:
            raise SystemExit(
                "--governor needs an approximate serving spec (float serving "
                "has nothing to degrade; speculative serving is exact "
                "already) — pass --mode/--m, --preset, or --spec-json")
        if args.slo_err_var is None:
            raise SystemExit("--governor needs --slo-err-var: the logits "
                             "error-variance ceiling the ladder enforces")
        from repro.numerics import resolve_ladder
        from repro.serving import GovernorConfig, NumericsGovernor

        rungs: list = [spec]
        if spec.name != "int8":
            rungs.append("int8")
        rungs.append("float")
        ladder = resolve_ladder(rungs, params_float)
        governor = NumericsGovernor(ladder, GovernorConfig(
            slo_err_var=args.slo_err_var,
            window_probes=args.governor_window,
            clean_windows_to_relax=args.governor_relax_after,
            layer_slo=_parse_layer_slos(getattr(args, "layer_slo", None))))

        def pack_fn(s, _p=params_float, _cfg=cfg):
            if s is None:
                return _p  # the "float" rung serves the raw init
            return build_serving_params(_p, _cfg, ServeConfig(spec=s))

        if probe_every <= 0:
            probe_every = 4  # the governor consumes the probe; arm it
            print(f"governor: defaulting --error-probe-every to {probe_every}")
    if getattr(args, "inject_faults", None):
        from repro.quant.faults import FaultInjector, FaultSpec

        injector = FaultInjector(
            FaultSpec.parse(args.inject_faults, seed=args.fault_seed))
        if injector.spec.surface == "step" and label != "int8":
            # quarantine replays must run an exact pack; int8 IS exact
            exact_params = build_serving_params(
                params_float, cfg, ServeConfig(spec=get_preset("int8")))

    # -- A/B shadow serving (repro.serving.shadow) ----------------------------
    shadow_params = shadow_label = None
    shadow_fraction = 0.0
    if getattr(args, "shadow_spec", None):
        if spec_k:
            raise SystemExit("--shadow-spec is incompatible with "
                             "--speculative-k (the engine refuses mixed "
                             "draft/shadow dual-pack regimes)")
        if governor is not None:
            raise SystemExit("--shadow-spec is incompatible with --governor "
                             "(a hot-swapping primary makes the A/B verdict "
                             "a mixed-regime average)")
        if params_float is None:
            raise SystemExit("--shadow-spec needs the float init to pack "
                             "the shadow from")
        shadow_spec = _spec_by_name_or_file(args.shadow_spec)
        shadow_params = build_serving_params(
            params_float, cfg, ServeConfig(spec=shadow_spec))
        shadow_label = shadow_spec.name
        shadow_fraction = args.shadow_fraction

    ecfg = EngineConfig(slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.chunk, cache_dtype=args.cache_dtype,
                        mixed_batches=not args.no_mixed,
                        kv_layout=args.kv_layout,
                        kv_block_size=args.block_size,
                        kv_blocks=args.kv_blocks,
                        prefix_cache=not args.no_prefix_cache,
                        trace=bool(args.trace_out),
                        metrics_window_s=args.metrics_window,
                        error_probe_every=probe_every,
                        speculative_k=spec_k,
                        detect_faults=getattr(args, "detect_faults", False),
                        shadow_fraction=shadow_fraction)
    eng = ServingEngine(cfg, params, ecfg, numerics=label,
                        draft_params=draft_params, draft_numerics=draft_label,
                        governor=governor, pack_fn=pack_fn,
                        fault_injector=injector, exact_params=exact_params,
                        shadow_params=shadow_params,
                        shadow_numerics=shadow_label)
    print(f"arch={cfg.name} numerics={label} slots={ecfg.slots} "
          f"max_len={ecfg.max_len} chunk={ecfg.prefill_chunk} "
          f"kv={ecfg.cache_dtype} mixed={ecfg.mixed_batches} "
          f"layout={ecfg.kv_layout}"
          + (f" block_size={ecfg.kv_block_size} "
             f"prefix_cache={ecfg.prefix_cache}"
             if ecfg.kv_layout == "paged" else "")
          + (f" speculative_k={spec_k} draft={draft_label}"
             if spec_k else "")
          + (f" governor=[{' -> '.join(r.name for r in governor.ladder)}] "
             f"slo_err_var={args.slo_err_var}" if governor else "")
          + (f" inject={injector.spec.kind}@{injector.spec.every} "
             f"seed={injector.spec.seed}" if injector else "")
          + (f" shadow={shadow_label} fraction={shadow_fraction}"
             if shadow_params is not None else ""))

    trace = mixed_trace(cfg, args.requests, ecfg.max_len, ecfg.prefill_chunk)
    if args.shared_prefix_pair:
        # one warmed shared-prefix pair: the second request must attach to
        # the first one's cached blocks (the --paged-only CI smoke asserts
        # the hit below)
        rng = np.random.default_rng(17)
        shared = rng.integers(
            0, cfg.vocab,
            min(4 * ecfg.prefill_chunk, ecfg.max_len // 2)).tolist()
        warm = eng.submit(shared, 2)
        eng.run()
        hit = eng.submit(shared + rng.integers(0, cfg.vocab, 4).tolist(), 4)
        eng.run()
        print(f"  shared-prefix pair: warm gen={len(warm.generated)} "
              f"hit prefix_hit_tokens={hit.prefix_hit_tokens}")
        if ecfg.kv_layout == "paged" and ecfg.prefix_cache:
            # sharing is full-block granular and capped one token early,
            # so the guaranteed hit is the block-aligned shareable prefix
            shareable = min(len(shared) // ecfg.kv_block_size
                            * ecfg.kv_block_size, len(shared) - 1)
            assert hit.prefix_hit_tokens >= shareable, (
                hit.prefix_hit_tokens, shareable)
    deadline = args.deadline_ms if getattr(args, "deadline_ms", 0) else None
    for prompt, gen in trace:
        r = eng.submit(prompt, gen, deadline_ms=deadline)
        # bounded retry with exponential backoff for QUEUE-FULL rejections
        # only: a full queue is transient (steps drain it), every other
        # reject reason (capacity, validation) is permanent for this job
        attempt = 0
        while (r.state.value == "rejected"
               and (r.reject_reason or "").startswith("queue full")
               and attempt < args.submit_retries):
            for _ in range(2 ** attempt):  # backoff unit = one engine step
                eng.step()
            attempt += 1
            eng.metrics.requests_retried += 1
            r = eng.submit(prompt, gen, deadline_ms=deadline)
        if r.state.value == "rejected":
            print(f"  request {r.rid} rejected: {r.reject_reason}"
                  + (f" (after {attempt} retries)" if attempt else ""))
    finished = eng.run()
    snap = eng.metrics.snapshot()
    print(f"finished {len(finished)}/{len(trace)} requests, "
          f"{eng.compile_count()} compiled shapes")
    if getattr(args, "assert_acceptance", False):
        # the CI speculative smoke: the verifier must have accepted at
        # least one draft (acceptance_rate None means nothing was drafted)
        acc = snap.get("acceptance_rate")
        assert acc is not None and acc > 0, (
            f"speculative smoke expected acceptance > 0, got {acc!r} "
            f"(drafted={snap.get('drafted_tokens')})")
        print(f"  speculative: acceptance_rate={acc} "
              f"drafted={snap['drafted_tokens']} "
              f"accepted={snap['accepted_draft_tokens']}")
    if injector is not None:
        m = eng.metrics
        print(f"  faults: injected={m.faults_injected} "
              f"detected={m.faults_detected} quarantines={m.quarantines} "
              f"replays={m.quarantine_replays}")
        if injector.spec.surface == "step":
            # the no-corrupted-emission contract: every injected row was
            # caught, rolled back, and replayed on the exact pack
            assert m.faults_detected >= m.faults_injected, (
                m.faults_detected, m.faults_injected)
            assert m.quarantine_replays == m.faults_detected
            assert all(0 <= t < cfg.vocab for r in finished
                       for t in r.generated), "corrupted token emitted"
    if governor is not None:
        print(f"  governor: rung={eng.numerics} "
              f"switches={eng.metrics.governor_switches} "
              f"(escalate {eng.metrics.governor_escalations} / "
              f"relax {eng.metrics.governor_relaxes})")
        for d in governor.decisions:
            dd = d.to_dict()
            print(f"    window {dd['window']}: {dd['action']} "
                  f"{dd['from']} -> {dd['to']} [{dd['reason']}] "
                  f"err_var={dd['err_var']} "
                  f"power_delta={dd['power_delta_pct']}%"
                  + (f" layer={dd['layer']}" if dd.get("layer") else ""))
    verdict = eng.shadow_verdict() if shadow_params is not None else None
    if verdict is not None:
        print(f"  shadow A/B [{label} vs {shadow_label}]: "
              f"{verdict['verdict']} — match "
              f"{verdict['token_match_rate']:.3f} over "
              f"{verdict['tokens']} tokens "
              f"({verdict['sampled_requests']} replays), "
              f"logits_err_var={verdict['logits_err_var']:.3g}, "
              f"power_delta={verdict['power_delta_pct']:+.2f}pp "
              f"[{verdict['reason']}]")
    if getattr(args, "assert_shadow", False):
        # the CI shadow smoke: at least one finished request was replayed
        # through the shadow pack and a verdict was reached
        assert verdict is not None and verdict["sampled_requests"] >= 1, (
            f"shadow smoke expected >=1 sampled replay, got {verdict!r}")
    if getattr(args, "assert_layer_breach", None) is not None:
        pattern = args.assert_layer_breach or "*"
        breaches = [d.to_dict() for d in (governor.decisions if governor
                                          else [])
                    if d.to_dict().get("reason") == "layer_slo_breach"]
        named = [d for d in breaches
                 if fnmatch.fnmatch(d.get("layer") or "", pattern)]
        assert named, (
            f"no governor escalation with reason=layer_slo_breach matching "
            f"layer pattern {pattern!r} (breaches seen: "
            f"{[d.get('layer') for d in breaches]})")
        # ...and the breaching layer must be visible in the windowed
        # per-layer err-var time-series (the attribution surface)
        layer = named[0]["layer"]
        windows_with = [s for s in eng.metrics.timeseries
                        if layer in (s.get("probe_layers") or {})]
        assert windows_with, (
            f"breaching layer {layer!r} absent from all "
            f"{len(eng.metrics.timeseries)} metrics_window samples")
        print(f"  layer-SLO breach: {layer} escalated "
              f"{named[0]['from']} -> {named[0]['to']}, present in "
              f"{len(windows_with)} window sample(s)")
    print(json.dumps(snap, indent=2))
    if getattr(args, "prom_out", None):
        from repro.serving.prom import to_openmetrics
        with open(args.prom_out, "w") as f:
            f.write(to_openmetrics(snap, labels={"engine": eng.engine_id}))
        print(f"openmetrics: {args.prom_out}")
    if args.trace_out:
        eng.tracer.write(args.trace_out)
        print(f"trace: {len(eng.tracer)} spans "
              f"({eng.tracer.dropped} dropped) -> {args.trace_out}")
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt {r.prompt_len:4d} -> gen "
              f"{len(r.generated):3d} [{r.finish_reason}] "
              f"sample {r.generated[:8]}")
    return snap


def _parse_tiers(items: list[str] | None) -> list:
    """``--tier SPEC=COUNT`` -> TierConfig list (tier name == spec name).

    SPEC is anything :func:`repro.numerics.ladder_spec` resolves — a
    preset name, ``float``, or a spec-JSON path.  Defaults to the
    two-tier deployment the docs describe: an exact-int8 latency tier
    and an approximate bulk tier, two replicas each."""
    from repro.serving import TierConfig

    items = items or ["int8=2", "serve-default=2"]
    tiers = []
    for item in items:
        spec, sep, cnt = item.partition("=")
        if not spec:
            raise SystemExit(f"--tier {item!r}: expected SPEC=COUNT")
        try:
            count = int(cnt) if sep else 1
        except ValueError:
            raise SystemExit(
                f"--tier {item!r}: COUNT must be an integer") from None
        tiers.append(TierConfig(name=spec, spec=spec, count=count))
    return tiers


def run_fleet(args) -> dict:
    """``--fleet``: heterogeneous-numerics replica tiers from ONE float
    init, behind the spec-aware router (repro.serving.fleet).

    Serves the same mixed trace as ``run_engine`` but classed: short
    chat turns are latency-sensitive (exact tiers only), long documents
    are bulk (approximate tiers, spilling into exact ones past
    ``--spill-threshold``).  The run asserts the routing contract —
    every latency request landed on an exact-tier replica — and
    ``--assert-prefix-share`` additionally asserts a cross-replica
    prefix-cache adoption (the CI fleet smoke)."""
    from repro.numerics import ladder_spec
    from repro.serving import build_fleet

    cfg = get_config(args.arch)
    tiers = _parse_tiers(args.tier)
    api = build_model(cfg)
    params_float = api.init(jax.random.PRNGKey(0))

    def pack(spec_name, _p=params_float, _cfg=cfg):
        label, spec = ladder_spec(spec_name)
        if spec is None:
            return _p, label, None
        return (build_serving_params(_p, _cfg, ServeConfig(spec=spec)),
                label, spec)

    ecfg = EngineConfig(slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.chunk,
                        cache_dtype=args.cache_dtype,
                        mixed_batches=not args.no_mixed,
                        kv_layout=args.kv_layout,
                        kv_block_size=args.block_size,
                        kv_blocks=args.kv_blocks,
                        prefix_cache=not args.no_prefix_cache,
                        trace=bool(args.trace_dir))
    fleet = build_fleet(cfg, params_float, tiers, ecfg, pack, api=api,
                        policy=args.route_policy,
                        spill_threshold=args.spill_threshold or None)
    by_id = {r.replica_id: r for r in fleet.replicas}
    print(f"arch={cfg.name} fleet replicas={len(fleet.replicas)} "
          f"policy={fleet.policy} spill_threshold={fleet.spill_threshold} "
          f"layout={ecfg.kv_layout}")
    for rep in fleet.replicas:
        print(f"  replica {rep.replica_id}: numerics={rep.engine.numerics} "
              f"exact={rep.exact}")

    if args.assert_prefix_share:
        # the CI fleet smoke: warm ONE replica of a multi-replica tier,
        # share, then prove a sibling replica serves the same prompt from
        # the imported blocks
        if ecfg.kv_layout != "paged" or not ecfg.prefix_cache:
            raise SystemExit("--assert-prefix-share needs --kv-layout "
                             "paged with the prefix cache enabled")
        pair = next((tuple(reps) for t in tiers
                     for reps in [[r for r in fleet.replicas
                                   if r.tier.name == t.name]]
                     if len(reps) >= 2), None)
        if pair is None:
            raise SystemExit("--assert-prefix-share needs a tier with "
                             ">= 2 replicas")
        warm_rep, cold_rep = pair[0], pair[1]
        rng = np.random.default_rng(17)
        shared = rng.integers(
            0, cfg.vocab,
            min(4 * ecfg.prefill_chunk, ecfg.max_len // 2)).tolist()
        warm_rep.engine.submit(shared, 2)
        warm_rep.engine.drain()
        imported = fleet.share_prefixes()
        hit = cold_rep.engine.submit(
            shared + rng.integers(0, cfg.vocab, 4).tolist(), 4)
        cold_rep.engine.drain()
        shareable = min(len(shared) // ecfg.kv_block_size
                        * ecfg.kv_block_size, len(shared) - 1)
        assert imported > 0, "share_prefixes imported nothing"
        assert hit.prefix_hit_tokens >= shareable, (
            hit.prefix_hit_tokens, shareable)
        print(f"  prefix share: {imported} blocks "
              f"{warm_rep.replica_id} -> fleet; {cold_rep.replica_id} "
              f"hit {hit.prefix_hit_tokens} tokens")

    trace = mixed_trace(cfg, args.requests, ecfg.max_len, ecfg.prefill_chunk)
    share_every = args.share_prefixes_every or None
    placed = []
    for i, (prompt, gen) in enumerate(trace):
        # mixed_trace makes every third request a long document — that is
        # the bulk/background traffic; chat turns are latency-sensitive
        klass = "bulk" if i % 3 == 2 else "latency"
        r = fleet.submit(prompt, gen, priority=0 if klass == "latency"
                         else 1, klass=klass)
        attempt = 0
        while (r.state.value == "rejected"
               and (r.reject_reason or "").startswith("queue full")
               and attempt < args.submit_retries):
            for _ in range(2 ** attempt):
                fleet.step()
            attempt += 1
            r = fleet.submit(prompt, gen, priority=0 if klass == "latency"
                             else 1, klass=klass)
        if r.state.value == "rejected":
            print(f"  request {r.rid} rejected: {r.reject_reason}")
        else:
            placed.append(r)
    finished = fleet.drain(share_every=share_every)

    # the routing contract: latency-class requests only on exact replicas
    for r in placed:
        if r.fleet_class == "latency" and fleet.policy == "spec-aware":
            assert by_id[r.fleet_replica].exact, (
                f"latency request {r.rid} on approximate replica "
                f"{r.fleet_replica}")
    snap = fleet.snapshot()
    print(f"finished {len(finished)}/{len(placed)} placed requests, "
          f"{fleet.compile_count()} compiled shapes across the fleet")
    for tname, ts in snap["tiers"].items():
        print(f"  tier {tname}: numerics={ts['numerics']} "
              f"engines={ts['engines']} finished={ts['requests_finished']} "
              f"gen_tok={ts['generated_tokens']} "
              f"prefix_imports={ts['prefix_imports']}")
    rt = snap["routing"]
    print(f"  routing: {rt['routed_by_class']} spills={rt['spills']}")
    print(json.dumps(snap["fleet"], indent=2))
    if args.trace_dir:
        paths = fleet.write_traces(args.trace_dir)
        print(f"traces: {len(paths)} replica files -> {args.trace_dir}")
    if getattr(args, "prom_out", None):
        from repro.serving.prom import to_openmetrics
        with open(args.prom_out, "w") as f:
            f.write(to_openmetrics(snap["fleet"], labels={"fleet": "all"}))
        print(f"openmetrics: {args.prom_out}")
    return snap


def run_legacy(args) -> None:
    cfg = get_config(args.arch)
    params, label, _, _ = _prepare_params(cfg, args)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} numerics={label}")
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:16].tolist())


def _plan_diff(plan: PackPlan, params, ckpt_path: str) -> int:
    """Compare a resolved plan against the NumericsSpec a checkpoint was
    saved with (its ``numerics`` metadata), re-resolved over the same
    abstract parameters.  Prints per-layer drift rows; returns the number
    of drifted layers (the plan subcommand's exit code), so 0 == the
    checkpoint really will serve under the numerics the CLI describes."""
    from repro.checkpoint.manager import read_meta

    meta = read_meta(ckpt_path)
    nd = (meta or {}).get("numerics")
    if nd is None:
        raise SystemExit(f"{ckpt_path}: checkpoint metadata carries no "
                         "numerics spec (saved before numerics persistence, "
                         "or not via save_pytree/CheckpointManager?)")
    ck_spec = NumericsSpec.from_dict(nd)
    ck_plan = ck_spec.resolve(params)
    mine = {e.path: e.label for e in plan.entries}
    theirs = {e.path: e.label for e in ck_plan.entries}
    drift = [(p, mine.get(p), theirs.get(p))
             for p in sorted(set(mine) | set(theirs))
             if mine.get(p) != theirs.get(p)]
    print(f"checkpoint spec: {ck_spec.name!r} ({ckpt_path})")
    if not drift:
        print(f"plan matches checkpoint: {len(mine)} layers, no drift")
        return 0
    print(f"PLAN DRIFT: {len(drift)} layer(s) differ (cli vs checkpoint)")
    for path, a, b in drift:
        print(f"  {path}: {a or '<absent>'} != {b or '<absent>'}")
    return len(drift)


def run_plan(args) -> PackPlan:
    """`plan` subcommand: resolve and print the per-layer assignment table
    without packing — parameters are abstract (eval_shape), so this is
    instant and allocation-free."""
    cfg = get_config(args.arch)
    api = build_model(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    spec = _spec_from_args(args)
    if spec is None:
        raise SystemExit("nothing to plan for float serving (pick --preset, "
                         "--spec-json, or --mode/--m)")
    plan = spec.resolve(params)
    if args.json:
        print(plan.to_json(indent=2))
    else:
        print(f"arch={cfg.name} spec={spec.name}")
        print(plan.table())
    if getattr(args, "diff_checkpoint", None):
        drifted = _plan_diff(plan, params, args.diff_checkpoint)
        if drifted:
            raise SystemExit(drifted)
    return plan


def _add_numerics_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", default="olmo-1b-reduced")
    ap.add_argument("--mode", default="perforated",
                    choices=["exact", "perforated", "truncated", "recursive", "float"])
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--no-cv", action="store_true")
    ap.add_argument("--preset", default=None,
                    help="named NumericsSpec preset (serve-default, int8, ...)")
    ap.add_argument("--spec-json", default=None, metavar="FILE",
                    help="serve under a NumericsSpec loaded from a JSON file")


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)

    if argv and argv[0] == "plan":
        ap = argparse.ArgumentParser(prog="repro.launch.serve plan")
        _add_numerics_flags(ap)
        ap.add_argument("--json", action="store_true",
                        help="emit the PackPlan as JSON instead of a table")
        ap.add_argument("--diff-checkpoint", default=None, metavar="PATH",
                        help="also resolve the NumericsSpec persisted in "
                             "this checkpoint's metadata and exit nonzero "
                             "if any layer's assignment drifted from the "
                             "CLI spec")
        run_plan(ap.parse_args(argv[1:]))
        return

    ap = argparse.ArgumentParser()
    _add_numerics_flags(ap)
    # engine path (default)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine (default path)")
    ap.add_argument("--legacy", action="store_true",
                    help="old lock-step rectangular batch loop")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "float32", "int8"])
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable mixed prefill+decode batches (fall back "
                         "to whole-batch alternation)")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="KV memory model: contiguous max_len stripes, or "
                         "block-granular paged allocation with prefix reuse")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="usable blocks in the shared pool (0 = capacity "
                         "parity with contiguous)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the content-hash shared-prefix cache")
    ap.add_argument("--shared-prefix-pair", action="store_true",
                    help="prepend a warmed shared-prefix request pair and "
                         "report/assert the prefix hit (CI paged smoke)")
    # speculative decode (repro.serving.speculative)
    ap.add_argument("--speculative-k", type=int, default=0, metavar="K",
                    help="self-verifying speculative decode: draft up to K "
                         "greedy tokens per slot through the approximate "
                         "parameters, verify them in one exact-int8 chunk "
                         "call (0 disables); the numerics flags then "
                         "describe the DRAFT spec")
    ap.add_argument("--draft-spec", default=None, metavar="NAME_OR_FILE",
                    help="draft NumericsSpec: a preset name or a spec-JSON "
                         "file path (default: whatever --mode/--m/--preset "
                         "resolve to)")
    ap.add_argument("--assert-acceptance", action="store_true",
                    help="fail unless the verifier accepted at least one "
                         "draft token (CI speculative smoke)")
    # observability (repro.serving.telemetry / repro.quant.error_probe)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the request-span trace here: *.jsonl for "
                         "JSONL, anything else for Chrome trace_event JSON "
                         "(opens in Perfetto); enables tracing")
    ap.add_argument("--metrics-window", type=float, default=0.0,
                    metavar="SECONDS",
                    help="windowed time-series sample interval "
                         "(0 disables; samples ride the trace as counters)")
    ap.add_argument("--error-probe-every", type=int, default=0, metavar="N",
                    help="every N engine steps re-run one scheduled batch "
                         "row through the exact-int8 path and record "
                         "approx-vs-exact error moments (0 disables)")
    # robustness (repro.serving.governor / repro.quant.faults)
    ap.add_argument("--governor", action="store_true",
                    help="attach the accuracy-SLO numerics governor: the "
                         "error probe's running variance estimate walks the "
                         "degradation ladder (CLI spec -> int8 -> float), "
                         "hot-swapping the live pack on breach and relaxing "
                         "back after clean windows")
    ap.add_argument("--slo-err-var", type=float, default=None, metavar="VAR",
                    help="accuracy SLO: max acceptable running logits "
                         "error variance (approx vs exact; required with "
                         "--governor)")
    ap.add_argument("--governor-window", type=int, default=4,
                    metavar="PROBES",
                    help="probe reports per governor window (count-based, "
                         "deterministic)")
    ap.add_argument("--governor-relax-after", type=int, default=3,
                    metavar="WINDOWS",
                    help="consecutive clean windows before relaxing one "
                         "rung back down")
    ap.add_argument("--layer-slo", action="append", metavar="PATTERN=VAR",
                    help="per-layer accuracy SLO for the governor: fnmatch "
                         "layer-path pattern -> max probe err-var ceiling "
                         "(e.g. 'blocks/0/*=1e-4'); first matching pattern "
                         "wins; repeatable; breaches escalate with reason "
                         "layer_slo_breach naming the layer")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic fault injection, as "
                         "KIND@EVERY[@START-STOP][@LAYERS] with KIND in "
                         "nan|inf|spike|dense-noise (e.g. 'nan@8', "
                         "'dense-noise@2@10-50', "
                         "'dense-noise@1@blocks/0/*'); step-surface kinds "
                         "corrupt served logits and must be fully "
                         "quarantined (asserted), dense-noise corrupts the "
                         "probe's observation and drives the governor — "
                         "the optional fnmatch LAYERS segment confines it "
                         "to matching packed layers")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault injector RNG seed (same seed = same "
                         "injected steps and rows)")
    ap.add_argument("--detect-faults", action="store_true",
                    help="engine-side NaN/divergence detection + "
                         "quarantine even without an injector")
    # observability (repro.serving.shadow / repro.serving.prom)
    ap.add_argument("--shadow-spec", default=None, metavar="NAME_OR_FILE",
                    help="A/B shadow serving: replay a deterministic "
                         "sample of finished requests teacher-forced "
                         "through a second pack built under this spec "
                         "(preset name or spec-JSON path) and diff tokens/"
                         "logits/modeled power; incompatible with "
                         "--speculative-k and --governor")
    ap.add_argument("--shadow-fraction", type=float, default=0.25,
                    metavar="F",
                    help="fraction of finished requests replayed through "
                         "the shadow pack (deterministic every-Nth "
                         "sampling; default %(default)s)")
    ap.add_argument("--assert-shadow", action="store_true",
                    help="fail unless the shadow replayed >= 1 request "
                         "and reached a verdict (CI shadow smoke)")
    ap.add_argument("--assert-layer-breach", nargs="?", const="*",
                    default=None, metavar="PATTERN",
                    help="fail unless the governor escalated with reason "
                         "layer_slo_breach on a layer matching PATTERN "
                         "(default any) AND that layer appears in the "
                         "windowed per-layer err-var samples (CI "
                         "layer-SLO smoke; needs --governor --layer-slo "
                         "--metrics-window)")
    ap.add_argument("--prom-out", default=None, metavar="FILE",
                    help="write the final metrics snapshot (engine, or "
                         "merged fleet with --fleet) as OpenMetrics text "
                         "exposition to FILE")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request latency SLO in ms from submission "
                         "(0 = none); expired queued requests are purged, "
                         "running ones stop with finish_reason 'deadline'")
    ap.add_argument("--submit-retries", type=int, default=3, metavar="N",
                    help="bounded retry budget for queue-full submissions "
                         "(exponential backoff in engine steps: 1, 2, 4 "
                         "... steps drained between attempts)")
    # fleet serving (repro.serving.fleet)
    ap.add_argument("--fleet", action="store_true",
                    help="serve through heterogeneous-numerics replica "
                         "tiers behind the spec-aware router instead of "
                         "one engine; the numerics flags are ignored — "
                         "--tier chooses each tier's spec")
    ap.add_argument("--tier", action="append", metavar="SPEC=COUNT",
                    help="one fleet tier: COUNT replicas packed under "
                         "SPEC (a preset name, 'float', or a spec-JSON "
                         "path); repeatable (default: int8=2 "
                         "serve-default=2)")
    ap.add_argument("--route-policy", default="spec-aware",
                    choices=["spec-aware", "least-loaded", "round-robin"],
                    help="fleet routing policy (spec-aware: latency "
                         "class -> exact tiers, bulk -> approximate "
                         "tiers, least-loaded within each)")
    ap.add_argument("--spill-threshold", type=int, default=0, metavar="N",
                    help="bulk traffic spills from a saturated "
                         "approximate tier into the exact tiers once "
                         "the least-loaded bulk replica has >= N "
                         "pending requests (0 disables; latency "
                         "traffic never spills to approximate tiers)")
    ap.add_argument("--share-prefixes-every", type=int, default=4,
                    metavar="STEPS",
                    help="propagate prefix-cache blocks across each "
                         "tier's replicas every N fleet iterations "
                         "while draining (0 disables)")
    ap.add_argument("--assert-prefix-share", action="store_true",
                    help="warm one replica, share, and assert a sibling "
                         "replica's prefix-cache hit on the imported "
                         "blocks (CI fleet smoke; needs --kv-layout "
                         "paged and a tier with >= 2 replicas)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="fleet tracing: write one JSONL span trace per "
                         "replica into DIR (feed them all to "
                         "tools/trace_report.py --trace ...)")
    # legacy path knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    if args.legacy:
        run_legacy(args)
    elif args.fleet:
        run_fleet(args)
    else:
        run_engine(args)


if __name__ == "__main__":
    main()
