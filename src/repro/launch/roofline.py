"""Roofline-term extraction from compiled (SPMD-partitioned) artifacts.

Hardware model: TPU v5e-class chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  The compiled module is the PER-DEVICE program (XLA SPMD
partitions before optimization), so `cost_analysis()` flops/bytes and the
collective shapes parsed from the optimized HLO are already per-chip:

    compute    = flops / 197e12            seconds
    memory     = bytes_accessed / 819e9    seconds
    collective = collective_bytes / 50e9   seconds

collective_bytes sums, over every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute in the optimized HLO, the larger of the op's
result vs summed-operand bytes (a per-device lower bound on wire traffic; we
report the breakdown per op kind so schedule changes are attributable).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    """Sum bytes of every 'dtype[dims]' shape literal in ``txt``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan optimized HLO for collective ops; bytes = max(result, operands)."""
    bytes_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_shapes, opname = m.groups()
        kind = None
        for k in _COLLECTIVES:
            if opname == k or opname.startswith(k + "-") or opname.startswith(k + "."):
                kind = k
                break
        if kind is None:
            continue
        res_bytes = _shape_bytes(result_shapes)
        # operand shapes appear in the argument list; HLO text usually lists
        # operand names only, so result bytes are our proxy (exact for
        # all-reduce/permute; result >= wire for all-gather; <= for rs).
        bytes_by[kind] += res_bytes
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: dict
    collective_counts: dict
    raw_cost: dict | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "collective_bytes_per_chip": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "raw_cost_analysis": self.raw_cost,
        }


def roofline_from_compiled(compiled) -> RooflineTerms:
    """Trip-count-aware analysis of the optimized per-device HLO.

    `compiled.cost_analysis()` counts while-loop (lax.scan) bodies once —
    useless for scanned layer stacks — so terms come from
    launch.hlo_analysis, which multiplies bodies by inferred trip counts.
    The raw cost_analysis numbers are kept in `raw_cost` for comparison.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))

    hc = analyze_hlo(compiled.as_text())
    terms = RooflineTerms(
        flops=hc.flops,
        bytes_accessed=hc.bytes_accessed,
        collective_bytes=hc.collective_bytes,
        collectives=hc.collectives,
        collective_counts=hc.collective_counts,
    )
    terms.raw_cost = {"flops": raw_flops, "bytes_accessed": raw_bytes,
                      "unknown_trip_counts": hc.unknown_trip_counts}
    return terms


def model_flops_estimate(cfg, shape_kind: str, seq_len: int, batch: int) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N*D per
    generated/processed token for serving, GLOBAL (divide by chips to compare
    with per-chip HLO flops)."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n_active * seq_len * batch
    if shape_kind == "prefill":
        return 2.0 * n_active * seq_len * batch
    return 2.0 * n_active * batch  # decode: one token per sequence
