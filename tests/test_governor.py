"""Robustness layer: accuracy-SLO governor, fault injection, deadlines.

Unit coverage (no model): governor window/ladder arithmetic (escalate on
breach, relax after clean windows, hysteresis, immediate fault
escalation, zero-sample no-ops), ladder resolution ordering, fault-spec
parsing and deterministic row planning, queue deadline purge semantics,
and metrics-merge edge cases (n=0 moments, single-engine exact no-op,
associativity with the new robustness counters).

Integration coverage (reduced model): same-seed fault injection hits the
same steps/rows on the contiguous AND paged layouts; quarantine replay
emits tokens identical to an uninjected run (the no-corrupted-emission
contract); a dense-noise injector drives the governor up the ladder and
the live pack hot-swaps; per-request deadlines purge queued work and
stop running work with finish_reason precedence deadline > length > eos.
"""

import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.numerics import (DEFAULT_LADDER, get_preset, ladder_spec,
                            resolve_ladder)
from repro.quant.faults import (DIVERGENCE_ABS, FaultInjector, FaultSpec,
                                suspect_rows)
from repro.serving import (EngineMetrics, GovernorConfig, NumericsGovernor,
                           Request, RequestQueue, ServingEngine, SlotScheduler)

# ---------------------------------------------------------------------------
# governor units (no model)
# ---------------------------------------------------------------------------


def _rungs(savings=(40.0, 10.0, 0.0)):
    from repro.numerics.ladder import LadderRung

    return [LadderRung(name=f"rung{i}", spec=None, power_saving_pct=s)
            for i, s in enumerate(savings)]


def _probe(n=4, mean=0.0, var=0.0):
    return {"row": 0, "layers": {}, "logits": {"n": n, "mean": mean,
                                               "var": var, "max_abs": 1.0}}


def _cfg(**kw):
    kw.setdefault("slo_err_var", 1.0)
    kw.setdefault("window_probes", 2)
    kw.setdefault("clean_windows_to_relax", 2)
    return GovernorConfig(**kw)


def test_governor_escalates_on_breach():
    gov = NumericsGovernor(_rungs(), _cfg())
    assert gov.observe_probe(_probe(var=9.0)) is None  # window still open
    d = gov.observe_probe(_probe(var=9.0))  # closes window 0: est 9 > 1
    assert d is not None and d.action == "escalate"
    assert d.reason == "slo_breach"
    assert gov.rung.name == "rung1"
    assert gov.first_breach_window == 0
    # the cost-model delta rides every decision: rung1 - rung0 savings
    assert d.power_delta_pct == pytest.approx(-30.0)
    assert d.to_dict()["from"] == "rung0" and d.to_dict()["to"] == "rung1"


def test_governor_relaxes_after_clean_windows():
    gov = NumericsGovernor(_rungs(), _cfg(), start=1)
    decisions = [gov.observe_probe(_probe(var=0.0)) for _ in range(4)]
    # two clean windows (4 probes) -> one relax back down
    assert [d.action for d in decisions if d] == ["relax"]
    assert gov.rung.name == "rung0"
    assert decisions[-1].power_delta_pct == pytest.approx(30.0)


def test_governor_hysteresis_band_resets_clean_count():
    # inside (headroom*slo, slo]: not a breach, but not clean either
    gov = NumericsGovernor(_rungs(), _cfg(relax_headroom=0.25), start=1)
    for _ in range(10):
        assert gov.observe_probe(_probe(var=0.5)) is None
    assert gov.rung.name == "rung1"  # parked: never relaxes in the band


def test_governor_severe_breach_jumps_to_clearing_rung():
    # err-var 9 >= 4*slo: severe.  residual model est*saving_j/saving_cur
    # gives rung1 -> 9*10/40 = 2.25 (still blown), rung2 -> 0: jump 0 -> 2
    gov = NumericsGovernor(_rungs(), _cfg(severe_factor=4.0))
    for _ in range(2):
        d = gov.observe_probe(_probe(var=9.0))
    assert d is not None and d.action == "escalate"
    assert d.reason == "slo_breach"
    assert gov.rung.name == "rung2"  # skipped rung1 entirely
    assert d.power_delta_pct == pytest.approx(-40.0)


def test_governor_severe_breach_stops_at_first_clearing_rung():
    # severe, but rung1's modeled residual 3.9*10/40 = 0.975 <= slo:
    # the jump lands there, not at the ladder bottom
    gov = NumericsGovernor(_rungs(), _cfg(severe_factor=3.0))
    for _ in range(2):
        d = gov.observe_probe(_probe(var=3.9))
    assert d is not None and gov.rung.name == "rung1"


def test_governor_non_severe_breach_still_walks_one_rung():
    # a plain breach under the severe threshold keeps the one-rung walk
    gov = NumericsGovernor(_rungs(), _cfg(severe_factor=4.0))
    for _ in range(2):
        d = gov.observe_probe(_probe(var=2.0))
    assert d is not None and d.action == "escalate"
    assert gov.rung.name == "rung1"


def test_governor_severe_factor_validation():
    with pytest.raises(ValueError, match="severe_factor"):
        _cfg(severe_factor=0.5)


def test_governor_severe_fault_path_unchanged():
    # note_fault carries no err-var estimate, so the severe jump cannot
    # apply — faults keep the one-rung escalation
    gov = NumericsGovernor(_rungs(), _cfg(severe_factor=2.0))
    d = gov.note_fault()
    assert d.action == "escalate" and d.err_var is None
    assert gov.rung.name == "rung1"


def test_governor_fault_escalates_immediately():
    gov = NumericsGovernor(_rungs(), _cfg())
    gov.observe_probe(_probe(var=0.0))  # open window discards on switch
    d = gov.note_fault()
    assert d.action == "escalate" and d.reason == "fault"
    assert d.err_var is None
    assert gov.first_breach_window == 0
    # at the top of the ladder note_fault is a recorded no-op
    gov.note_fault()
    assert gov.rung.name == "rung2"
    assert gov.note_fault() is None


def test_governor_zero_sample_probes_are_noops():
    gov = NumericsGovernor(_rungs(), _cfg())
    assert gov.observe_probe(None) is None
    assert gov.observe_probe({}) is None
    assert gov.observe_probe({"logits": None}) is None
    assert gov.observe_probe(_probe(n=0, var=99.0)) is None
    assert gov.err_var_estimate is None
    assert gov._win_probes == 0  # nothing folded, window untouched


def test_governor_estimate_chan_merges_windows():
    gov = NumericsGovernor(_rungs(), _cfg(window_probes=1,
                                          slo_err_var=100.0))
    rng = np.random.default_rng(0)
    chunks = [rng.normal(size=8) for _ in range(3)]
    for c in chunks:
        gov.observe_probe(_probe(n=len(c), mean=float(np.mean(c)),
                                 var=float(np.var(c))))
    pooled = np.concatenate(chunks)
    assert gov.err_var_estimate == pytest.approx(float(np.var(pooled)))


def test_governor_history_resets_on_switch():
    gov = NumericsGovernor(_rungs(), _cfg())
    for _ in range(2):
        gov.observe_probe(_probe(var=9.0))
    assert gov.rung.name == "rung1"
    # the breach window must not leak into the new rung's estimate
    assert gov.err_var_estimate is None


def test_governor_validation():
    with pytest.raises(ValueError):
        GovernorConfig(slo_err_var=0.0)
    with pytest.raises(ValueError):
        GovernorConfig(slo_err_var=1.0, window_probes=0)
    with pytest.raises(ValueError):
        GovernorConfig(slo_err_var=1.0, relax_headroom=1.5)
    with pytest.raises(ValueError):
        NumericsGovernor(_rungs()[:1], _cfg())
    with pytest.raises(ValueError):
        NumericsGovernor(_rungs(), _cfg(), start=3)


# ---------------------------------------------------------------------------
# ladder resolution + fault-spec units (shapes only / no model)
# ---------------------------------------------------------------------------


def test_ladder_resolution_orders_most_approximate_first():
    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    ladder = resolve_ladder(DEFAULT_LADDER, params)
    assert [r.name for r in ladder][-1] == "float"
    savings = [r.power_saving_pct for r in ladder]
    assert savings == sorted(savings, reverse=True)
    assert savings[0] > 0.0 and savings[-1] == 0.0
    # a ladder that RAISES savings along escalation is a config bug
    with pytest.raises(ValueError):
        resolve_ladder(["float", "serve-default"], params)
    with pytest.raises(ValueError):
        resolve_ladder(["int8"], params)
    assert ladder_spec("float") == ("float", None)
    name, spec = ladder_spec("int8")
    assert name == "int8" and spec.name == "int8"


def test_fault_spec_parse_and_validation():
    s = FaultSpec.parse("nan@8")
    assert s.kind == "nan" and s.every == 8 and s.stop is None
    s = FaultSpec.parse("dense-noise@2@10-50", seed=5)
    assert (s.kind, s.every, s.start, s.stop, s.seed) == (
        "dense-noise", 2, 10, 50, 5)
    assert s.surface == "dense"
    assert FaultSpec.parse("spike@4").surface == "step"
    with pytest.raises(ValueError):
        FaultSpec.parse("bogus@2")
    with pytest.raises(ValueError):
        FaultSpec(kind="nan", every=0)


def test_fault_injector_plan_rows_deterministic():
    spec = FaultSpec(kind="nan", every=4, rows=2, seed=11)
    a, b = FaultInjector(spec), FaultInjector(spec)
    live = [0, 1, 2, 3, 5]
    # row choice depends on (seed, step) and the SET of live rows only —
    # not on arrival order, so contiguous/paged scheduling agree
    for step in range(0, 32, 4):
        assert a.plan_rows(step, live) == b.plan_rows(step, live[::-1])
    assert not a.fires(1) and a.fires(4)
    c = FaultInjector(FaultSpec(kind="nan", every=4, rows=2, seed=12))
    assert any(a.plan_rows(s, live) != c.plan_rows(s, live)
               for s in range(0, 32, 4))


def test_corrupt_logits_kinds_and_suspect_rows():
    inj = FaultInjector(FaultSpec(kind="nan", every=1, seed=0))
    logits = np.zeros((4, 2, 8), np.float32)
    out = inj.corrupt_logits(0, logits, [1, 3])
    assert np.isnan(out[1]).any() and np.isnan(out[3]).any()
    assert np.isfinite(out[0]).all() and np.isfinite(out[2]).all()
    assert not np.isnan(logits).any()  # input untouched (copy semantics)
    spiked = FaultInjector(FaultSpec(kind="spike", every=1, scale=1e4)) \
        .corrupt_logits(0, logits, [2])
    cols = np.stack([out[:, -1], spiked[:, -1]])  # (2, slots, vocab)
    assert suspect_rows(cols[0]).tolist() == [False, True, False, True]
    assert suspect_rows(cols[1]).tolist() == [False, False, True, False]
    assert suspect_rows(np.full((1, 4), DIVERGENCE_ABS / 2)).tolist() == \
        [False]


# ---------------------------------------------------------------------------
# deadline units (no model)
# ---------------------------------------------------------------------------


def _req(rid, deadline_ms=None, priority=0):
    return Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=4,
                   priority=priority, deadline_ms=deadline_ms)


def test_queue_purge_preserves_survivor_order():
    q = RequestQueue()
    reqs = [_req(0), _req(1, priority=1), _req(2), _req(3, priority=1)]
    for r in reqs:
        q.push(r)
    gone = q.purge(lambda r: r.rid in (1, 2))
    assert [r.rid for r in gone] == [1, 2]
    assert q.pop().rid == 0 and q.pop().rid == 3  # (priority, FIFO) kept
    assert q.purge(lambda r: False) == []


def test_scheduler_purges_expired_queued_requests():
    q = RequestQueue()
    live = _req(0)
    dead = _req(1, deadline_ms=1.0)
    dead.t_submit = time.time() - 1.0  # blew its 1ms budget long ago
    for r in (live, dead):
        q.push(r)
    m = EngineMetrics()
    sched = SlotScheduler(slots=2, prefill_chunk=4)
    expired = sched.purge_expired(q, m)
    assert [r.rid for r in expired] == [1]
    assert expired[0].finished and expired[0].finish_reason == "deadline"
    assert m.requests_deadline_expired == 1
    assert len(q) == 1 and q.peek().rid == 0


def test_deadline_expiry_predicate():
    r = _req(0)
    assert not r.deadline_expired  # no deadline = never expires
    r = _req(0, deadline_ms=1e7)
    assert not r.deadline_expired
    r = _req(0, deadline_ms=0.5)
    r.t_submit = time.time() - 1.0
    assert r.deadline_expired


# ---------------------------------------------------------------------------
# metrics merge edge cases (no model)
# ---------------------------------------------------------------------------


def test_merge_single_engine_is_exact_noop():
    m = EngineMetrics(numerics="int8")
    m.start_clock()
    m.record_step("decode", 0.625, 3, generated_tokens=1)
    m.ttfts.push(0.123456789)
    m.governor_switches = 2
    m.governor_escalations = 1
    m.governor_relaxes = 1
    m.faults_injected = 5
    m.faults_detected = 5
    m.quarantines = 5
    m.quarantine_replays = 5
    m.requests_retried = 3
    m.requests_deadline_expired = 1
    snap = m.snapshot()
    merged = EngineMetrics.merge([snap])
    for k, v in snap.items():
        # rates recompute from the rounded elapsed_s by design; everything
        # else — counters AND weighted means — must pass through EXACTLY
        if k in merged and not k.endswith("_per_s"):
            assert merged[k] == v, k


def test_merge_zero_sample_window_is_noop():
    # an engine that served nothing must not perturb the fleet estimate
    idle = EngineMetrics(numerics="int8").snapshot()
    busy = EngineMetrics(numerics="int8")
    busy.start_clock()
    for _ in range(10):
        busy.record_step("decode", 0.5, 1, generated_tokens=1)
        busy.itls.push(0.002)
    bs = busy.snapshot()
    merged = EngineMetrics.merge([bs, idle])
    assert merged["itl_p50_s"] == bs["itl_p50_s"]  # exact pass-through
    assert merged["mean_slot_occupancy"] == bs["mean_slot_occupancy"]
    assert merged["generated_tokens"] == bs["generated_tokens"]
    # Chan n=0 identity at the moments level too
    from repro.serving.metrics import _merge_moments

    stat = (37, 1.5, 0.25)
    assert _merge_moments(stat, (0, 0.0, 0.0)) == stat
    assert _merge_moments((0, 0.0, 0.0), stat) == stat


def test_merge_associativity_with_robustness_counters():
    def snap(seed):
        rng = np.random.default_rng(seed)
        m = EngineMetrics(numerics="int8")
        m.start_clock()
        for _ in range(20):
            m.record_step("decode", float(rng.random()), 1,
                          generated_tokens=1)
            m.itls.push(float(rng.random() * 0.01))
        m.governor_switches = int(rng.integers(0, 5))
        m.governor_escalations = int(rng.integers(0, 3))
        m.faults_injected = int(rng.integers(0, 9))
        m.faults_detected = m.faults_injected
        m.quarantines = m.faults_injected
        m.quarantine_replays = m.faults_injected
        m.requests_retried = int(rng.integers(0, 4))
        m.requests_deadline_expired = int(rng.integers(0, 2))
        return m.snapshot()

    a, b, c = snap(1), snap(2), snap(3)
    left = EngineMetrics.merge([EngineMetrics.merge([a, b]), c])
    flat = EngineMetrics.merge([a, b, c])
    for k in ("governor_switches", "governor_escalations", "faults_injected",
              "faults_detected", "quarantines", "quarantine_replays",
              "requests_retried", "requests_deadline_expired"):
        assert left[k] == flat[k] == a[k] + b[k] + c[k], k
    assert left["itl_p50_s"] == pytest.approx(flat["itl_p50_s"], rel=1e-9)


# ---------------------------------------------------------------------------
# engine integration (reduced model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def packed_model():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    int8 = build_serving_params(params, cfg,
                                ServeConfig(spec=get_preset("int8")))
    return cfg, params, int8


def _trace(vocab, n=3, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, int(rng.integers(4, 12))).tolist(), 6)
            for _ in range(n)]


def _ecfg(layout="contiguous", **kw):
    return EngineConfig(slots=2, max_len=48, prefill_chunk=8,
                        cache_dtype="float32", kv_layout=layout,
                        kv_block_size=8, **kw)


def _serve(cfg, params, trace, layout="contiguous", injector=None, **kw):
    eng = ServingEngine(cfg, params, _ecfg(layout), numerics="int8",
                        fault_injector=injector, **kw)
    reqs = [eng.submit(p, g) for p, g in trace]
    eng.run()
    assert all(r.finished for r in reqs)
    return eng, [r.generated for r in reqs]


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_quarantine_replay_token_identity(packed_model, layout):
    cfg, _, int8 = packed_model
    trace = _trace(cfg.vocab)
    _, clean = _serve(cfg, int8, trace, layout)
    inj = FaultInjector(FaultSpec(kind="nan", every=3, rows=1, seed=7))
    eng, injected = _serve(cfg, int8, trace, layout, injector=inj)
    m = eng.metrics
    assert m.faults_injected > 0
    assert m.faults_detected == m.faults_injected
    assert m.quarantine_replays == m.faults_detected
    assert len(eng.quarantine_log) == m.quarantines
    # the contract: every corrupted row replayed exact BEFORE emission
    assert injected == clean
    assert all(0 <= t < cfg.vocab for toks in injected for t in toks)


def test_fault_injection_deterministic_across_layouts(packed_model):
    cfg, _, int8 = packed_model
    trace = _trace(cfg.vocab)
    logs = []
    for layout in ("contiguous", "paged"):
        inj = FaultInjector(FaultSpec(kind="nan", every=3, rows=1, seed=7))
        _serve(cfg, int8, trace, layout, injector=inj)
        logs.append(list(inj.log))
    assert logs[0] == logs[1] and logs[0]  # same steps, same rows


def test_governor_escalates_and_hotswaps_pack(packed_model):
    cfg, params, int8 = packed_model
    spec = get_preset("serve-default")
    approx = build_serving_params(params, cfg, ServeConfig(spec=spec))
    gov = NumericsGovernor(
        resolve_ladder([spec, "int8", "float"], params),
        GovernorConfig(slo_err_var=1e-6, window_probes=2))
    built = []

    def pack_fn(s):
        built.append("float" if s is None else s.name)
        if s is None:
            return params
        return int8 if s.name == "int8" else build_serving_params(
            params, cfg, ServeConfig(spec=s))

    inj = FaultInjector(FaultSpec(kind="dense-noise", every=1, seed=3,
                                  scale=5.0))
    eng = ServingEngine(cfg, approx, _ecfg(error_probe_every=1, trace=True),
                        numerics=spec.name, governor=gov, pack_fn=pack_fn,
                        fault_injector=inj, exact_params=int8)
    for p, g in _trace(cfg.vocab):
        eng.submit(p, g)
    eng.run()
    assert eng.metrics.governor_escalations >= 1
    assert eng.numerics != spec.name  # the live pack really swapped
    assert built  # ...through pack_fn
    assert eng.metrics.faults_injected > 0  # dense hook armed on probes
    kinds = {e.kind for e in eng.tracer.events()}
    assert "governor_switch" in kinds
    sw = [e for e in eng.tracer.events() if e.kind == "governor_switch"]
    assert all("power_delta_pct" in e.data for e in sw)


def test_governor_requires_probe_and_pack_fn(packed_model):
    cfg, params, int8 = packed_model
    gov = NumericsGovernor(_rungs(), _cfg())
    with pytest.raises(ValueError, match="pack_fn"):
        ServingEngine(cfg, int8, _ecfg(error_probe_every=1), governor=gov)
    with pytest.raises(ValueError, match="error_probe_every"):
        ServingEngine(cfg, int8, _ecfg(), governor=gov,
                      pack_fn=lambda s: int8)


def test_engine_deadline_queued_and_running(packed_model):
    cfg, _, int8 = packed_model
    eng = ServingEngine(cfg, int8, _ecfg(), numerics="int8")
    # fill both slots with undeadlined work, queue one with a blown budget
    r1 = eng.submit([1, 2, 3, 4], 6)
    r2 = eng.submit([5, 6, 7, 8], 6)
    dead = eng.submit([9, 10, 11], 6, deadline_ms=0.01)
    time.sleep(0.002)
    finished = eng.run()
    assert dead in finished
    assert dead.finish_reason == "deadline" and not dead.generated
    assert r1.finish_reason == "length" and r2.finish_reason == "length"
    assert eng.metrics.requests_deadline_expired == 1

    # a RUNNING request stops at its first emission past the budget, and
    # deadline takes precedence over a simultaneous eos coincidence
    eng2 = ServingEngine(cfg, int8, _ecfg(), numerics="int8")
    r = eng2.submit(list(range(1, 9)), 40, deadline_ms=1.0,
                    eos_id=0)
    t0 = time.time()
    while not r.finished and time.time() - t0 < 30:
        eng2.step()
    assert r.finish_reason == "deadline"
    assert len(r.generated) < 40  # partial output kept
