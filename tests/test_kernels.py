"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU; TPU target).

Per the deliverable: sweep shapes/dtypes/modes and assert_allclose against
the ref.py oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("mode,m", [
    ("exact", 0), ("perforated", 1), ("perforated", 3),
    ("recursive", 2), ("recursive", 4), ("truncated", 5), ("truncated", 7),
])
@pytest.mark.parametrize("shape", [(8, 32, 16), (64, 200, 48), (128, 512, 128)])
def test_approx_matmul_kernel_vs_ref(mode, m, shape):
    mm, kk, nn = shape
    a_q = RNG.integers(0, 256, (mm, kk)).astype(np.uint8)
    w_q = RNG.integers(0, 256, (kk, nn)).astype(np.uint8)
    c = RNG.normal(100, 30, (nn,)).astype(np.float32)
    c0 = RNG.normal(0, 10, (nn,)).astype(np.float32)
    sqw = np.asarray(w_q, np.int64).sum(0).astype(np.int32)
    bias = RNG.normal(0, 1, (nn,)).astype(np.float32)
    args = (a_q, w_q, c, c0, sqw, bias, 0.015, 0.02, 7.0, 131.0)
    out_k = np.asarray(ops.approx_matmul_cv_op(*args, mode=mode, m=m, interpret=True))
    out_r = np.asarray(ref.approx_matmul_cv_ref(*args, mode=mode, m=m))
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-3)


@pytest.mark.parametrize("use_cv", [True, False])
def test_approx_matmul_kernel_cv_flag(use_cv):
    a_q = RNG.integers(0, 256, (16, 64)).astype(np.uint8)
    w_q = RNG.integers(0, 256, (64, 16)).astype(np.uint8)
    c = RNG.normal(50, 10, (16,)).astype(np.float32)
    c0 = np.zeros(16, np.float32)
    sqw = np.asarray(w_q, np.int64).sum(0).astype(np.int32)
    bias = np.zeros(16, np.float32)
    args = (a_q, w_q, c, c0, sqw, bias, 0.01, 0.01, 0.0, 0.0)
    k = np.asarray(ops.approx_matmul_cv_op(*args, mode="perforated", m=2,
                                           use_cv=use_cv, interpret=True))
    r = np.asarray(ref.approx_matmul_cv_ref(*args, mode="perforated", m=2,
                                            use_cv=use_cv))
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-4)


def test_approx_matmul_batched_leading_dims():
    a_q = RNG.integers(0, 256, (3, 5, 40)).astype(np.uint8)
    w_q = RNG.integers(0, 256, (40, 24)).astype(np.uint8)
    c = RNG.normal(0, 5, (24,)).astype(np.float32)
    c0 = np.zeros(24, np.float32)
    sqw = np.asarray(w_q, np.int64).sum(0).astype(np.int32)
    bias = np.zeros(24, np.float32)
    args = (a_q.reshape(-1, 40), w_q, c, c0, sqw, bias, 0.01, 0.02, 1.0, 2.0)
    flat = np.asarray(ref.approx_matmul_cv_ref(*args, mode="recursive", m=3))
    out = np.asarray(ops.approx_matmul_cv_op(
        a_q, w_q, c, c0, sqw, bias, 0.01, 0.02, 1.0, 2.0,
        mode="recursive", m=3, interpret=True))
    np.testing.assert_allclose(out.reshape(-1, 24), flat, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("t,dk,dv", [(64, 64, 64), (96, 32, 32)])
def test_rwkv6_scan_vs_sequential(t, dk, dv):
    b, h = 2, 2
    r = RNG.normal(0, 1, (b, t, h, dk)).astype(np.float32)
    k = RNG.normal(0, 1, (b, t, h, dk)).astype(np.float32)
    v = RNG.normal(0, 1, (b, t, h, dv)).astype(np.float32)
    w = np.clip(np.exp(-np.exp(RNG.normal(-1, 1.5, (b, t, h, dk)))),
                np.exp(-8.0), 0.9999).astype(np.float32)
    u = RNG.normal(0, 0.5, (h, dk)).astype(np.float32)
    out_k = np.asarray(rwkv6_scan(r, k, v, w, u, chunk=32, interpret=True))
    out_r, _ = ref.rwkv6_scan_ref(
        jnp.asarray(r), jnp.asarray(k), jnp.asarray(v), jnp.asarray(w),
        jnp.asarray(u), jnp.zeros((b, h, dk, dv)))
    np.testing.assert_allclose(out_k, np.asarray(out_r), rtol=2e-4, atol=5e-4)


@pytest.mark.parametrize("causal,window,hq,hkv", [
    (True, None, 4, 4), (True, None, 8, 2), (False, None, 4, 4),
    (True, 64, 4, 2),
])
def test_flash_attention_vs_ref(causal, window, hq, hkv):
    b, t, d = 2, 128, 32
    q = RNG.normal(0, 1, (b, hq, t, d)).astype(np.float32)
    k = RNG.normal(0, 1, (b, hkv, t, d)).astype(np.float32)
    v = RNG.normal(0, 1, (b, hkv, t, d)).astype(np.float32)
    out_k = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, bq=64, bk=64, interpret=True))
    out_r = np.asarray(ref.flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window))
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_shape():
    # tq < tk (chunked decode): rows aligned to the end of the kv axis
    b, hq, hkv, tq, tk, d = 1, 4, 2, 64, 256, 64
    q = RNG.normal(0, 1, (b, hq, tq, d)).astype(np.float32)
    k = RNG.normal(0, 1, (b, hkv, tk, d)).astype(np.float32)
    v = RNG.normal(0, 1, (b, hkv, tk, d)).astype(np.float32)
    out_k = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                       causal=True, bq=64, bk=64, interpret=True))
    out_r = np.asarray(ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                               jnp.asarray(v), causal=True))
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)
