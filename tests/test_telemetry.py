"""Telemetry layer: span tracing, windowed metrics, fleet merge, and the
approximation-error probe.

Unit coverage (no model): percentile interpolation, reservoir sampling,
tracer ring-buffer eviction, Chrome-trace schema, merge() associativity.
Integration coverage (reduced model): a traced engine run emits ordered,
monotonic lifecycle spans plus windowed samples, and the error probe
reports ~0 error under exact-int8 but strictly larger error for
perforated-m2 without the control variate than with it — the paper's
CV claim, observable from the serving path.
"""

import dataclasses
import json
import math
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.core.policy import ApproxPolicy
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.serving import EngineMetrics, ServingEngine, SpanTracer
from repro.serving.metrics import Reservoir, _merge_moments, _percentile
from repro.serving.telemetry import LIFECYCLE_KINDS

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_report  # noqa: E402

# ---------------------------------------------------------------------------
# metrics units (no model)
# ---------------------------------------------------------------------------


def test_percentile_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    # numpy's default (linear) method is the contract
    for q in (0.0, 0.25, 0.5, 0.733, 0.95, 1.0):
        assert _percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q * 100)))
    assert _percentile([5.0], 0.5) == 5.0
    assert _percentile([], 0.5) == 0.0


def test_reservoir_exact_stats_under_cap():
    r = Reservoir(cap=8)
    for x in [3.0, 1.0, 4.0, 1.0, 5.0]:
        r.push(x)
    assert len(r) == 5 and r.capped == 0
    assert r.mean == pytest.approx(2.8)
    assert r.max == 5.0
    assert r.percentile(1.0) == 5.0


def test_reservoir_caps_but_keeps_exact_moments():
    r = Reservoir(cap=16)
    xs = [float(i) for i in range(1000)]
    for x in xs:
        r.push(x)
    # sample bounded, but n/mean/max stay exact over the full stream
    assert len(r) == 1000 and r.n == 1000
    assert len(r.samples) == 16 and r.capped == 984
    assert r.mean == pytest.approx(np.mean(xs))
    assert r.max == 999.0
    # the retained sample is a uniform draw: its median should land
    # well inside the stream's bulk, not at an extreme
    assert 100.0 < r.percentile(0.5) < 900.0


def test_merge_moments_matches_pooled():
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=100), rng.normal(loc=2.0, size=37)
    stat = lambda x: (len(x), float(np.mean(x)), float(np.var(x)))
    n, mean, var = _merge_moments(stat(a), stat(b))
    pooled = np.concatenate([a, b])
    assert n == len(pooled)
    assert mean == pytest.approx(float(np.mean(pooled)))
    assert var == pytest.approx(float(np.var(pooled)))


def _fake_metrics(seed, steps=50, numerics="serve-default"):
    rng = np.random.default_rng(seed)
    m = EngineMetrics(numerics=numerics)
    m.start_clock()
    m.prompt_tokens = int(rng.integers(100, 1000))
    m.generated_tokens = int(rng.integers(100, 1000))
    m.finished = int(rng.integers(1, 20))
    for _ in range(steps):
        m.record_step("decode", float(rng.random()), int(rng.integers(0, 5)),
                      generated_tokens=1)
        m.ttfts.push(float(rng.random()))
        m.itls.push(float(rng.random() * 0.01))
        m.latencies.push(float(rng.random() * 2))
    m.record_probe({"layers": {"blocks/0/q": {"n": 4, "mean": 0.1 * seed,
                                              "var": 0.01 * (seed + 1)}},
                    "logits": {"n": 4, "mean": 0.2, "var": 0.02}})
    return m.snapshot()


def test_merge_is_associative():
    a, b, c = _fake_metrics(1), _fake_metrics(2), _fake_metrics(3)
    left = EngineMetrics.merge([EngineMetrics.merge([a, b]), c])
    right = EngineMetrics.merge([a, EngineMetrics.merge([b, c])])
    flat = EngineMetrics.merge([a, b, c])
    assert left["engines"] == right["engines"] == flat["engines"] == 3
    for key in ("requests_finished", "generated_tokens", "ttft_samples",
                "step_samples"):
        assert left[key] == right[key] == flat[key]
    for key in ("elapsed_s", "ttft_mean_s", "itl_p50_s",
                "mean_slot_occupancy", "gen_tok_per_s"):
        assert left[key] == pytest.approx(right[key], rel=1e-9)
        assert left[key] == pytest.approx(flat[key], rel=1e-9)
    for m in (left, right, flat):
        p = m["error_probe"]
        assert p["runs"] == 3 and p["logits_err_n"] == 12
        assert p["layers"]["blocks/0/q"]["n"] == 12
    assert left["error_probe"]["logits_err_var"] == pytest.approx(
        right["error_probe"]["logits_err_var"], rel=1e-9)


def test_merge_mixed_numerics_flagged():
    a = _fake_metrics(1, numerics="int8")
    b = _fake_metrics(2, numerics="serve-default")
    merged = EngineMetrics.merge([a, b])
    assert merged["numerics"] == "mixed"
    assert EngineMetrics.merge([a])["numerics"] == "int8"


# ---------------------------------------------------------------------------
# span tracer units
# ---------------------------------------------------------------------------


def test_tracer_rejects_unknown_kind():
    tr = SpanTracer(capacity=4)
    with pytest.raises(ValueError, match="unknown span kind"):
        tr.record("not-a-kind")


def test_tracer_ring_eviction():
    tr = SpanTracer(capacity=8)
    for i in range(20):
        tr.record("decode_step", rid=i)
    assert len(tr) == 8 and tr.dropped == 12
    # oldest evicted first: the survivors are the 8 newest
    assert [e.rid for e in tr.events()] == list(range(12, 20))
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 12
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_chrome_trace_schema():
    tr = SpanTracer(capacity=64, engine="eng0")
    tr.record("queued", rid=3, prompt_len=7)
    tr.record("prefill_chunk", rid=3, dur=0.004, n_valid=7)
    tr.record("metrics_window", gen_tok_per_s=123.4, numerics="int8",
              steps=9)
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and doc["displayTimeUnit"] == "ms"
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] != "M":
            assert isinstance(e["ts"], float) and e["ts"] >= 0
    by_name = {e["name"]: e for e in evs if e["ph"] != "M"}
    assert by_name["queued"]["ph"] == "i"
    assert by_name["queued"]["tid"] == 4  # rid + 1
    assert by_name["queued"]["args"]["rid"] == 3
    assert by_name["prefill_chunk"]["ph"] == "X"
    assert by_name["prefill_chunk"]["dur"] == pytest.approx(4000, rel=1e-3)
    # counter events keep only numeric args (Perfetto plots them)
    cnt = by_name["metrics_window"]
    assert cnt["ph"] == "C" and cnt["tid"] == 0
    assert "numerics" not in cnt["args"] and cnt["args"]["steps"] == 9
    json.dumps(doc)  # must be serializable as-is


def test_write_and_report_loader_roundtrip(tmp_path):
    tr = SpanTracer(capacity=64, engine="eng0")
    tr.record("queued", rid=0, prompt_len=5)
    tr.record("admitted", rid=0, slot=1, queue_wait_s=0.001)
    tr.record("prefill_chunk", rid=0, dur=0.002, n_valid=5)
    tr.record("decode_step", rid=0, dur=0.001)
    tr.record("finished", rid=0, reason="length", generated=1)
    chrome, jsonl = tmp_path / "t.json", tmp_path / "t.jsonl"
    tr.write(str(chrome))
    tr.write(str(jsonl))
    ea = trace_report.load_events(str(chrome))
    eb = trace_report.load_events(str(jsonl))
    assert [e["kind"] for e in ea] == [e["kind"] for e in eb]
    assert all(e["rid"] == 0 for e in ea)
    for x, y in zip(ea, eb):
        assert x["t"] == pytest.approx(y["t"], abs=1e-5)
        assert x["dur"] == pytest.approx(y["dur"], abs=1e-5)
    rep = trace_report.report(ea)
    assert rep["requests"][0]["finish_reason"] == "length"
    assert rep["requests"][0]["prefill_chunks"] == 1
    assert not [k for k in trace_report.LIFECYCLE if not rep["kinds"].get(k)]


# ---------------------------------------------------------------------------
# engine integration (reduced model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _requests(vocab, n=4, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, 20).tolist(), 8) for _ in range(n)]


def test_traced_engine_lifecycle_spans(model_and_params, tmp_path):
    cfg, _, params = model_and_params
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=3, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32", trace=True,
                                     metrics_window_s=0.02))
    for p, g in _requests(cfg.vocab, n=5):
        eng.submit(p, g)
    eng.run()

    events = eng.tracer.events()
    kinds = {e.kind for e in events}
    assert set(LIFECYCLE_KINDS) <= kinds

    # per-request lifecycle ordering on the shared monotonic clock
    for rid in {e.rid for e in events if e.rid is not None}:
        t = {k: [e.t for e in events if e.rid == rid and e.kind == k]
             for k in LIFECYCLE_KINDS}
        if not t["finished"]:
            continue
        assert t["queued"][0] <= t["admitted"][0]
        assert t["admitted"][0] <= min(t["prefill_chunk"])
        assert min(t["prefill_chunk"]) <= t["finished"][0]
        if t["decode_step"]:
            assert min(t["prefill_chunk"]) <= min(t["decode_step"])
    # export timestamps are monotone non-decreasing per export order
    ts = [e["ts"] for e in eng.tracer.chrome_trace()["traceEvents"]
          if e["ph"] != "M"]
    assert ts == sorted(ts)

    # windowed samples rolled and bridged into the trace
    snap = eng.metrics.snapshot()
    assert snap["metrics_window_s"] == 0.02
    assert snap["timeseries_samples"] == len(eng.metrics.timeseries)
    if snap["timeseries_samples"]:
        sample = eng.metrics.timeseries[0]
        assert {"t", "dur_s", "gen_tok_per_s", "steps"} <= set(sample)
        assert "metrics_window" in kinds

    # the report tool accepts the written trace and finds all stages
    out = tmp_path / "trace.json"
    eng.tracer.write(str(out))
    assert trace_report.main([str(out), "--assert-lifecycle"]) == 0


@pytest.mark.parametrize("fmt", ["json", "jsonl"])
def test_trace_report_formats_on_engine_trace(model_and_params, tmp_path,
                                              fmt, capsys):
    cfg, _, params = model_and_params
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32", trace=True))
    for p, g in _requests(cfg.vocab, n=2):
        eng.submit(p, g)
    eng.run()
    out = tmp_path / f"trace.{fmt}"
    eng.tracer.write(str(out))
    assert trace_report.main([str(out), "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["events"] == len(eng.tracer)
    assert len(rep["requests"]) == 2


def _probe_logits_var(cfg, params, policy):
    qparams = build_serving_params(params, cfg, ServeConfig(policy=policy))
    eng = ServingEngine(cfg, qparams,
                        EngineConfig(slots=3, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32",
                                     error_probe_every=1))
    for p, g in _requests(cfg.vocab, n=3):
        eng.submit(p, g)
    eng.run()
    probe = eng.metrics.snapshot()["error_probe"]
    assert probe is not None and probe["runs"] > 0
    assert probe["layers"], "probe must record per-layer moments"
    return probe


def test_probe_exact_int8_error_is_zero(model_and_params):
    """quantized_linear in exact mode IS the integer reference, so the
    probe's approximate-vs-exact delta must be numerically nil."""
    cfg, _, params = model_and_params
    probe = _probe_logits_var(cfg, params, ApproxPolicy("exact", 0))
    # the only residual is float dequant accumulation order between the
    # fused serving path and the eager reference — orders of magnitude
    # below any perforation error (compare ~1e-3 in the CV test below)
    assert probe["logits_err_var"] == pytest.approx(0.0, abs=1e-6)
    assert probe["mean_layer_err_var"] == pytest.approx(0.0, abs=1e-6)


def test_probe_cv_reduces_perforation_error(model_and_params):
    """The paper's claim, measured in-engine: perforated multipliers
    without the control variate show strictly larger per-layer and
    logits error variance than with it."""
    cfg, _, params = model_and_params
    with_cv = _probe_logits_var(
        cfg, params, ApproxPolicy("perforated", 2, use_cv=True))
    no_cv = _probe_logits_var(
        cfg, params, ApproxPolicy("perforated", 2, use_cv=False))
    assert with_cv["logits_err_var"] > 0
    assert no_cv["logits_err_var"] > with_cv["logits_err_var"]
    assert no_cv["mean_layer_err_var"] > with_cv["mean_layer_err_var"]
    for p in (with_cv, no_cv):
        assert all(math.isfinite(st["err_var"])
                   for st in p["layers"].values())
