"""Minimal stand-in for the ``hypothesis`` API used by this suite.

The container image does not always ship the ``hypothesis`` wheel, and the
tier-1 suite must not lose the property tests when it is absent.  This
module implements the tiny subset the tests use (``given``, ``settings``,
``st.floats`` / ``st.integers`` / ``st.sampled_from``) as a deterministic
mini property runner: each ``@given`` test runs ``max_examples`` draws from
a fixed-seed RNG, with range endpoints tried first.

It is NOT a shrinker and finds no minimal counterexamples — when the real
``hypothesis`` is installed the test modules import it instead.
"""

from __future__ import annotations

import functools
import random


class _Strategy:
    """A sampler with optional boundary values tried before random draws."""

    def __init__(self, sample, boundaries=()):
        self._sample = sample
        self.boundaries = tuple(boundaries)

    def draw(self, rng: random.Random, i: int):
        if i < len(self.boundaries):
            return self.boundaries[i]
        return self._sample(rng)


class st:
    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         boundaries=(min_value, max_value))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         boundaries=(min_value, max_value))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq), boundaries=seq[:1])


def settings(max_examples: int = 30, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature (the
        # original's params would be mistaken for fixtures via __wrapped__)
        def wrapper():
            n = getattr(wrapper, "_max_examples", 30)
            rng = random.Random(0)
            for i in range(n):
                drawn = [s.draw(rng, i) for s in strategies]
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = getattr(fn, "_max_examples", 30)
        return wrapper

    return deco
