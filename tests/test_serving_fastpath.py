"""Zero-overhead serving path: fan-out fusion, offline-blocked layout,
quantize-in-kernel, and decode-shape specialization.

Equality contract (docs/kernels.md):

  * fused fan-out vs separate member calls — BIT-identical (same lowering,
    per-column arithmetic unchanged);
  * offline-blocked kernel path vs the legacy per-call-padding path —
    bit-identical at tile-aligned K; float-ulp association difference when
    the legacy path pads K (its pad compensation sits outside the sa*sw
    rescale), in which case the BLOCKED path is the one matching ref.py;
  * Pallas kernels vs ref.py scalar semantics — exact integer accumulators,
    f32 epilogue within the kernel suite's standard rtol=2e-5 (FMA
    contraction differs across lowerings);
  * folded jnp serving operands (build_fold) vs the exact integer path —
    the same math re-associated into float GEMMs: float-ulp agreement.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx_linear import (
    QuantizedDense,
    QuantizedDenseGroup,
    dense,
    dense_group,
    pack_dense,
    pack_params,
    packed_layer_paths,
)
from repro.core.policy import ApproxPolicy
from repro.kernels import ops, ref
from repro.quant.quantize import quantize

RNG = np.random.default_rng(11)

ALL_MODES = [("exact", 0), ("perforated", 2), ("recursive", 3), ("truncated", 6)]


def _qkv_params(k=64, nq=64, nkv=32, bias=False):
    def leaf(n):
        p = {"w": jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)}
        if bias:
            p["b"] = jnp.asarray(RNG.normal(0, 0.3, (n,)), jnp.float32)
        return p

    return {"q": leaf(nq), "k": leaf(nkv), "v": leaf(nkv), "o": leaf(k)}


# ---------------------------------------------------------------------------
# fan-out fusion: bit-identity vs separate dense() calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,m", ALL_MODES)
@pytest.mark.parametrize("use_cv", [True, False])
def test_fused_qkv_bit_identical_vs_separate(mode, m, use_cv):
    params = _qkv_params()
    pol = ApproxPolicy(mode, m, use_cv=use_cv)
    fused = pack_params(params, lambda p: pol)
    sep = pack_params(params, lambda p: pol, fuse=False)
    assert isinstance(fused["qkv"], QuantizedDenseGroup)
    assert fused["qkv"].names == ("q", "k", "v")
    x = jnp.asarray(RNG.normal(0, 1, (3, 7, 64)), jnp.float32)
    outs = dense_group(fused["qkv"], x)
    for name in ("q", "k", "v"):
        np.testing.assert_array_equal(
            np.asarray(outs[name]), np.asarray(dense(sep[name], x)), err_msg=name)


def test_fused_qkv_with_bias_and_grouped_cv():
    params = _qkv_params(bias=True)
    pol = ApproxPolicy("perforated", 3, groups=4)
    fused = pack_params(params, lambda p: pol)
    sep = pack_params(params, lambda p: pol, fuse=False)
    x = jnp.asarray(RNG.normal(0, 1, (5, 64)), jnp.float32)
    outs = dense_group(fused["qkv"], x)
    for name in ("q", "k", "v"):
        np.testing.assert_array_equal(
            np.asarray(outs[name]), np.asarray(dense(sep[name], x)))


def test_fused_gateup_swiglu_bit_identical():
    from repro.nn.layers import init_swiglu, swiglu

    p = init_swiglu(jax.random.PRNGKey(0), 64, 128)
    pol = ApproxPolicy("recursive", 3)
    fused = pack_params(p, lambda path: pol)
    sep = pack_params(p, lambda path: pol, fuse=False)
    assert isinstance(fused["gateup"], QuantizedDenseGroup)
    assert "gate" not in fused and "up" not in fused
    x = jnp.asarray(RNG.normal(0, 1, (2, 5, 64)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(swiglu(fused, x)), np.asarray(swiglu(sep, x)))


def test_fused_qkv_stacked_scan_sliceable():
    """(L, k, n) stacked fused groups slice per layer under lax.scan and
    stay bit-identical to the unfused stacked packs."""
    L, k = 2, 32
    params = {
        n: {"w": jnp.asarray(RNG.normal(0, 0.1, (L, k, w)), jnp.float32)}
        for n, w in (("q", 32), ("k", 16), ("v", 16), ("o", 32))
    }
    pol = ApproxPolicy("perforated", 2)
    fused = pack_params(params, lambda p: pol)
    sep = pack_params(params, lambda p: pol, fuse=False)
    x = jnp.asarray(RNG.normal(0, 1, (3, k)), jnp.float32)

    def body_fused(carry, g):
        outs = dense_group(g, carry)
        return carry, jnp.concatenate([outs["q"], outs["k"], outs["v"]], -1)

    def body_sep(carry, layer):
        q, kk, v = layer
        return carry, jnp.concatenate(
            [dense(q, carry), dense(kk, carry), dense(v, carry)], -1)

    _, yf = jax.lax.scan(body_fused, x, fused["qkv"])
    _, ys = jax.lax.scan(body_sep, x, (sep["q"], sep["k"], sep["v"]))
    np.testing.assert_array_equal(np.asarray(yf), np.asarray(ys))


def test_fusion_skips_mismatched_policies_and_experts():
    params = _qkv_params()
    pols = {"q": ApproxPolicy("perforated", 2), "k": ApproxPolicy("perforated", 3),
            "v": ApproxPolicy("perforated", 2), "o": ApproxPolicy("perforated", 2)}
    packed = pack_params(params, lambda p: pols[p[-1]])
    assert "qkv" not in packed  # policies differ: no fusion
    assert isinstance(packed["q"], QuantizedDense)

    # q/k/v names WITHOUT the attention companion "o" (e.g. RWKV-style
    # mixes whose members take different inputs) must never fuse
    no_comp = {kk: vv for kk, vv in _qkv_params().items() if kk != "o"}
    packed = pack_params(no_comp, lambda p: ApproxPolicy("perforated", 2))
    assert "qkv" not in packed
    assert isinstance(packed["q"], QuantizedDense)

    # MoE expert stacks keep per-member packs for the ragged grouped path
    experts = {"experts": {
        n: {"w": jnp.asarray(RNG.normal(0, 0.1, (4, 16, 8)), jnp.float32)}
        for n in ("gate", "up", "down")}}
    packed = pack_params(experts, lambda p: ApproxPolicy("perforated", 2))
    assert "gateup" not in packed["experts"]
    assert isinstance(packed["experts"]["gate"], QuantizedDense)


def test_fused_model_forward_and_paths_match_unfused():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.numerics import apply_numerics, get_preset

    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    plan = get_preset("serve-default").resolve(params)
    fused = apply_numerics(params, plan)
    want = {e.path: e.policy for e in plan.entries}
    unfused = pack_params(params, lambda p: want.get("/".join(p)), fuse=False)
    assert packed_layer_paths(fused) == packed_layer_paths(unfused)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab)
    np.testing.assert_array_equal(
        np.asarray(api.forward(fused, {"tokens": toks})),
        np.asarray(api.forward(unfused, {"tokens": toks})))


# ---------------------------------------------------------------------------
# offline-blocked layout + quantize-in-kernel (pallas backend)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 3),
                                    ("truncated", 6)])
@pytest.mark.parametrize("use_cv", [True, False])
def test_blocked_kernel_matches_ref_scalar_semantics(mode, m, use_cv):
    """Quantize-in-kernel over the blocked layout vs ref.py on the same
    codes (standard kernel-suite tolerance; integer parts are exact)."""
    k, n = 200, 48  # deliberately unaligned: exercises in-kernel K masking
    w = jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.5, (n,)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (5, k)), jnp.float32)
    pol = ApproxPolicy(mode, m, use_cv=use_cv, backend="pallas")
    qd = pack_dense({"w": w, "b": b}, pol, (-4.0, 4.0))
    assert qd.blocked is not None
    y = np.asarray(dense(qd, x))
    a_q = quantize(x, qd.a_qp)
    r = np.asarray(ref.approx_matmul_cv_ref(
        a_q, qd.pack.w_q, qd.pack.c, qd.pack.c0, qd.pack.sum_qw, b,
        qd.a_qp.scale, qd.pack.w_scale, qd.a_qp.zero_point, qd.pack.w_zp,
        mode=mode, m=m, use_cv=use_cv))
    np.testing.assert_allclose(y, r, rtol=2e-5, atol=2e-3)


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 3),
                                    ("truncated", 6)])
def test_blocked_bit_identical_to_legacy_at_aligned_k(mode, m):
    k, n = 256, 48  # K already a tile multiple: no legacy pad compensation
    w = jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (5, k)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy(mode, m, backend="pallas"),
                    (-4.0, 4.0))
    y_blocked = np.asarray(dense(qd, x))
    y_legacy = np.asarray(dense(dataclasses.replace(qd, blocked=None), x))
    np.testing.assert_array_equal(y_blocked, y_legacy)


def test_blocked_close_to_legacy_at_unaligned_k():
    """With K padding the legacy path compensates (k_pad-k)*za*zw outside
    the sa*sw rescale — ulp-level association difference only."""
    k, n = 200, 48
    w = jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (5, k)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy("perforated", 2, backend="pallas"),
                    (-4.0, 4.0))
    y_blocked = np.asarray(dense(qd, x))
    y_legacy = np.asarray(dense(dataclasses.replace(qd, blocked=None), x))
    np.testing.assert_allclose(y_blocked, y_legacy, rtol=2e-5, atol=2e-4)


def test_pallas_fused_group_bit_identical_vs_separate_pallas():
    params = _qkv_params(k=128)
    pol = ApproxPolicy("perforated", 2, backend="pallas")
    fused = pack_params(params, lambda p: pol)
    sep = pack_params(params, lambda p: pol, fuse=False)
    assert fused["qkv"].blocked is not None
    x = jnp.asarray(RNG.normal(0, 1, (4, 128)), jnp.float32)
    outs = dense_group(fused["qkv"], x)
    for name in ("q", "k", "v"):
        np.testing.assert_array_equal(
            np.asarray(outs[name]), np.asarray(dense(sep[name], x)))


@pytest.mark.parametrize("m_rows", [4, 128])
def test_decode_and_prefill_shapes_pick_valid_blocks(m_rows):
    """M=4 exercises the decode-specialized single-K-step tiles, M=128 the
    prefill tiles; both must agree with ref."""
    k, n = 384, 32
    w = jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (m_rows, k)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy("perforated", 2, backend="pallas"),
                    (-4.0, 4.0))
    y = np.asarray(dense(qd, x))
    a_q = quantize(x, qd.a_qp)
    r = np.asarray(ref.approx_matmul_cv_ref(
        a_q, qd.pack.w_q, qd.pack.c, qd.pack.c0, qd.pack.sum_qw,
        jnp.zeros((n,), jnp.float32), qd.a_qp.scale, qd.pack.w_scale,
        qd.a_qp.zero_point, qd.pack.w_zp, mode="perforated", m=2))
    np.testing.assert_allclose(y, r, rtol=2e-5, atol=2e-3)


def test_pick_blocks_decode_merges_k_axis():
    bm, bn, bk = ops._pick_blocks(4, 2048, 128, 128, 128, 512)
    assert bm == 8 and bk == 2048  # single K step for decode rows
    bm, bn, bk = ops._pick_blocks(128, 2048, 128, 128, 128, 512)
    assert bk == 512  # prefill keeps the default K depth


def test_pallas_grouped_cv_falls_back_to_jnp():
    """backend="pallas" with groups > 1 must serve via the jnp grouped path
    instead of crashing (no grouped Pallas kernel yet)."""
    w = jnp.asarray(RNG.normal(0, 0.1, (64, 16)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (4, 64)), jnp.float32)
    qd_p = pack_dense({"w": w},
                      ApproxPolicy("perforated", 3, groups=4, backend="pallas"),
                      (-4.0, 4.0))
    qd_j = pack_dense({"w": w},
                      ApproxPolicy("perforated", 3, groups=4, backend="jnp"),
                      (-4.0, 4.0))
    np.testing.assert_array_equal(np.asarray(dense(qd_p, x)),
                                  np.asarray(dense(qd_j, x)))


# ---------------------------------------------------------------------------
# folded serving operands (jnp fast path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,m", ALL_MODES)
@pytest.mark.parametrize("use_cv", [True, False])
def test_folded_path_matches_integer_reference(mode, m, use_cv):
    """The folded float-GEMM path vs the exact-integer reference path:
    same math re-associated, so agreement to float ulps."""
    from repro.quant.quantize import quantized_linear

    k, n = 96, 40
    w = jnp.asarray(RNG.normal(0, 0.1, (k, n)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.5, (n,)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 1, (9, k)), jnp.float32)
    qd = pack_dense({"w": w, "b": b}, ApproxPolicy(mode, m, use_cv=use_cv),
                    (-4.0, 4.0))
    assert qd.fold is not None
    y = np.asarray(dense(qd, x))
    r = np.asarray(quantized_linear(x, qd.pack, qd.a_qp, mode, m,
                                    use_cv=use_cv))
    np.testing.assert_allclose(y, r, rtol=2e-5, atol=2e-4)


def test_pack_params_fold_false_keeps_exact_integer_path():
    from repro.quant.quantize import quantized_linear

    params = _qkv_params()
    pol = ApproxPolicy("perforated", 2)
    packed = pack_params(params, lambda p: pol, fuse=False, fold=False)
    assert packed["q"].fold is None
    x = jnp.asarray(RNG.normal(0, 1, (5, 64)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(dense(packed["q"], x)),
        np.asarray(quantized_linear(x, packed["q"].pack, packed["q"].a_qp,
                                    "perforated", 2)))


def test_fold_skipped_for_grouped_and_deep_fanin():
    w_deep = jnp.asarray(RNG.normal(0, 0.1, (512, 16)), jnp.float32)
    qd = pack_dense({"w": w_deep}, ApproxPolicy("perforated", 2), (-4.0, 4.0))
    assert qd.fold is None  # deep fan-in keeps the exact integer path
    w = jnp.asarray(RNG.normal(0, 0.1, (64, 16)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy("perforated", 2, groups=4),
                    (-4.0, 4.0))
    assert qd.fold is None  # grouped CV keeps the exact integer path


# ---------------------------------------------------------------------------
# plan accounting + engine surfacing
# ---------------------------------------------------------------------------


def test_plan_reports_blocked_and_fold_bytes():
    from repro.numerics import uniform_spec
    from repro.quant.quantize import EPI_ROWS, META_LEN, serving_blocks

    k, n = 200, 48
    params = {"lin": {"w": jnp.zeros((k, n))}}
    plan_j = uniform_spec(ApproxPolicy("perforated", 2)).resolve(params)
    plan_p = uniform_spec(
        ApproxPolicy("perforated", 2, backend="pallas")).resolve(params)
    bn, bk = serving_blocks(k, n)
    kb, nb = -(-k // bk) * bk, -(-n // bn) * bn
    legacy = k * n + 4 * n * 3  # uint8 codes + sum_qw/c/c0 vectors
    blocked = kb * nb + 4 * (EPI_ROWS * nb + META_LEN)
    assert plan_p.entries[0].packed_bytes == legacy + blocked
    # jnp backend: canonical pack + the folded f32 operands
    # (A and B are (k, n) each for perforated, delta is (n,))
    fold = 4 * (2 * k * n + n)
    assert plan_j.entries[0].packed_bytes == legacy + fold

    # deep fan-in: no fold built, none counted
    deep = {"lin": {"w": jnp.zeros((512, n))}}
    plan_deep = uniform_spec(ApproxPolicy("perforated", 2)).resolve(deep)
    assert plan_deep.entries[0].packed_bytes == 512 * n + 4 * n * 3


def test_engine_metrics_surface_decode_specialization():
    from repro.configs import get_config
    from repro.configs.base import EngineConfig
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(slots=4, max_len=32, prefill_chunk=8)

    # float params: no blocked packs, so the flag must stay off even though
    # the slot count fits the decode window
    eng = ServingEngine(cfg, params, ecfg)
    assert eng.metrics.snapshot()["decode_specialized"] is False

    pallas = build_serving_params(params, cfg, ServeConfig(
        policy=ApproxPolicy("perforated", 2, backend="pallas")))
    eng_p = ServingEngine(cfg, pallas, ecfg)
    assert eng_p.metrics.snapshot()["decode_specialized"] is True
    eng_p.reset_metrics()
    assert eng_p.metrics.snapshot()["decode_specialized"] is True

    eng16 = ServingEngine(cfg, pallas, EngineConfig(slots=16, max_len=32,
                                                    prefill_chunk=8))
    assert eng16.metrics.snapshot()["decode_specialized"] is False
