"""The paper's central claims at convolution level (Sec. 3, Eqs. 12-32).

* without CV: error mean/std follow Eq. 12 (k*mu, sqrt(k)*sigma);
* with CV: mean is nullified (Eqs. 22/28) and variance shrinks;
* C = E[W] is the variance-minimizing constant (Eq. 21's argmin);
* Eq. 20 predicts the with-CV variance for perforated/recursive;
* grouped CV (beyond paper) only improves on the paper's single group.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the wheel: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import control_variate as cv
from repro.core import multipliers as am

MODES = ["perforated", "recursive", "truncated"]


def _conv_errors(mode, m, k, n_trials, seed=0, use_cv=True, groups=1, c_override=None):
    """Empirical distribution of the convolution error over random uniform
    activations, for ONE fixed random weight vector."""
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 256, (k, 1))
    a = rng.integers(0, 256, (n_trials, k))
    exact = a.astype(np.int64) @ w.astype(np.int64)
    acc = np.asarray(am.approx_matmul(a, w, mode, m)).astype(np.float64)
    if use_cv:
        if c_override is not None:
            const = cv.CVConstants(
                c=np.asarray([c_override], np.float32), c0=np.zeros(1, np.float32))
        elif groups == 1:
            const = cv.cv_constants(w, mode, m)
        else:
            const = cv.cv_constants_grouped(w, mode, m, groups)
        if groups == 1:
            v = np.asarray(cv.cv_term(a, const, mode, m))
        else:
            v = np.asarray(cv.cv_term_grouped(a, const, mode, m, groups))
        acc = acc + v
    return (exact[:, 0] - acc[:, 0]), w


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 3), ("truncated", 6)])
def test_no_cv_error_follows_eq12(mode, m):
    """Eq. 12 (k*mu, sqrt(k)*sigma) holds when BOTH operands are random —
    the i.i.d. setting of the paper's derivation."""
    k, n = 256, 4000
    rng = np.random.default_rng(11)
    w = rng.integers(0, 256, (n, k))
    a = rng.integers(0, 256, (n, k))
    errs = np.asarray(am.am_error(w, a, mode, m)).sum(axis=1).astype(np.float64)
    mu_pred, sig_pred = cv.predicted_conv_error_no_cv_uniform(mode, m, k)
    assert abs(errs.mean() - mu_pred) < 5 * sig_pred / np.sqrt(n) + 1e-9
    assert abs(errs.std() - sig_pred) / sig_pred < 0.10


@pytest.mark.parametrize("mode,m", [("perforated", 1), ("perforated", 3),
                                    ("recursive", 3), ("truncated", 5),
                                    ("truncated", 7)])
def test_cv_nullifies_mean(mode, m):
    """Eqs. 22/28: with the paper's (C, C0) the mean convolution error is 0."""
    k, n = 256, 8000
    errs, _ = _conv_errors(mode, m, k, n, use_cv=True)
    se = errs.std() / np.sqrt(n)
    assert abs(errs.mean()) < 5 * se + 1e-9, (errs.mean(), se)


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 4)])
def test_cv_reduces_variance(mode, m):
    """Perforated/recursive: V is proportional to the error -> variance drops
    (Eq. 20 vs Eq. 12)."""
    k, n = 256, 4000
    e_cv, _ = _conv_errors(mode, m, k, n, use_cv=True)
    e_no, _ = _conv_errors(mode, m, k, n, use_cv=False)
    assert e_cv.std() < 0.7 * e_no.std(), (e_cv.std(), e_no.std())


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 4), ("truncated", 6)])
def test_cv_reduces_rms(mode, m):
    """All three multipliers: total RMS error (bias included — what accuracy
    actually sees) collapses with the CV.  For the truncated multiplier the
    win is mostly the nullified mean (Sec. 3.2), so RMS is the right metric."""
    k, n = 256, 4000
    e_cv, _ = _conv_errors(mode, m, k, n, use_cv=True)
    e_no, _ = _conv_errors(mode, m, k, n, use_cv=False)
    rms = lambda e: np.sqrt((e**2).mean())
    assert rms(e_cv) < 0.25 * rms(e_no), (rms(e_cv), rms(e_no))


def test_c_is_variance_argmin_perforated():
    """Eq. 21: C = E[W] minimizes Var(eps_G*) — perturbing C is never better."""
    mode, m, k, n = "perforated", 2, 128, 6000
    rng = np.random.default_rng(3)
    w = rng.integers(0, 256, (k, 1))
    c_star = float(w.mean())
    best, _ = _conv_errors(mode, m, k, n, seed=3, c_override=c_star)
    for delta in (-30, -10, 10, 30):
        worse, _ = _conv_errors(mode, m, k, n, seed=3, c_override=c_star + delta)
        assert worse.var() >= best.var() * 0.999, delta


def test_eq20_variance_prediction():
    """Eq. 20 evaluated at C = E[W] predicts the empirical variance."""
    mode, m, k, n = "perforated", 2, 128, 20000
    rng = np.random.default_rng(5)
    w = rng.integers(0, 256, (k, 1))
    errs, _ = _conv_errors(mode, m, k, n, seed=5)
    pred = cv.predicted_var_with_cv_perforated(w[:, 0], m)
    assert abs(errs.var() - pred) / pred < 0.1


def test_grouped_cv_improves():
    """Beyond paper: per-group constants reduce variance further (or tie)."""
    mode, m, k, n = "perforated", 3, 256, 6000
    e1, _ = _conv_errors(mode, m, k, n, groups=1)
    e4, _ = _conv_errors(mode, m, k, n, groups=4)
    e16, _ = _conv_errors(mode, m, k, n, groups=16)
    assert e4.var() <= e1.var() * 1.02
    assert e16.var() <= e4.var() * 1.02


@given(st.integers(0, 2**32 - 1), st.sampled_from(MODES), st.integers(1, 7))
@settings(max_examples=25, deadline=None)
def test_cv_term_matches_manual(seed, mode, m):
    """V == C * sum(x_j) + C0 for random inputs (structure property)."""
    rng = np.random.default_rng(seed)
    k = 32
    w = rng.integers(0, 256, (k, 3))
    a = rng.integers(0, 256, (5, k))
    const = cv.cv_constants(w, mode, m)
    v = np.asarray(cv.cv_term(a, const, mode, m))
    sx = np.asarray(cv.sum_x(a, mode, m))
    manual = sx[:, None] * np.asarray(const.c)[None, :] + np.asarray(const.c0)[None, :]
    assert np.allclose(v, manual, rtol=1e-6, atol=1e-4)
