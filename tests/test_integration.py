"""End-to-end integration: training reduces loss, serving matches training
numerics, and the paper's technique survives the full model pipeline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import ApproxPolicy
from repro.data import SyntheticLMConfig
from repro.data.synthetic import lm_batch
from repro.launch.serve import ServeConfig, build_serving_params, make_decode_step, make_prefill_step
from repro.launch.train import TrainConfig, init_train_state, make_train_step
from repro.models import build_model


def test_lm_training_reduces_loss():
    cfg = get_config("olmo-1b-reduced")
    tcfg = TrainConfig(base_lr=1e-2, warmup_steps=5, total_steps=200)
    dcfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=64, batch=8,
                             markov_states=32)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_grad_compress_training_matches_uncompressed_roughly():
    cfg = get_config("olmo-1b-reduced")
    dcfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=64, batch=8, markov_states=32)

    def run(grad_compress):
        tcfg = TrainConfig(base_lr=1e-2, warmup_steps=5, total_steps=200,
                           grad_compress=grad_compress)
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, tcfg))
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
            state, metrics = step(state, batch)
        return float(metrics["loss"])

    plain, compressed = run(False), run(True)
    assert compressed < plain + 0.3, (plain, compressed)


@pytest.mark.parametrize("mode,m", [("perforated", 1), ("recursive", 2)])
def test_approx_cv_tracks_exact_int8(mode, m):
    """Teacher-forced argmax agreement: mild approximation + CV must track
    the EXACT-int8 pack closely (isolates the multiplier error from shared
    quantization noise; greedy-generation agreement on an untrained model is
    chaotic by construction, so it is not the right metric)."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (8, 24), 0, cfg.vocab)

    def argmaxes(policy):
        scfg = ServeConfig(policy=policy)
        p = build_serving_params(params, cfg, scfg)
        return np.asarray(jnp.argmax(api.forward(p, {"tokens": toks}), -1))

    exact = argmaxes(ApproxPolicy("exact", 0))
    approx = argmaxes(ApproxPolicy(mode, m, use_cv=True))
    agree = (exact == approx).mean()
    assert agree > 0.7, agree  # untrained-model logit margins are razor-thin
    if mode == "perforated":  # high-error multiplier: the CV is what saves it
        no_cv = argmaxes(ApproxPolicy(mode, m, use_cv=False))
        agree_no = (exact == no_cv).mean()
        assert agree > 2 * agree_no, (agree, agree_no)


def test_serving_pipeline_generates():
    """Prefill+decode through packed params runs jitted end to end."""
    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(policy=ApproxPolicy("perforated", 2, use_cv=True))
    packed = build_serving_params(params, cfg, scfg)
    prompt = jax.random.randint(jax.random.PRNGKey(5), (4, 12), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg, max_len=24, scfg=scfg))
    decode = jax.jit(make_decode_step(cfg, scfg=scfg))
    logits, cache = prefill(packed, {"tokens": prompt})
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(8):
        logits, cache = decode(packed, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        assert bool(jnp.isfinite(logits).all())


def test_cv_improves_model_level_fidelity():
    """The paper's headline at model level: under AGGRESSIVE approximation,
    logits with CV are much closer to float logits than without CV."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, 16), 0, cfg.vocab)
    ref = api.forward(params, {"tokens": toks})

    def packed_logits(use_cv):
        scfg = ServeConfig(policy=ApproxPolicy("perforated", 3, use_cv=use_cv))
        p = build_serving_params(params, cfg, scfg)
        return api.forward(p, {"tokens": toks})

    err_cv = float(jnp.abs(packed_logits(True) - ref).mean())
    err_no = float(jnp.abs(packed_logits(False) - ref).mean())
    assert err_cv < 0.5 * err_no, (err_cv, err_no)


def test_pallas_backend_matches_jnp_backend_in_model():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab)

    def logits(backend):
        scfg = ServeConfig(policy=ApproxPolicy("truncated", 5, backend=backend))
        p = build_serving_params(params, cfg, scfg)
        return api.forward(p, {"tokens": toks})

    lj = logits("jnp")
    lp = logits("pallas")
    assert float(jnp.abs(lj - lp).max()) < 1e-3


def test_auto_policy_respects_budget():
    """Greedy per-layer policy search: the mixed-policy model stays within
    the error budget while using aggressive multipliers where it can."""
    from repro.core.approx_linear import pack_params
    from repro.core.policy import auto_policy, paper_policies

    cfg = dataclasses.replace(get_config("olmo-1b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, cfg.vocab)

    apply_fn = lambda p, b: api.forward(p, b)
    policy_fn, rows = auto_policy(
        apply_fn, params, {"tokens": toks},
        candidates=paper_policies(use_cv=True),
        budget_rel_err=0.08, skip=("embed",))
    assert rows, "no layers considered"
    labels = {r["policy"] for r in rows}
    assert any(l != "int8-exact" for l in labels), labels  # used approximation

    mixed = pack_params(params, policy_fn)
    ref = api.forward(params, {"tokens": toks})
    out = api.forward(mixed, {"tokens": toks})
    rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-12))
    assert rel < 0.4, rel  # layers compose; stays in a sane band
