"""Fleet serving: spec-aware routing, cross-replica prefix sharing, and
the fleet-vs-single-engine token-identity contract.

Unit coverage (no model): router placement per policy on stub replicas —
latency class pinned to exact tiers, bulk to approximate tiers with
threshold spill into exact ones (never the reverse), least-loaded
scoring, validation errors — plus ``NumericsSpec.is_exact`` tier
classification and ``TierConfig`` validation.

Integration coverage (reduced model): prefix-cache export/import
roundtrip across two ``PagedKVPool``s (content equality, importer-side
refcount of exactly 1, idempotent re-import, LRU eviction of imported
blocks), an import-then-serve prefix hit that is token-identical to the
exporter, and the tentpole acceptance sweep — a two-tier fleet serving a
classed trace is token-identical, request by request, to single engines
packed per tier, under every routing policy.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.numerics import get_preset
from repro.serving import (FleetReplica, FleetRouter, ServingEngine,
                           TierConfig, build_fleet)

# ---------------------------------------------------------------------------
# router units (no model)
# ---------------------------------------------------------------------------


class _StubRequest:
    def __init__(self, rid):
        self.rid = rid


class _StubEngine:
    """The replica-handle surface the router touches, minus the model."""

    def __init__(self, numerics="int8", pending=0, ttft=None):
        self.numerics = numerics
        self.pending = pending
        self.ttft = ttft
        self.tracer = None
        self.submitted = []
        self._rid = 0

    def load(self):
        return {"queued": 0, "prefilling": 0, "decoding": 0,
                "pending": self.pending, "slots": 4, "slots_free": 4,
                "ttft_mean_s": self.ttft}

    def submit(self, prompt, max_new_tokens, priority=0, **kw):
        r = _StubRequest(self._rid)
        self._rid += 1
        self.submitted.append(r)
        self.pending += 1
        return r

    @property
    def idle(self):
        return True


def _stub_fleet(policy="spec-aware", spill_threshold=None,
                exact_counts=(2,), approx_counts=(2,)):
    reps = []
    for i in range(sum(exact_counts)):
        reps.append(FleetReplica(_StubEngine("int8"),
                                 TierConfig("exact", "int8", count=2),
                                 i, exact=True))
    for i in range(sum(approx_counts)):
        reps.append(FleetReplica(_StubEngine("serve-default"),
                                 TierConfig("bulk", "serve-default", count=2),
                                 i, exact=False))
    return FleetRouter(reps, policy=policy, spill_threshold=spill_threshold)


def test_spec_aware_routes_by_class():
    fl = _stub_fleet()
    lat = fl.submit([1, 2], 4, klass="latency")
    blk = fl.submit([1, 2], 4, klass="bulk")
    assert lat.fleet_tier == "exact" and not lat.fleet_spill
    assert blk.fleet_tier == "bulk" and not blk.fleet_spill
    assert fl.routed_by_class == {"latency": 1, "bulk": 1}


def test_class_derives_from_priority():
    fl = _stub_fleet()
    assert fl.submit([1], 4, priority=0).fleet_class == "latency"
    assert fl.submit([1], 4, priority=3).fleet_class == "bulk"


def test_least_loaded_within_home_tier_with_ttft_tiebreak():
    fl = _stub_fleet()
    exact = [r for r in fl.replicas if r.exact]
    exact[0].engine.pending = 3
    assert fl.submit([1], 4, klass="latency").fleet_replica == \
        exact[1].replica_id
    # equal pending: the faster-answering replica absorbs the request
    exact[0].engine.pending = exact[1].engine.pending
    exact[0].engine.ttft = 0.01
    exact[1].engine.ttft = 0.50
    assert fl.submit([1], 4, klass="latency").fleet_replica == \
        exact[0].replica_id


def test_bulk_spills_to_exact_past_threshold_latency_never():
    fl = _stub_fleet(spill_threshold=2)
    approx = [r for r in fl.replicas if not r.exact]
    for r in approx:
        r.engine.pending = 2  # bulk side saturated
    spilled = fl.submit([1], 4, klass="bulk")
    assert spilled.fleet_spill and spilled.fleet_tier == "exact"
    assert fl.spills == 1
    # exact side also at threshold: bulk stays home (spilling would only
    # move the queue, and the exact side serves latency traffic)
    for r in fl.replicas:
        r.engine.pending = 2
    stuck = fl.submit([1], 4, klass="bulk")
    assert not stuck.fleet_spill and stuck.fleet_tier == "bulk"
    # latency requests NEVER land on approximate replicas, loaded or not
    for _ in range(4):
        assert not fl.submit([1], 4, klass="latency").fleet_replica.startswith(
            "bulk")


def test_latency_without_exact_tier_raises():
    reps = [FleetReplica(_StubEngine("serve-default"),
                         TierConfig("bulk", "serve-default"), 0, exact=False)]
    fl = FleetRouter(reps)
    with pytest.raises(ValueError, match="exact tier"):
        fl.submit([1], 4, klass="latency")
    # bulk traffic on an all-approx fleet is fine
    assert fl.submit([1], 4, klass="bulk").fleet_tier == "bulk"


def test_bulk_without_approx_tier_runs_on_exact():
    reps = [FleetReplica(_StubEngine("int8"),
                         TierConfig("exact", "int8"), 0, exact=True)]
    fl = FleetRouter(reps)
    r = fl.submit([1], 4, klass="bulk")
    assert r.fleet_tier == "exact" and not r.fleet_spill


def test_round_robin_and_least_loaded_ignore_class():
    fl = _stub_fleet(policy="round-robin")
    seen = [fl.submit([1], 4, klass="latency").fleet_replica
            for _ in range(4)]
    assert len(set(seen)) == 4  # cycles the whole fleet
    fl = _stub_fleet(policy="least-loaded")
    for r in fl.replicas[:-1]:
        r.engine.pending = 5
    r = fl.submit([1], 4, klass="latency")
    assert r.fleet_replica == fl.replicas[-1].replica_id  # approx is fine


def test_router_and_tier_validation():
    with pytest.raises(ValueError, match="at least one replica"):
        FleetRouter([])
    reps = [FleetReplica(_StubEngine(), TierConfig("t", "int8"), 0, True)]
    with pytest.raises(ValueError, match="routing policy"):
        FleetRouter(reps, policy="nope")
    with pytest.raises(ValueError, match="spill_threshold"):
        FleetRouter(reps, spill_threshold=0)
    with pytest.raises(ValueError, match="count"):
        TierConfig("t", "int8", count=0)
    fl = FleetRouter(reps)
    with pytest.raises(ValueError, match="request class"):
        fl.submit([1], 4, klass="interactive")


def test_is_exact_classifies_tiers():
    assert get_preset("int8").is_exact
    assert not get_preset("serve-default").is_exact


# ---------------------------------------------------------------------------
# prefix export/import across pools (reduced model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def olmo():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


def _engine(cfg, api, params, layout="paged", slots=3, max_len=64,
            chunk=16, bs=8, mesh=None, engine_id=None):
    return ServingEngine(cfg, params, EngineConfig(
        slots=slots, max_len=max_len, prefill_chunk=chunk,
        cache_dtype="float32", kv_layout=layout, kv_block_size=bs),
        api=api, mesh=mesh, engine_id=engine_id)


def test_prefix_export_import_roundtrip_refcounts_and_eviction(olmo):
    cfg, api, params = olmo
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 24).tolist()  # 3 full 8-blocks
    warm = _engine(cfg, api, params)
    warm.submit(prompt, 2)
    warm.drain()
    entries = warm.export_prefix()
    assert len(entries) == 3
    cold = _engine(cfg, api, params)
    imported = cold.import_prefix(entries)
    assert imported == 3
    assert cold.metrics.prefix_imports == 3
    # every imported block: registered under the exporter's chain hash,
    # content bit-identical, refcount exactly 1 (cache-held, evictable)
    held = dict(cold.pool.prefix.items())
    for h, content in entries:
        bid = held[h]
        assert cold.pool.allocator.refcount(bid) == 1
        for k, v in content.items():
            np.testing.assert_array_equal(
                np.asarray(cold.pool.cache[k][:, bid]), v)
    # idempotent: a second import of the same entries is a no-op
    assert cold.import_prefix(entries) == 0
    assert cold.metrics.prefix_imports == 3
    # importer-side eviction: refcount-1 entries are LRU-reclaimable
    free_before = cold.pool.allocator.n_free
    for _ in range(3):
        assert cold.pool.prefix.evict_lru(cold.pool.allocator)
    assert not cold.pool.prefix.evict_lru(cold.pool.allocator)
    assert cold.pool.allocator.n_free == free_before + 3


def test_import_then_serve_hits_and_matches_exporter_tokens(olmo):
    cfg, api, params = olmo
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, 24).tolist()
    suffix = rng.integers(0, cfg.vocab, 4).tolist()
    warm = _engine(cfg, api, params)
    warm.submit(shared, 2)
    warm.drain()
    ref = warm.submit(shared + suffix, 5)  # exporter serves from its cache
    warm.drain()
    cold = _engine(cfg, api, params)
    assert cold.import_prefix(warm.export_prefix()) > 0
    hit = cold.submit(shared + suffix, 5)
    cold.drain()
    # block-aligned shareable prefix, capped one token early
    assert hit.prefix_hit_tokens >= min(len(shared) // 8 * 8,
                                        len(shared) - 1)
    assert hit.generated == ref.generated


# ---------------------------------------------------------------------------
# fleet vs single engine: the token-identity acceptance sweep
# ---------------------------------------------------------------------------

_TIERS = ("int8", "serve-default")


@pytest.fixture(scope="module")
def packs(olmo):
    cfg, _, params = olmo
    return {name: build_serving_params(
        params, cfg, ServeConfig(spec=get_preset(name)))
        for name in _TIERS}


def _jobs(cfg, n=4, seed=6):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab,
                          int(rng.integers(4, 22))).tolist(), 5)
            for _ in range(n)]


@pytest.fixture(scope="module")
def references(olmo, packs):
    """Per tier: the jobs served by ONE engine under that tier's pack."""
    cfg, api, _ = olmo
    jobs = _jobs(cfg)
    refs = {}
    for name in _TIERS:
        eng = _engine(cfg, api, packs[name], layout="contiguous")
        reqs = [eng.submit(p, g) for p, g in jobs]
        eng.drain()
        refs[name] = [r.generated for r in reqs]
    return jobs, refs


@pytest.mark.parametrize("policy",
                         ["spec-aware", "least-loaded", "round-robin"])
def test_fleet_token_identity_per_policy(olmo, packs, references, policy):
    cfg, api, _ = olmo
    jobs, refs = references
    ecfg = EngineConfig(slots=3, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="contiguous")
    tiers = [TierConfig(name, name) for name in _TIERS]
    fleet = build_fleet(
        cfg, None, tiers, ecfg,
        pack=lambda name: (packs[name], name, get_preset(name)),
        api=api, policy=policy)
    placed = [fleet.submit(p, g, klass="bulk" if i % 2 else "latency")
              for i, (p, g) in enumerate(jobs)]
    fleet.drain()
    for i, r in enumerate(placed):
        # a request's tokens depend only on the tier that served it:
        # identical to a single engine under that tier's pack
        assert r.finish_reason == "length"
        assert r.generated == refs[r.fleet_tier][i], (policy, i)
        if policy == "spec-aware" and r.fleet_class == "latency":
            assert r.fleet_tier == "int8"  # exact tier only
    snap = fleet.snapshot()
    assert snap["fleet"]["numerics"] == "mixed"
    assert snap["fleet"]["engines"] == 2
    assert set(snap["tiers"]) == set(_TIERS)
    assert fleet.compile_count() <= 2 * len(fleet.replicas)


def test_fleet_share_prefixes_cross_replica(olmo, packs):
    cfg, api, _ = olmo
    ecfg = EngineConfig(slots=3, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="paged",
                        kv_block_size=8)
    fleet = build_fleet(
        cfg, None, [TierConfig("int8", "int8", count=2)], ecfg,
        pack=lambda name: (packs[name], name, get_preset(name)), api=api)
    r0, r1 = fleet.replicas
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, 24).tolist()
    warm = r0.engine.submit(shared, 4)
    r0.engine.drain()
    assert fleet.share_prefixes() > 0
    hit = r1.engine.submit(shared, 4)
    r1.engine.drain()
    assert hit.prefix_hit_tokens == len(shared) - 1
    assert hit.generated == warm.generated
    snap = fleet.snapshot()
    assert snap["tiers"]["int8"]["prefix_imports"] > 0
    assert snap["fleet"]["prefix_imports"] == \
        snap["tiers"]["int8"]["prefix_imports"]
