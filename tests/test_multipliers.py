"""Bit-exactness of the approximate-multiplier models (paper Sec. 2).

Unit + hypothesis property tests: the elementwise definitions, the error
identities (Eqs. 3/6/8), the partial-product-matrix oracle, the MXU bit-slice
matmul algebra, and the analytic Table 1 moments.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the wheel: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import multipliers as am

MODES = ["perforated", "recursive", "truncated"]
code = st.integers(0, 255)
mval = st.integers(0, 8)


@given(code, code, mval)
@settings(max_examples=300, deadline=None)
def test_perforated_definition(w, a, m):
    # AM_P = W * (A - A mod 2^m)  (Eq. 2/3 closed form)
    expected = w * (a - (a % (1 << m)))
    assert int(am.am_perforated(w, a, m)) == expected


@given(code, code, mval)
@settings(max_examples=300, deadline=None)
def test_recursive_definition(w, a, m):
    # w*a - AM_R = (w mod 2^m) * (a mod 2^m)  (Eq. 6)
    err = (w % (1 << m)) * (a % (1 << m))
    assert int(am.am_recursive(w, a, m)) == w * a - err


@given(code, code, mval)
@settings(max_examples=200, deadline=None)
def test_truncated_matches_ppmatrix(w, a, m):
    # Eq. 7/8 closed form == literal partial-product-matrix truncation
    assert int(am.am_truncated(w, a, m)) == int(am.am_truncated_ppmatrix(w, a, m))


@given(code, code, mval, st.sampled_from(MODES))
@settings(max_examples=300, deadline=None)
def test_error_identity(w, a, m, mode):
    # am + error == exact product, always
    assert int(am.am(w, a, mode, m)) + int(am.am_error(w, a, mode, m)) == w * a


@given(code, code, st.sampled_from(MODES))
@settings(max_examples=100, deadline=None)
def test_m0_is_exact(w, a, mode):
    assert int(am.am(w, a, mode, 0)) == w * a


@given(code, code, mval, st.sampled_from(MODES))
@settings(max_examples=200, deadline=None)
def test_error_nonnegative_and_bounded(w, a, m, mode):
    # all three multipliers under-approximate: 0 <= eps <= w*a
    eps = int(am.am_error(w, a, mode, m))
    assert 0 <= eps <= w * a or (w * a == 0 and eps == 0)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("m", [1, 2, 3, 5, 7])
def test_matmul_algebra_exact(mode, m):
    rng = np.random.default_rng(42)
    a = rng.integers(0, 256, (7, 33))
    w = rng.integers(0, 256, (33, 9))
    ref = np.asarray(am.approx_matmul_ref(a, w, mode, m))
    fast = np.asarray(am.approx_matmul(a, w, mode, m))
    assert np.array_equal(ref, fast)


@pytest.mark.parametrize(
    "mode,m,mu_paper,sigma_paper",
    [
        ("perforated", 1, 63.7, 82), ("perforated", 2, 191, 198),
        ("perforated", 3, 447, 425),
        ("recursive", 2, 2.24, 2.67), ("recursive", 3, 12.26, 12.51),
        ("recursive", 4, 56, 53.4), ("recursive", 5, 239, 219),
        ("truncated", 4, 12, 9.9), ("truncated", 5, 32, 23),
        ("truncated", 6, 80, 52), ("truncated", 7, 192, 115),
    ],
)
def test_table1_analytic_matches_paper(mode, m, mu_paper, sigma_paper):
    """Table 1 (uniform operands): analytic moments within 3% of the paper's
    1M-sample measurements (the paper rounds, e.g. 12.25 -> "12")."""
    mu, sigma = am.analytic_error_moments_uniform(mode, m)
    assert abs(mu - mu_paper) / max(mu_paper, 1) < 0.03
    assert abs(sigma - sigma_paper) / max(sigma_paper, 1) < 0.03


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 3), ("truncated", 5)])
def test_table1_empirical_matches_analytic(mode, m):
    rng = np.random.default_rng(0)
    w = rng.integers(0, 256, 200_000)
    a = rng.integers(0, 256, 200_000)
    mu_e, sig_e = am.empirical_error_moments(mode, m, w, a)
    mu_a, sig_a = am.analytic_error_moments_uniform(mode, m)
    assert abs(mu_e - mu_a) / max(mu_a, 1e-9) < 0.02
    assert abs(sig_e - sig_a) / max(sig_a, 1e-9) < 0.02


def test_error_mean_per_weight():
    # E_A[eps | W] tables used by the CV: verify against brute force
    for mode, m in [("perforated", 2), ("recursive", 3), ("truncated", 5)]:
        table = am.error_mean_per_weight_uniform_a(mode, m)
        a_all = np.arange(256)
        for w in (0, 1, 77, 200, 255):
            brute = np.asarray(am.am_error(w, a_all, mode, m)).mean()
            assert abs(table[w] - brute) < 1e-6, (mode, m, w)
