"""Speculative decode: the approximate pack drafts, the exact-int8 pack
verifies.  Contracts under test:

  * bit-identity — speculative greedy output equals the sequential
    exact-int8 baseline for every k and both KV layouts, with the
    two-compiled-shapes invariant intact and acceptance > 0;
  * rollback — a near-always-rejected (junk) drafter forces a KV cursor
    rollback every round, including across paged block boundaries, and
    the output still matches the baseline token for token;
  * stop conditions — a drafted-but-rejected token equal to eos_id must
    NOT finish the request (finish decisions run on verifier output only);
  * CV as a draft-quality knob — the control-variate draft spec accepts
    at least as well as the same spec without CV on the same trace;
  * construction guards and the `plan --diff-checkpoint` drift gate.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.core.policy import ApproxPolicy
from repro.launch import serve
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.numerics.presets import get_preset
from repro.serving import ServingEngine

MAX_LEN = 64


def _sequential_baseline(api, params, prompt, gen, decode):
    """Per-request prefill + decode_step greedy loop (the oracle the
    engine — speculative or not — must reproduce token for token)."""
    logits, cache = api.prefill(params, {"tokens": jnp.asarray([prompt])},
                                max_len=MAX_LEN, cache_dtype=jnp.float32)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, jnp.asarray([[tok]]), cache)
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    return out


def _mixed_requests(vocab, n=6, seed=3):
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n):
        plen = [3, 17, 33, 9, 25, 5][i % 6] + int(rng.integers(0, 3))
        gen = int(rng.integers(4, 12))
        trace.append((rng.integers(0, vocab, plen).tolist(), gen))
    return trace


@pytest.fixture(scope="module")
def setup():
    """One float init packed twice — exact int8 verifier, approximate+CV
    drafter (the one-checkpoint speculative pair)."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    raw = api.init(jax.random.PRNGKey(0))
    verify = build_serving_params(raw, cfg, ServeConfig(spec=get_preset("int8")))
    draft = build_serving_params(raw, cfg,
                                 ServeConfig(spec=get_preset("serve-default")))
    return cfg, api, raw, verify, draft


@pytest.fixture(scope="module")
def trace(setup):
    return _mixed_requests(setup[0].vocab)


@pytest.fixture(scope="module")
def baseline(setup, trace):
    cfg, api, _, verify, _ = setup
    decode = jax.jit(api.decode_step)
    return [_sequential_baseline(api, verify, p, g, decode) for p, g in trace]


def _spec_engine(cfg, verify, draft, k, layout="contiguous", block_size=16,
                 slots=3, draft_label="serve-default"):
    ecfg = EngineConfig(slots=slots, max_len=MAX_LEN, prefill_chunk=16,
                        cache_dtype="float32", speculative_k=k,
                        kv_layout=layout, kv_block_size=block_size)
    return ServingEngine(cfg, verify, ecfg, draft_params=draft,
                         draft_numerics=draft_label)


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_speculative_token_identical(setup, trace, baseline, layout):
    """For every draft depth k the speculative engine must emit exactly the
    sequential exact-int8 greedy tokens — the drafts only ever change HOW
    the tokens are computed, never WHICH tokens come out — while accepting
    a nonzero share of drafts and compiling at most two shapes."""
    cfg, _, _, verify, draft = setup
    for k in (1, 2, 4):
        eng = _spec_engine(cfg, verify, draft, k, layout=layout,
                           block_size=8 if layout == "paged" else 16)
        reqs = [eng.submit(p, g) for p, g in trace]
        finished = eng.run()
        assert len(finished) == len(trace)
        for r, base in zip(reqs, baseline):
            assert r.finished and r.generated == base, (layout, k, r.rid)
        # draft params see only the thin shape, verify params only the
        # chunk shape: speculation must not add compiled shapes
        assert eng.compile_count() <= 2, (layout, k)
        snap = eng.metrics.snapshot()
        assert snap["speculative_k"] == k
        assert snap["drafted_tokens"] > 0 and snap["spec_rounds"] > 0
        assert snap["acceptance_rate"] is not None
        assert snap["acceptance_rate"] > 0, (layout, k)


def test_paged_rollback_at_block_boundary(setup):
    """A drafter packed from DIFFERENT weights proposes near-pure junk, so
    almost every round rejects and rolls the KV cursors back over drafted
    positions — with block_size=4 those rollbacks repeatedly cross paged
    block boundaries.  Rollback must be a pure cursor move (no block free
    or remap), so the output still matches the baseline exactly."""
    cfg, api, _, verify, _ = setup
    junk_raw = api.init(jax.random.PRNGKey(42))
    junk = build_serving_params(junk_raw, cfg,
                                ServeConfig(spec=get_preset("serve-default")))
    rng = np.random.default_rng(5)
    trace = [(rng.integers(0, cfg.vocab, 7).tolist(), 12),
             (rng.integers(0, cfg.vocab, 19).tolist(), 10)]
    decode = jax.jit(api.decode_step)
    base = [_sequential_baseline(api, verify, p, g, decode) for p, g in trace]

    eng = _spec_engine(cfg, verify, junk, k=4, layout="paged", block_size=4,
                       slots=2, draft_label="junk")
    reqs = [eng.submit(p, g) for p, g in trace]
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["drafted_tokens"] > 0
    # the junk drafter must actually exercise the rejection/rollback path
    assert snap["accepted_draft_tokens"] < snap["drafted_tokens"]
    for r, b in zip(reqs, base):
        assert r.generated == b, (r.rid, r.generated, b)


def test_drafted_eos_never_finishes(setup):
    """Stop-condition contract: a junk drafter whose first proposal d1 is
    outside the exact greedy continuation is submitted with eos_id == d1.
    The draft is rejected by the verifier, so the request must run to its
    full budget with finish_reason 'length' — a drafted-but-rejected eos
    token must never finish a request."""
    cfg, api, _, verify, _ = setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9).tolist()
    gen = 8
    decode = jax.jit(api.decode_step)
    base = _sequential_baseline(api, verify, prompt, gen, decode)

    # the drafter's first proposal: the token it emits from the verifier's
    # first token x1 over the prefilled cache (exactly what round 1 drafts)
    logits, cache = api.prefill(verify, {"tokens": jnp.asarray([prompt])},
                                max_len=MAX_LEN, cache_dtype=jnp.float32)
    assert int(jnp.argmax(logits[0])) == base[0]
    junk = d1 = None
    for key in (9, 13, 21):
        cand = build_serving_params(
            api.init(jax.random.PRNGKey(key)), cfg,
            ServeConfig(spec=get_preset("serve-default")))
        dl, _ = decode(cand, jnp.asarray([[base[0]]]), cache)
        tok = int(jnp.argmax(dl[0]))
        if tok != base[1] and tok not in base:
            junk, d1 = cand, tok
            break
    assert junk is not None, "no junk drafter drafted outside the baseline"

    eng = _spec_engine(cfg, verify, junk, k=4, slots=2, draft_label="junk")
    r = eng.submit(prompt, gen, eos_id=d1)
    eng.run()
    assert r.generated == base and r.finish_reason == "length", (
        r.generated, base, r.finish_reason)


def test_cv_acceptance_at_least_no_cv(setup, trace):
    """The acceptance rate is a live draft-quality readout: the CV-corrected
    perforated drafter must agree with the exact verifier at least as often
    as the same perforated spec without the control variate."""
    cfg, _, raw, verify, draft_cv = setup
    draft_nocv = build_serving_params(
        raw, cfg, ServeConfig(spec=get_preset(
            "serve-default",
            policy=ApproxPolicy("perforated", 2, use_cv=False))))
    rates = {}
    for label, dp in (("cv", draft_cv), ("no-cv", draft_nocv)):
        eng = _spec_engine(cfg, verify, dp, k=4, draft_label=label)
        for p, g in trace:
            eng.submit(p, g)
        eng.run()
        snap = eng.metrics.snapshot()
        assert snap["acceptance_rate"] is not None
        rates[label] = snap["acceptance_rate"]
    assert rates["cv"] >= rates["no-cv"], rates


def test_speculative_construction_guards(setup):
    cfg, _, _, verify, _ = setup
    # speculation without a drafter is a config error, caught at build time
    with pytest.raises(ValueError, match="draft_params"):
        ServingEngine(cfg, verify,
                      EngineConfig(slots=2, max_len=32, prefill_chunk=8,
                                   cache_dtype="float32", speculative_k=2))
    # recurrent state cannot rewind a rejected draft
    rcfg = dataclasses.replace(get_config("rwkv6-1.6b-reduced"),
                               compute_dtype="float32")
    rapi = build_model(rcfg)
    rparams = rapi.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="roll back"):
        ServingEngine(rcfg, rparams,
                      EngineConfig(slots=2, max_len=32, prefill_chunk=8,
                                   cache_dtype="float32", speculative_k=2),
                      draft_params=rparams)


def test_plan_diff_checkpoint_gate(setup, tmp_path):
    """`plan --diff-checkpoint` re-resolves the NumericsSpec persisted in a
    checkpoint's metadata over the same abstract params: clean exit when
    the CLI spec matches, SystemExit(=drifted layer count) when not."""
    from repro.checkpoint.manager import save_pytree

    _, _, raw, _, _ = setup
    path = str(tmp_path / "ckpt.rpk")
    save_pytree(raw, path,
                meta={"numerics": get_preset("serve-default").to_dict()})
    # same spec as the checkpoint was packed under: no drift, clean return
    serve.main(["plan", "--arch", "olmo-1b-reduced",
                "--preset", "serve-default", "--diff-checkpoint", path])
    # different spec: every approximable layer drifts -> nonzero SystemExit
    with pytest.raises(SystemExit) as ei:
        serve.main(["plan", "--arch", "olmo-1b-reduced", "--preset", "int8",
                    "--diff-checkpoint", path])
    assert int(ei.value.code) > 0
