"""Serving correctness: prefill + decode must reproduce the training-path
forward logits token by token, for every decode-capable architecture."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model

DECODE_ARCHS = [a for a in list_archs() if get_config(a).has_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = dataclasses.replace(get_config(arch + "-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, t = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    full = api.forward(params, {"tokens": toks})

    logits, cache = api.prefill(params, {"tokens": toks[:, : t - 2]}, max_len=t + 4,
                                cache_dtype=jnp.float32)
    assert float(jnp.abs(logits - full[:, t - 3]).max()) < 1e-3
    for i in (t - 2, t - 1):
        logits, cache = api.decode_step(params, toks[:, i : i + 1], cache)
        assert float(jnp.abs(logits - full[:, i]).max()) < 1e-3, (arch, i)


def test_ring_cache_equals_full_window_decode():
    """hymba's ring cache (len W) decodes identically to masked full attention."""
    cfg = dataclasses.replace(get_config("hymba-1.5b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b = 2
    t_total = 20  # window is 8 in the reduced config: exercises wraparound
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, t_total), 0, cfg.vocab)
    full = api.forward(params, {"tokens": toks})
    _, cache = api.prefill(params, {"tokens": toks[:, :4]}, max_len=t_total,
                           cache_dtype=jnp.float32)
    for i in range(4, t_total):
        logits, cache = api.decode_step(params, toks[:, i : i + 1], cache)
        err = float(jnp.abs(logits - full[:, i]).max())
        assert err < 2e-3, (i, err)


def test_greedy_generation_runs_jitted():
    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    prefill = jax.jit(lambda p, b: api.prefill(p, b, max_len=24))
    decode = jax.jit(api.decode_step)
    logits, cache = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(8):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        assert bool(jnp.isfinite(logits).all())
    assert int(cache["pos"]) == 8 + 8


@pytest.mark.parametrize("kv_layout", ["contiguous", "paged"])
def test_decode_slots_single_device_mesh_token_identity(kv_layout):
    """The ``decode_slots(..., mesh=)`` plumb-through: a single-device
    mesh (what every fleet replica gets, repro.serving.fleet.replica_mesh)
    must generate token-identically to the mesh-less path, on both KV
    layouts.  This is the no-op anchor the multi-host fleet placement
    builds on — if a trivial mesh perturbs tokens, a sharded one hides
    real divergence."""
    from repro.configs.base import EngineConfig
    from repro.serving import ServingEngine
    from repro.serving.fleet import replica_mesh

    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(slots=2, max_len=48, prefill_chunk=16,
                        kv_layout=kv_layout)
    rng = np.random.default_rng(5)
    # one short prompt, one crossing a paged block boundary
    jobs = [(rng.integers(0, cfg.vocab, 10).tolist(), 6),
            (rng.integers(0, cfg.vocab, 20).tolist(), 6)]
    outs = []
    for mesh in (None, replica_mesh()):
        eng = ServingEngine(cfg, params, ecfg, api=api, mesh=mesh)
        reqs = [eng.submit(p, g) for p, g in jobs]
        eng.run()
        assert all(r.finish_reason == "length" for r in reqs)
        outs.append([r.generated for r in reqs])
    assert outs[0] == outs[1]


def test_int8_kv_cache_decode_close():
    """int8 KV cache (the §Perf decode optimization): logits stay close to
    the bf16-cache decode (fixed-point 1/16 resolution on O(1) post-rope
    values)."""
    import jax.numpy as jnp

    cfg = dataclasses.replace(get_config("qwen3-4b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, t = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    ref = api.forward(params, {"tokens": toks})

    _, cache = api.prefill(params, {"tokens": toks[:, : t - 2]}, max_len=t + 2,
                           cache_dtype=jnp.int8)
    for i in (t - 2, t - 1):
        logits, cache = api.decode_step(params, toks[:, i : i + 1], cache)
        err = float(jnp.abs(logits - ref[:, i]).max())
        scale = float(jnp.abs(ref[:, i]).max())
        assert err < 0.05 * scale + 0.05, (i, err, scale)
