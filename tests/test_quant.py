"""Quantization substrate + approximate quantized linear behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the wheel: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro import quant
from repro.core.approx_linear import QuantizedDense, dense, pack_dense, pack_params
from repro.core.policy import ApproxPolicy


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (64, 64)).astype(np.float32)
    qp = quant.calibrate_tensor(jnp.asarray(x))
    x2 = np.asarray(quant.dequantize(quant.quantize(jnp.asarray(x), qp), qp))
    step = float(np.asarray(qp.scale))
    assert np.abs(x - x2).max() <= step * 0.501 + 1e-7


@given(st.floats(-100, 0, allow_nan=False), st.floats(0, 100, allow_nan=False))
@settings(max_examples=50, deadline=None)
def test_calibration_contains_zero(lo, hi):
    qp = quant.calibrate_minmax(lo, hi)
    zero = np.asarray(quant.dequantize(quant.quantize(jnp.zeros(()), qp), qp))
    assert abs(float(zero)) <= float(np.asarray(qp.scale)) * 0.5 + 1e-7


def test_exact_int8_linear_close_to_float():
    rng = np.random.default_rng(1)
    k, n = 128, 32
    w = rng.normal(0, 0.1, (k, n)).astype(np.float32)
    x = rng.normal(0, 0.8, (16, k)).astype(np.float32)
    pack = quant.pack_linear(jnp.asarray(w), None, "exact", 0)
    aqp = quant.calibrate_tensor(jnp.asarray(x))
    y = np.asarray(quant.quantized_linear(jnp.asarray(x), pack, aqp, "exact", 0))
    ref = x @ w
    rel = np.abs(y - ref).max() / np.abs(ref).max()
    assert rel < 0.03, rel


@pytest.mark.parametrize("mode,m", [("perforated", 2), ("recursive", 3), ("truncated", 6)])
def test_cv_beats_no_cv_at_layer_level(mode, m):
    """The paper's claim, at one linear layer: adding V cuts the error."""
    rng = np.random.default_rng(2)
    k, n = 256, 64
    w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
    x = rng.normal(0.3, 0.5, (32, k)).astype(np.float32)
    ref = x @ w
    pack = quant.pack_linear(jnp.asarray(w), None, mode, m)
    aqp = quant.calibrate_tensor(jnp.asarray(x))
    y_cv = np.asarray(quant.quantized_linear(jnp.asarray(x), pack, aqp, mode, m, use_cv=True))
    y_no = np.asarray(quant.quantized_linear(jnp.asarray(x), pack, aqp, mode, m, use_cv=False))
    err_cv = np.abs(y_cv - ref).mean()
    err_no = np.abs(y_no - ref).mean()
    assert err_cv < 0.5 * err_no, (err_cv, err_no)


def test_pack_params_walks_tree_and_skips():
    from repro.numerics import Rule, apply_numerics, uniform_spec

    params = {
        "blocks": {"attn": {"q": {"w": jnp.ones((8, 8))}},
                   "norm": {"scale": jnp.ones(8)}},
        "router": {"w": jnp.ones((8, 4))},
    }
    spec = uniform_spec(ApproxPolicy("perforated", 2), rules=(Rule("router"),))
    packed = apply_numerics(params, spec.resolve(params))
    assert isinstance(packed["blocks"]["attn"]["q"], QuantizedDense)
    assert isinstance(packed["router"], dict)  # kept float by the rule
    assert "scale" in packed["blocks"]["norm"]


def test_stacked_pack_scan_sliceable():
    """(L, k, n) stacked linears pack to per-layer constants that lax.scan
    can slice (per-layer quant scales + CV constants)."""
    import jax

    L, k, n = 3, 16, 8
    w = jnp.asarray(np.random.default_rng(0).normal(0, 0.1, (L, k, n)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy("perforated", 2), (-4.0, 4.0))
    assert qd.pack.w_q.shape == (L, k, n)
    assert qd.pack.c.shape == (L, n)
    assert qd.a_qp.scale.shape == (L,)

    x = jnp.ones((2, k))

    def body(carry, qd_l):
        return carry + dense(qd_l, x).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0), qd)
    assert np.isfinite(float(total))


def test_grouped_cv_policy_path():
    rng = np.random.default_rng(4)
    w = rng.normal(0, 0.1, (64, 16)).astype(np.float32)
    x = rng.normal(0, 0.5, (8, 64)).astype(np.float32)
    qd = pack_dense({"w": jnp.asarray(w)}, ApproxPolicy("perforated", 3, groups=4),
                    (float(x.min()), float(x.max())))
    y = np.asarray(dense(qd, jnp.asarray(x)))
    ref = x @ w
    assert np.abs(y - ref).mean() < 0.05 * np.abs(ref).mean() + 0.05
