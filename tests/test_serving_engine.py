"""Continuous-batching engine: scheduler/admission units and the core
equivalence contract — engine outputs are token-identical to the
sequential prefill+decode baseline for exact and approximate+CV numerics,
with at most two compiled shapes (prefill chunk + decode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.core.policy import ApproxPolicy
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.serving import (AdmissionController, Request, RequestQueue,
                           RequestState, ServingEngine, SlotScheduler)

# ---------------------------------------------------------------------------
# scheduler / admission units (no model)
# ---------------------------------------------------------------------------


class FakePool:
    def __init__(self, slots):
        self._free = list(range(slots - 1, -1, -1))

    def acquire(self, rid):
        return self._free.pop() if self._free else None

    def acquire_for(self, req):
        return self.acquire(req.rid)

    def release(self, slot):
        self._free.append(slot)

    @property
    def n_free(self):
        return len(self._free)


def _req(rid, plen=4, gen=4, priority=0):
    return Request(rid=rid, prompt=list(range(plen)), max_new_tokens=gen,
                   priority=priority)


def test_queue_priority_then_fifo():
    q = RequestQueue()
    for rid, pr in [(0, 1), (1, 0), (2, 1), (3, 0)]:
        q.push(_req(rid, priority=pr))
    assert [q.pop().rid for _ in range(4)] == [1, 3, 0, 2]


def test_admission_rejections():
    adm = AdmissionController(max_queue=2, max_len=32, prefill_chunk=8)
    q = RequestQueue()
    ok, why = adm.check(q, _req(0, plen=0))
    assert not ok and "empty" in why
    ok, why = adm.check(q, _req(1, plen=30, gen=4))  # padded 32 fits, 30+4 no
    assert not ok and "exceeds slot capacity" in why
    ok, why = adm.check(q, _req(2, plen=33, gen=1))  # padded 40 > 32
    assert not ok and "padded" in why
    ok, _ = adm.check(q, _req(3, plen=8, gen=8))
    assert ok
    q.push(_req(4)), q.push(_req(5))
    ok, why = adm.check(q, _req(6))
    assert not ok and "queue full" in why


def test_admit_order_and_slot_reuse():
    sched = SlotScheduler(slots=2, prefill_chunk=8)
    q, pool, active = RequestQueue(), FakePool(2), {}
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        q.push(r)
    admitted = sched.admit(q, pool, active)
    assert [r.rid for r in admitted] == [0, 1]  # FIFO
    assert all(r.state == RequestState.PREFILL for r in admitted)
    assert pool.n_free == 0 and len(q) == 2

    # finishing rid 0 frees its slot; the NEXT admission reuses that slot
    freed = admitted[0].slot
    pool.release(freed)
    del active[freed]
    more = sched.admit(q, pool, active)
    assert [r.rid for r in more] == [2] and more[0].slot == freed


def test_interleave_prevents_starvation():
    """mixed=False fallback: strict whole-batch alternation still bounds
    the decode stall at one prefill turn per decode token."""
    sched = SlotScheduler(slots=2, prefill_chunk=4, interleave=True,
                          mixed=False)
    long_prefill = _req(0, plen=400, gen=2)
    long_prefill.slot, long_prefill.state = 0, RequestState.PREFILL
    decoding = _req(1)
    decoding.slot, decoding.state = 1, RequestState.DECODE
    decoding.generated = [7]
    active = {0: long_prefill, 1: decoding}
    kinds = []
    for _ in range(6):
        b = sched.next_batch(active)
        kinds.append(b.kind)
        if b.kind == "prefill":  # chunk bookkeeping so the batch stays valid
            long_prefill.prefilled += int(b.n_valid[0])
    # strict alternation: a 100-chunk prompt cannot starve running decodes
    assert kinds.count("decode") >= 3
    assert "prefill" in kinds[:2] and "decode" in kinds[:2]


def test_prefill_batch_shapes_and_padding():
    sched = SlotScheduler(slots=3, prefill_chunk=8)
    r = _req(0, plen=5)
    r.slot, r.state = 1, RequestState.PREFILL
    b = sched.next_batch({1: r})
    assert b.kind == "prefill" and b.tokens.shape == (3, 8)
    assert b.n_valid.tolist() == [0, 5, 0]
    assert b.tokens[1, :5].tolist() == r.prompt and b.tokens[1, 5:].sum() == 0
    assert b.row_kinds == ["prefill"]


def test_mixed_batch_construction():
    """With both kinds pending, decode rows ride the chunk-shaped call with
    n_valid = 1 — the decode stall never happens.  Decode-only turns keep
    the (slots, 1) shape so the thin-M kernel specialization still fires."""
    sched = SlotScheduler(slots=3, prefill_chunk=8, mixed=True)
    pre = _req(0, plen=20)
    pre.slot, pre.state = 0, RequestState.PREFILL
    dec = _req(1)
    dec.slot, dec.state = 2, RequestState.DECODE
    dec.generated = [42]
    b = sched.next_batch({0: pre, 2: dec})
    assert b.kind == "mixed" and b.tokens.shape == (3, 8)
    assert b.n_valid.tolist() == [8, 0, 1]
    assert b.tokens[2, 0] == 42 and b.tokens[2, 1:].sum() == 0
    assert dict(zip((r.slot for r in b.rows), b.row_kinds)) == {
        0: "prefill", 2: "decode"}
    # every iteration advances the decode row — no alternation turn skipped
    pre.prefilled = 8
    b2 = sched.next_batch({0: pre, 2: dec})
    assert b2.kind == "mixed" and b2.n_valid.tolist() == [8, 0, 1]
    # decode-only: thin (slots, 1) shape preserved
    pre.state = RequestState.DECODE
    pre.generated = [7]
    b3 = sched.next_batch({0: pre, 2: dec})
    assert b3.kind == "decode" and b3.tokens.shape == (3, 1)
    assert b3.row_kinds == ["decode", "decode"]


def test_admission_evicts_lowest_priority():
    """A full queue must not drop an urgent request while it holds only
    lower-priority work: the worst queued job (lowest class, latest
    arrival) is evicted instead."""
    adm = AdmissionController(max_queue=3, max_len=64, prefill_chunk=8)
    q = RequestQueue()
    victims = [_req(i, priority=5) for i in range(2)]
    for v in victims:
        q.push(v)
    q.push(_req(2, priority=1))
    # urgent request: admitted by evicting the NEWEST priority-5 job
    ok, reason, evicted = adm.admit(q, _req(3, priority=0))
    assert ok and reason is None and evicted is victims[1]
    assert len(q) == 2
    q.push(_req(3, priority=0))
    # equal priority to the worst queued -> plain queue-full rejection
    # (eviction requires STRICTLY lower-priority queued work)
    ok, reason, evicted = adm.admit(q, _req(4, priority=5))
    assert not ok and "queue full" in reason and evicted is None
    # strictly lower-priority work still queued -> the priority-5 survivor
    # is the next victim
    ok, _, evicted = adm.admit(q, _req(5, priority=1))
    assert ok and evicted is victims[0]


# ---------------------------------------------------------------------------
# engine equivalence vs sequential baseline
# ---------------------------------------------------------------------------


def _sequential_baseline(api, params, prompt, gen, max_len, decode=None):
    """Per-request prefill + decode_step loop (pass a shared jitted
    ``decode`` to amortize compilation across requests)."""
    decode = decode or api.decode_step
    logits, cache = api.prefill(params, {"tokens": jnp.asarray([prompt])},
                                max_len=max_len, cache_dtype=jnp.float32)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, jnp.asarray([[tok]]), cache)
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    return out


def _mixed_requests(vocab, n=8, seed=3):
    """>= n requests with heterogeneous prompt/gen lengths (some prompts
    span multiple prefill chunks, some fit a fraction of one)."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n):
        plen = [3, 17, 33, 9, 25, 5, 40, 12][i % 8] + int(rng.integers(0, 3))
        gen = int(rng.integers(2, 10))
        trace.append((rng.integers(0, vocab, plen).tolist(), gen))
    return trace


@pytest.mark.parametrize("policy", [None, ApproxPolicy("exact", 0),
                                    ApproxPolicy("perforated", 2, use_cv=True)],
                         ids=["float", "int8-exact", "perforated-m2-cv"])
def test_engine_token_identical_to_sequential(policy):
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if policy is not None:
        params = build_serving_params(params, cfg, ServeConfig(policy=policy))

    max_len = 64
    trace = _mixed_requests(cfg.vocab, n=8)
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=3, max_len=max_len, prefill_chunk=16,
                                     cache_dtype="float32"))
    reqs = [eng.submit(p, g) for p, g in trace]
    finished = eng.run()
    assert len(finished) == len(trace)
    # fixed-shape contract: exactly prefill + decode shapes, never more
    assert eng.compile_count() <= 2

    decode = jax.jit(api.decode_step)
    for r, (prompt, gen) in zip(reqs, trace):
        assert r.finished and len(r.generated) == gen
        base = _sequential_baseline(api, params, prompt, gen, max_len, decode)
        assert r.generated == base, (r.rid, r.generated, base)


def test_engine_rwkv_token_identical():
    """The recurrent arch serves through per-slot state with masked
    updates; equivalence must hold there too.  The baseline runs the
    prompt through the RECURRENT step (the form the engine serves) — the
    parallel-scan prefill is only ~1e-3-close to the recurrence, which can
    flip an argmax on long prompts."""
    cfg = dataclasses.replace(get_config("rwkv6-1.6b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    trace = _mixed_requests(cfg.vocab, n=5, seed=7)
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32"))
    reqs = [eng.submit(p, g) for p, g in trace]
    finished = eng.run()
    assert len(finished) == len(trace) and eng.compile_count() <= 2
    decode = jax.jit(api.decode_step)
    for r, (prompt, gen) in zip(reqs, trace):
        cache = api.init_cache(1, 64, jnp.float32)
        for t in prompt:
            logits, cache = decode(params, jnp.asarray([[t]]), cache)
        tok = int(jnp.argmax(logits[0]))
        base = [tok]
        for _ in range(gen - 1):
            logits, cache = decode(params, jnp.asarray([[tok]]), cache)
            tok = int(jnp.argmax(logits[0]))
            base.append(tok)
        assert r.generated == base, (r.rid, r.generated, base)


def test_engine_streaming_eos_and_metrics():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = list(np.random.default_rng(0).integers(0, cfg.vocab, 10))
    base = _sequential_baseline(api, params, prompt, 6, 64)

    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32"))
    streamed = []
    r_eos = eng.submit(prompt, 6, eos_id=base[1],
                       on_token=lambda r, t: streamed.append(t))
    r_full = eng.submit(prompt, 6)
    eng.run()
    # eos fires on the 2nd generated token -> early stop, reason "eos"
    assert r_eos.generated == base[:2] and r_eos.finish_reason == "eos"
    assert streamed == r_eos.generated  # on_token saw every token, in order
    assert r_full.generated == base and r_full.finish_reason == "length"

    snap = eng.metrics.snapshot()
    assert snap["requests_finished"] == 2
    assert snap["generated_tokens"] == len(r_eos.generated) + len(r_full.generated)
    assert snap["ttft_mean_s"] is not None and r_eos.ttft is not None
    assert 0 < snap["mean_slot_occupancy"] <= 1


def test_padding_rows_never_write_cache():
    """dynamic_update_slice CLAMPS out-of-range starts: a padding row
    (n_valid == 0) whose cursor exceeds max_len - chunk would, without the
    masked write in _slot_update, clobber its own valid attended K/V during
    another request's prefill batch.  The cache row must stay bit-exact."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    S, CH = 64, 16
    cache = api.init_slot_cache(2, S, jnp.float32)
    rng = np.random.default_rng(0)
    # fill slot 0 up to cursor 60 (> S - CH) via chunked prefill
    for _ in range(3):
        toks = np.zeros((2, CH), np.int32)
        toks[0] = rng.integers(0, cfg.vocab, CH)
        _, cache = api.decode_slots(params, jnp.asarray(toks), cache,
                                    jnp.asarray([CH, 0], np.int32))
    for _ in range(12):
        toks = np.zeros((2, 1), np.int32)
        toks[0] = rng.integers(0, cfg.vocab)
        _, cache = api.decode_slots(params, jnp.asarray(toks), cache,
                                    jnp.asarray([1, 0], np.int32))
    assert int(cache["lengths"][0]) == 60
    before = {k: np.asarray(v) for k, v in cache.items()}
    # slot 1 prefills a chunk; slot 0 is a padding row with cursor 60
    toks = np.zeros((2, CH), np.int32)
    toks[1] = rng.integers(0, cfg.vocab, CH)
    _, cache = api.decode_slots(params, jnp.asarray(toks), cache,
                                jnp.asarray([0, CH], np.int32))
    for key in ("k", "v"):
        assert np.array_equal(np.asarray(cache[key])[:, 0], before[key][:, 0]), key
    assert int(cache["lengths"][0]) == 60


def test_engine_high_cursor_interleave_token_identical():
    """Engine-level regression for the clamped-write bug: a request decoding
    past max_len - chunk while another request's chunked prefill runs."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompt_a = rng.integers(0, cfg.vocab, 40).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 20).tolist()

    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32"))
    ra = eng.submit(prompt_a, 20)
    while len(ra.generated) < 10:  # drive A's cursor past 48 = max_len-chunk
        eng.step()
    rb = eng.submit(prompt_b, 4)  # B's prefill now interleaves with A
    eng.run()

    decode = jax.jit(api.decode_step)
    assert ra.generated == _sequential_baseline(api, params, prompt_a, 20, 64,
                                                decode)
    assert rb.generated == _sequential_baseline(api, params, prompt_b, 4, 64,
                                                decode)


def test_mixed_vs_alternating_vs_sequential_token_identical():
    """The core mixed-batch contract: one engine with mixed batches on, one
    with the alternating fallback, both token-identical to the sequential
    baseline on a trace where prefill chunks and decode rows share calls —
    including a request that finishes its prefill in the same call a
    neighbor decodes."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    max_len = 64
    rng = np.random.default_rng(23)
    prompt_a = rng.integers(0, cfg.vocab, 6).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 12).tolist()  # single-chunk prompt
    prompt_c = rng.integers(0, cfg.vocab, 35).tolist()  # multi-chunk prompt

    outs = {}
    for mixed in (True, False):
        eng = ServingEngine(cfg, params,
                            EngineConfig(slots=2, max_len=max_len,
                                         prefill_chunk=16,
                                         cache_dtype="float32",
                                         mixed_batches=mixed))
        ra = eng.submit(prompt_a, 12)
        eng.step()  # A prefills (whole prompt, one chunk) and starts decoding
        assert ra.state == RequestState.DECODE
        # B's whole prompt fits one chunk: it COMPLETES prefill in the very
        # call where A's decode row rides along
        rb = eng.submit(prompt_b, 5)
        eng.step()
        if mixed:
            assert len(rb.generated) == 1  # emitted in the shared call
            assert len(ra.generated) == 2  # and A advanced in the same call
        rc = eng.submit(prompt_c, 4)  # multi-chunk prefill over running decodes
        eng.run()
        assert eng.compile_count() <= 2
        snap = eng.metrics.snapshot()
        assert (snap["mixed_steps"] > 0) == mixed
        outs[mixed] = [ra.generated, rb.generated, rc.generated]

    assert outs[True] == outs[False]
    decode = jax.jit(api.decode_step)
    for got, (prompt, gen) in zip(outs[True], [(prompt_a, 12), (prompt_b, 5),
                                               (prompt_c, 4)]):
        assert got == _sequential_baseline(api, params, prompt, gen, max_len,
                                           decode)


def test_decode_row_high_cursor_in_chunk_call():
    """_slot_update regression: a decode row (n_valid == 1) whose cursor
    exceeds max_len - chunk rides a chunk-shaped call.  dynamic_update_slice
    clamps the start, so without the clamp-aware roll+mask the token's K/V
    would land chunk-displaced over attended history."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    S, CH = 64, 16
    rng = np.random.default_rng(3)
    cache = api.init_slot_cache(2, S, jnp.float32)
    # drive slot 0's cursor to 60 > S - CH
    for _ in range(3):
        toks = np.zeros((2, CH), np.int32)
        toks[0] = rng.integers(0, cfg.vocab, CH)
        _, cache = api.decode_slots(params, jnp.asarray(toks), cache,
                                    jnp.asarray([CH, 0], np.int32))
    for _ in range(12):
        toks = np.zeros((2, 1), np.int32)
        toks[0] = rng.integers(0, cfg.vocab)
        _, cache = api.decode_slots(params, jnp.asarray(toks), cache,
                                    jnp.asarray([1, 0], np.int32))
    assert int(cache["lengths"][0]) == 60
    ref = {k: np.asarray(v) for k, v in cache.items()}

    # mixed call: slot 0 decodes one token AT CURSOR 60 inside the
    # chunk-shaped call that prefills slot 1
    tok0 = int(rng.integers(0, cfg.vocab))
    mixed_toks = np.zeros((2, CH), np.int32)
    mixed_toks[0, 0] = tok0
    mixed_toks[1] = rng.integers(0, cfg.vocab, CH)
    mixed_logits, mixed_cache = api.decode_slots(
        params, jnp.asarray(mixed_toks), cache,
        jnp.asarray([1, CH], np.int32))

    # reference: the same decode token through a thin (slots, 1) call
    thin_toks = np.zeros((2, 1), np.int32)
    thin_toks[0, 0] = tok0
    thin_logits, thin_cache = api.decode_slots(
        params, jnp.asarray(thin_toks), cache, jnp.asarray([1, 0], np.int32))

    assert int(mixed_cache["lengths"][0]) == 61
    for key in ("k", "v"):
        got = np.asarray(mixed_cache[key])[:, 0]
        want = np.asarray(thin_cache[key])[:, 0]
        # the new K/V must land at column 60 exactly, history untouched
        assert np.array_equal(got, want), key
        assert not np.array_equal(got[..., :61, :], ref[key][:, 0][..., :61, :])
    np.testing.assert_allclose(np.asarray(mixed_logits[0, 0]),
                               np.asarray(thin_logits[0, 0]), rtol=1e-5,
                               atol=1e-5)


def test_engine_eviction_surfaces_in_metrics():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32", max_queue=2))
    prompt = list(range(1, 9))
    low1 = eng.submit(prompt, 2, priority=5)
    low2 = eng.submit(prompt, 2, priority=5)
    urgent = eng.submit(prompt, 2, priority=0)  # queue full of priority-5 work
    assert urgent.state == RequestState.QUEUED
    assert low2.state == RequestState.REJECTED  # newest low-priority victim
    assert "evicted" in low2.reject_reason
    assert low1.state == RequestState.QUEUED
    assert eng.metrics.evicted == 1 and eng.metrics.rejected == 1
    finished = eng.run()
    assert {r.rid for r in finished} == {low1.rid, urgent.rid}
    assert eng.metrics.snapshot()["requests_evicted"] == 1


def test_metrics_clock_starts_at_first_step():
    """Warmup/compile time before the first served batch must not deflate
    throughput: the clock arms at the first record_step."""
    from repro.serving.metrics import EngineMetrics
    import time as _time

    m = EngineMetrics()
    snap = m.snapshot()  # nothing served yet: well-defined zeros
    assert snap["elapsed_s"] == 0.0 and snap["gen_tok_per_s"] == 0.0
    _time.sleep(0.25)  # "warmup" before the first batch
    m.record_step("decode", 0.5, 0, generated_tokens=100)
    snap = m.snapshot()
    # construction-time clock would report >= 0.25s elapsed and <= 400 tok/s
    assert snap["elapsed_s"] < 0.2
    assert snap["gen_tok_per_s"] > 500


def test_finish_reason_recorded_not_rederived():
    """A length-stopped generation whose final greedy token coincides with
    eos_id is a LENGTH stop; tail re-derivation would misreport it as
    eos."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = list(np.random.default_rng(2).integers(0, cfg.vocab, 8))
    base = _sequential_baseline(api, params, prompt, 3, 64)

    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                                     cache_dtype="float32"))
    # budget of 1 with eos_id equal to the token that will be generated:
    # both stop conditions fire on the same step; length is the actual stop
    r_len = eng.submit(prompt, 1, eos_id=base[0])
    # eos genuinely earlier than the budget
    r_eos = eng.submit(prompt, 3, eos_id=base[1])
    eng.run()
    assert r_len.generated == base[:1] and r_len.finish_reason == "length"
    assert r_eos.generated == base[:2] and r_eos.finish_reason == "eos"
    assert eng.submit(prompt, 1).finish_reason is None  # queued, not finished


def test_slot_pool_fused_recurrent_zeroing():
    """Recycling a slot must zero ONLY that slot's recurrent state, in one
    fused update (the old per-leaf loop mutated the dict mid-iteration)."""
    from repro.serving.kv_pool import SlotPool

    cfg = dataclasses.replace(get_config("rwkv6-1.6b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    pool = SlotPool(api, slots=2, max_len=32, cache_dtype="float32")
    dirty = {k: (jnp.ones_like(v) if k != "lengths"
                 else jnp.asarray([4, 7], jnp.int32))
             for k, v in pool.cache.items()}
    pool.update(dirty)
    slot = pool.acquire(rid=0)
    for k, v in pool.cache.items():
        arr = np.asarray(v)
        if k == "lengths":
            assert arr[slot] == 0 and arr[1 - slot] == 7
        else:  # leaves are (L, slots, ...)
            assert arr[:, slot].sum() == 0, k
            assert np.all(arr[:, 1 - slot] == 1), k


def test_engine_rejects_unservable():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(slots=2, max_len=32, prefill_chunk=8,
                                     cache_dtype="float32"))
    r = eng.submit(list(range(40)), 4)
    assert r.state == RequestState.REJECTED and "padded" in r.reject_reason
    assert eng.metrics.rejected == 1
    # unsupported arch (sliding-window ring cache) fails fast at build time
    hymba = get_config("hymba-1.5b-reduced")
    hymba_api = build_model(hymba)
    with pytest.raises(NotImplementedError):
        ServingEngine(hymba, hymba_api.init(jax.random.PRNGKey(0)),
                      EngineConfig(slots=2, max_len=32, prefill_chunk=8))
