"""Distribution correctness at test scale: spec fitting, mini-mesh dry-run
(lower+compile a reduced arch on 8 fake devices), EP-MoE equivalence, and
the HLO cost analyzer on a known program.  Multi-device parts run in
subprocesses so the main test process keeps 1 device."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=REPO, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fit_spec_drops_indivisible():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.parallel import fit_spec

    mesh = make_test_mesh((1,), ("model",))
    # recreate a 16-way mesh abstractly via a fake object is overkill: use
    # the real mesh api with 1 device but assert the arithmetic directly
    from repro.parallel.sharding import fit_spec as fs
    spec = fs(P("model", None), (32001, 64), mesh)  # 32001 % 1 == 0 -> kept
    assert spec == P("model", None)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.launch.train import (TrainConfig, init_train_state,
                                    make_train_step, train_state_shardings)
    from repro.parallel import batch_shardings
    from repro.models.registry import input_specs

    ARCH = os.environ["MINI_ARCH"]
    cfg = get_config(ARCH + "-reduced")
    mesh = make_test_mesh((2, 4), ("data", "model"))
    if cfg.mlp == "moe":
        cfg = dataclasses.replace(cfg, moe_impl="ep_psum")
    with use_mesh(mesh):
        tcfg = TrainConfig()
        step = make_train_step(cfg, tcfg, mesh=mesh)
        abstract = jax.eval_shape(lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0)))
        st_sh = train_state_shardings(cfg, tcfg, mesh)
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        }
        if cfg.input_mode == "embeds":
            batch_abs = {
                "embeds": jax.ShapeDtypeStruct((4, 32, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            }
        b_sh = batch_shardings(batch_abs, mesh)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        compiled = jitted.lower(abstract, batch_abs).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older jax returns [dict]
            cost = cost[0]
        print("MINI_DRYRUN_OK", ARCH, int(cost.get("flops", 0)) > 0)
""")


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b", "rwkv6-1.6b",
                                  "hymba-1.5b"])
def test_mini_mesh_train_step_compiles(arch):
    out = _run(f"import os; os.environ['MINI_ARCH']={arch!r}\n" + MINI_DRYRUN)
    assert f"MINI_DRYRUN_OK {arch}" in out


EP_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.nn import moe as moelib

    mesh = make_test_mesh((2, 4), ("data", "model"))
    cfg = moelib.MoEConfig(d_model=32, d_ff_expert=16, n_experts=8, top_k=2,
                           n_shared=1, impl="ep_psum", capacity_factor=8.0)
    p = moelib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 12, 32))
    with use_mesh(mesh):
        y_ep = jax.jit(lambda p, x: moelib.moe_apply(p, x, cfg, mesh=mesh))(p, x)
    y_local = moelib.moe_apply(p, x, dataclasses.replace(cfg, impl="local"))
    diff = float(jnp.abs(y_ep - y_local).max())
    assert diff < 1e-5, diff
    print("EP_EQUIV_OK")
""")


def test_ep_moe_matches_local():
    assert "EP_EQUIV_OK" in _run(EP_EQUIV)


OVERLAP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.runtime.overlap import rs_matmul_overlapped, compressed_psum

    mesh = make_test_mesh((4,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    with use_mesh(mesh):
        y = jax.jit(lambda x, w: rs_matmul_overlapped(x, w, mesh, "model"))(x, w)
    assert float(jnp.abs(y - x @ w).max()) < 1e-4
    print("OVERLAP_OK")
""")


def test_overlapped_collective_matmul():
    assert "OVERLAP_OK" in _run(OVERLAP)


def test_hlo_analyzer_counts_scan_trips():
    """A scan with known trip count and dot shape: flops must be multiplied
    by the trip count (compiled.cost_analysis counts the body once)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze_hlo

    L, M, K, N = 7, 32, 64, 48
    w = jnp.ones((L, K, N), jnp.float32)

    def f(x, w):
        def body(c, wl):
            return jnp.dot(c, wl), None

        out, _ = jax.lax.scan(body, x, w)
        return out

    x = jnp.ones((M, K), jnp.float32)
    # N == K required for scan carry; use square
    w2 = jnp.ones((L, K, K), jnp.float32)
    compiled = jax.jit(f).lower(x, w2).compile()
    hc = analyze_hlo(compiled.as_text())
    expected = 2 * M * K * K * L
    assert 0.9 * expected < hc.flops < 1.3 * expected, (hc.flops, expected)
