"""Fault tolerance: resilient step loop survives injected worker failures,
resumes from checkpoints with deterministic data, stragglers are flagged,
heartbeats age correctly."""

import tempfile
import time

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import ShardedLoader
from repro.runtime import Heartbeat, RetryPolicy, StragglerMonitor, run_resilient


def _loader():
    def batch_fn(step, shard, n_shards):
        return {"x": np.full((2,), float(step), np.float32)}

    return ShardedLoader(batch_fn)


def test_resilient_loop_recovers_from_failures():
    """Two injected crashes; the run must still process every step exactly
    once in order (state is a log of consumed step values)."""
    crashes = {7, 13}

    def failure_hook(step):
        if step in crashes:
            crashes.discard(step)
            raise RuntimeError("injected node failure")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        loader = _loader()

        def init_state():
            return {"sum": np.float32(0), "count": np.int32(0)}

        def step_fn(state, batch, step):
            assert batch["x"][0] == step, "loader must resume deterministically"
            return {"sum": state["sum"] + batch["x"][0],
                    "count": state["count"] + 1}

        final = run_resilient(
            init_state=init_state,
            step_fn=step_fn,
            loader=loader,
            manager=mgr,
            total_steps=20,
            policy=RetryPolicy(max_failures=5, checkpoint_every=5, backoff_s=0.01),
            failure_hook=failure_hook,
        )
        loader.close()
    # restarts may REPLAY steps after the last checkpoint (at-least-once is
    # inherent) but the state comes from the checkpoint, so the sum equals
    # the clean run's: sum over 0..19
    assert float(final["sum"]) == sum(range(20))
    assert int(final["count"]) == 20


def test_resilient_loop_gives_up_after_max_failures():
    def failure_hook(step):
        raise RuntimeError("permanently broken")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        loader = _loader()
        with pytest.raises(RuntimeError):
            run_resilient(
                init_state=lambda: {"n": np.int32(0)},
                step_fn=lambda s, b, i: {"n": s["n"] + 1},
                loader=loader,
                manager=mgr,
                total_steps=5,
                policy=RetryPolicy(max_failures=2, backoff_s=0.01),
                failure_hook=failure_hook,
            )
        loader.close()


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    for i in range(10):
        assert not mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.record(10, 0.5)  # 5x the EMA
    assert mon.flagged and mon.flagged[0][0] == 10
    # a straggler must not poison the EMA
    assert abs(mon.ema - 0.1) < 0.02


def test_heartbeat_ages():
    with tempfile.TemporaryDirectory() as d:
        hb = Heartbeat(f"{d}/hb", interval_s=0.05).start()
        time.sleep(0.12)
        assert hb.age() < 0.2
        hb.stop()
        time.sleep(0.15)
        assert hb.age() >= 0.1
