"""Per-architecture smoke tests (assigned deliverable): every arch as a
REDUCED config of the same family — one forward/train step on CPU asserting
output shapes + no NaNs.  Full configs are exercised only via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model

ALL_ARCHS = list_archs()


def _batch(cfg, b=2, t=16, seed=0):
    kt, kl = jax.random.split(jax.random.PRNGKey(seed))
    if cfg.input_mode == "embeds":
        batch = {
            "embeds": jax.random.normal(kt, (b, t, cfg.d_model)),
            "labels": jax.random.randint(kl, (b, t), 0, cfg.vocab),
        }
        if cfg.family == "audio":
            batch["mask"] = (jax.random.uniform(kt, (b, t)) < 0.3).astype(jnp.float32)
        return batch
    return {
        "tokens": jax.random.randint(kt, (b, t), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (b, t), 0, cfg.vocab),
    }


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, t = 2, 16
    logits = api.forward(params, _batch(cfg, b, t))
    assert logits.shape == (b, t, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_no_nans(arch):
    from repro.launch.train import TrainConfig, init_train_state, make_train_step

    cfg = get_config(arch + "-reduced")
    tcfg = TrainConfig(total_steps=10)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed and stayed finite
    leaves = jax.tree.leaves(state["params"])
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The registered full configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32, kv_heads=8,
                         d_ff=9728, vocab=151936, qk_norm=True),
        "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64, kv_heads=8,
                             d_ff=22016, vocab=102400),
        "olmo-1b": dict(n_layers=16, d_model=2048, n_heads=16, kv_heads=16,
                        d_ff=8192, vocab=50304, norm="nonparametric_ln"),
        "granite-8b": dict(n_layers=36, d_model=4096, n_heads=32, kv_heads=8,
                           d_ff=14336, vocab=49152),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25, kv_heads=5,
                           d_ff=5504, vocab=32001, parallel_ssm=True, ssm_state=16),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, kv_heads=2,
                            d_ff=8960, vocab=151936, rope="mrope"),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16, kv_heads=16,
                              d_ff=5120, vocab=504, causal=False),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab=65536,
                           rwkv=True),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab=102400, attn="mla", kv_lora_rank=512,
                                     n_experts=64, top_k=6, n_shared_experts=2,
                                     d_ff_expert=1408),
        "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    kv_heads=16, vocab=163840, n_experts=64,
                                    top_k=6, n_shared_experts=2, d_ff_expert=1408),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_long_500k_eligibility():
    from repro.models.registry import shape_applicable

    ok = {a for a in ALL_ARCHS if shape_applicable(get_config(a), "long_500k")[0]}
    assert ok == {"rwkv6-1.6b", "hymba-1.5b"}
    dec, reason = shape_applicable(get_config("hubert-xlarge"), "decode_32k")
    assert not dec and "encoder" in reason


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b", "rwkv6-1.6b"])
def test_param_count_analytic_close(arch):
    """Analytic parameter counts track actual reduced-model leaf counts."""
    cfg = get_config(arch + "-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    est = cfg.param_count()
    assert 0.5 < est / actual < 1.6, (est, actual)
