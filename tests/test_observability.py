"""Observability layer: layer-resolved attribution, A/B shadow serving,
OpenMetrics export, and the dashboard/report tooling.

Unit coverage (no model): merge_layer_moments associativity and layout
independence against pooled numpy moments; the windowed per-layer probe
section (fresh accumulators each roll); governor per-layer SLOs (breach
names the layer, config validation, first-match-wins ceilings);
OpenMetrics writer/parser round-trip including label escaping; the
fault-spec ``@LAYERS`` segment grammar; trace_report gap-cause
attribution of probe/shadow overhead on synthetic events; dashboard
smoke-render from synthetic events.

Integration coverage (reduced model): the shadow control experiment —
replaying through a shadow pack IDENTICAL to the primary must yield
token match 1.0, zero logits err-var, zero power delta, and verdict
keep-primary, without perturbing the primary's emitted tokens.
"""

import dataclasses
import json
import os
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.numerics import get_preset
from repro.quant.faults import FaultSpec
from repro.serving import (EngineMetrics, GovernorConfig, NumericsGovernor,
                           ServingEngine)
from repro.serving.metrics import merge_layer_moments
from repro.serving.prom import metric_value, parse_openmetrics, to_openmetrics

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import obs_dashboard  # noqa: E402
import trace_report  # noqa: E402

# ---------------------------------------------------------------------------
# per-layer moment merge (no model)
# ---------------------------------------------------------------------------


def _layer_map(rng, layers):
    out = {}
    for path in layers:
        xs = rng.normal(loc=rng.uniform(-1, 1), size=int(rng.integers(3, 40)))
        out[path] = (len(xs), float(np.mean(xs)), float(np.var(xs)))
    return out


def test_merge_layer_moments_associative_and_layout_independent():
    rng = np.random.default_rng(3)
    a = _layer_map(rng, ["blocks/0/q", "blocks/0/k"])
    b = _layer_map(rng, ["blocks/0/q", "blocks/1/o"])
    c = _layer_map(rng, ["blocks/1/o", "blocks/2/up"])
    left = merge_layer_moments(merge_layer_moments(a, b), c)
    right = merge_layer_moments(a, merge_layer_moments(b, c))
    flat = merge_layer_moments(a, b, c)
    # key union never depends on merge order or which map saw a layer first
    assert set(left) == set(right) == set(flat) == {
        "blocks/0/q", "blocks/0/k", "blocks/1/o", "blocks/2/up"}
    for path in left:
        for other in (right, flat):
            assert left[path][0] == other[path][0]
            assert left[path][1] == pytest.approx(other[path][1], rel=1e-9)
            assert left[path][2] == pytest.approx(other[path][2], rel=1e-9)


def test_merge_layer_moments_matches_pooled():
    rng = np.random.default_rng(7)
    xs, ys = rng.normal(size=50), rng.normal(loc=2.0, size=31)
    stat = lambda x: (len(x), float(np.mean(x)), float(np.var(x)))
    merged = merge_layer_moments({"L": stat(xs)}, {"L": stat(ys)})["L"]
    pooled = np.concatenate([xs, ys])
    assert merged[0] == len(pooled)
    assert merged[1] == pytest.approx(float(np.mean(pooled)))
    assert merged[2] == pytest.approx(float(np.var(pooled)))


# ---------------------------------------------------------------------------
# windowed per-layer probe section (no model)
# ---------------------------------------------------------------------------


def _probe_report(var, path="blocks/0/q"):
    return {"row": 0,
            "layers": {path: {"n": 4, "mean": 0.0, "var": var}},
            "logits": {"n": 4, "mean": 0.0, "var": var, "max_abs": 1.0}}


def test_window_probe_section_resets_each_roll():
    m = EngineMetrics(window_s=0.01)
    m.start_clock()
    m.record_step("decode", 0.5, 0, generated_tokens=1)  # arms the window
    m.record_probe(_probe_report(2.0))
    time.sleep(0.012)
    m.record_step("decode", 0.5, 0, generated_tokens=1)  # rolls window 1
    assert len(m.timeseries) == 1
    w1 = m.timeseries[0]
    assert w1["probe_runs"] == 1
    assert w1["probe_layers"]["blocks/0/q"] == pytest.approx(2.0)
    assert w1["probe_worst_layer"] == "blocks/0/q"
    # window 2 sees ONLY its own probes (fresh accumulators, not deltas
    # of the running totals — moments are not diffable)
    m.record_probe(_probe_report(8.0, path="blocks/1/k"))
    time.sleep(0.012)
    m.record_step("decode", 0.5, 0, generated_tokens=1)
    w2 = m.timeseries[1]
    assert set(w2["probe_layers"]) == {"blocks/1/k"}
    assert w2["probe_layers"]["blocks/1/k"] == pytest.approx(8.0)
    # ...while the lifetime snapshot still pools both layers
    layers = m.snapshot()["error_probe"]["layers"]
    assert set(layers) == {"blocks/0/q", "blocks/1/k"}


# ---------------------------------------------------------------------------
# governor per-layer SLOs (no model)
# ---------------------------------------------------------------------------


def _rungs(savings=(40.0, 10.0, 0.0)):
    from repro.numerics.ladder import LadderRung

    return [LadderRung(name=f"rung{i}", spec=None, power_saving_pct=s)
            for i, s in enumerate(savings)]


def test_governor_layer_slo_breach_names_layer():
    gov = NumericsGovernor(_rungs(), GovernorConfig(
        slo_err_var=1e9,  # global SLO never trips — the layer one must
        window_probes=2, clean_windows_to_relax=2,
        layer_slo={"blocks/0/*": 1e-4}))
    assert gov.observe_probe(_probe_report(1.0)) is None  # window open
    d = gov.observe_probe(_probe_report(1.0))
    assert d is not None and d.action == "escalate"
    dd = d.to_dict()
    assert dd["reason"] == "layer_slo_breach"
    assert dd["layer"] == "blocks/0/q"
    assert dd["err_var"] == pytest.approx(1.0)


def test_governor_layer_slo_ignores_unwatched_layers():
    gov = NumericsGovernor(_rungs(), GovernorConfig(
        slo_err_var=1e9, window_probes=1, clean_windows_to_relax=2,
        layer_slo={"blocks/7/*": 1e-4}))
    # huge error on a layer no pattern matches: no decision
    assert gov.observe_probe(_probe_report(50.0, path="blocks/0/q")) is None


def test_governor_layer_slo_first_match_wins():
    gov = NumericsGovernor(_rungs(), GovernorConfig(
        slo_err_var=1e9, window_probes=1, clean_windows_to_relax=2,
        layer_slo=(("blocks/0/q", 100.0), ("blocks/0/*", 1e-6))))
    # the exact pattern (ceiling 100) shadows the wildcard for this layer
    assert gov.observe_probe(_probe_report(1.0, path="blocks/0/q")) is None
    d = gov.observe_probe(_probe_report(1.0, path="blocks/0/k"))
    assert d is not None and d.to_dict()["layer"] == "blocks/0/k"


def test_governor_layer_slo_config_validation():
    with pytest.raises(ValueError, match="non-empty"):
        GovernorConfig(slo_err_var=1.0, layer_slo={"": 1.0})
    with pytest.raises(ValueError, match="must be"):
        GovernorConfig(slo_err_var=1.0, layer_slo={"blocks/*": -1.0})


# ---------------------------------------------------------------------------
# OpenMetrics writer/parser (no model)
# ---------------------------------------------------------------------------


def _fake_snapshot():
    m = EngineMetrics(numerics="int8")
    m.start_clock()
    for _ in range(10):
        m.record_step("decode", 0.75, 2, generated_tokens=1)
    m.finished = 3
    m.record_probe(_probe_report(0.25, path='blocks/0/"odd"\npath'))
    return m.snapshot()


def test_prom_round_trip():
    snap = _fake_snapshot()
    text = to_openmetrics(snap, labels={"engine": "e0"})
    assert text.endswith("# EOF\n")
    assert "# TYPE repro_generated_tokens gauge" in text
    parsed = parse_openmetrics(text)
    assert metric_value(parsed, "repro_generated_tokens",
                        engine="e0") == snap["generated_tokens"]
    assert metric_value(parsed, "repro_requests_finished") == 3
    # the per-layer series carries its label through escape + unescape
    assert metric_value(parsed, "repro_probe_layer_err_var",
                        layer='blocks/0/"odd"\npath') == pytest.approx(
        snap["error_probe"]["layers"]['blocks/0/"odd"\npath']["err_var"])
    # every emitted sample parses (no silent drops)
    samples = [l for l in text.splitlines()
               if l and not l.startswith("#")]
    assert len(parsed) == len(samples)


def test_prom_cli_require(tmp_path):
    path = tmp_path / "metrics.prom"
    path.write_text(to_openmetrics(_fake_snapshot()))
    from repro.serving import prom
    assert prom.main([str(path), "--require", "repro_generated_tokens"]) == 0
    assert prom.main([str(path), "--require", "repro_nope"]) == 1


# ---------------------------------------------------------------------------
# fault-spec @LAYERS grammar (no model)
# ---------------------------------------------------------------------------


def test_fault_spec_layer_segment_parse():
    s = FaultSpec.parse("dense-noise@1@blocks/0/*")
    assert (s.kind, s.every, s.start, s.stop) == ("dense-noise", 1, 0, None)
    assert s.layers == "blocks/0/*"
    s = FaultSpec.parse("dense-noise@2@10-30@blocks/0/o")
    assert (s.start, s.stop, s.layers) == (10, 30, "blocks/0/o")
    # a range-looking third segment stays a range, not a pattern
    s = FaultSpec.parse("spike@7@20-60")
    assert (s.start, s.stop, s.layers) == (20, 60, "*")
    s = FaultSpec.parse("nan@5")
    assert (s.start, s.stop, s.layers) == (0, None, "*")
    with pytest.raises(ValueError, match="at most one layer"):
        FaultSpec.parse("dense-noise@1@a/*@b/*")
    with pytest.raises(ValueError):
        FaultSpec.parse("dense-noise")


# ---------------------------------------------------------------------------
# gap-cause attribution + dashboard render on synthetic events (no model)
# ---------------------------------------------------------------------------


def _ev(kind, t, dur=0.0, rid=None, **data):
    return {"kind": kind, "rid": rid, "t": t, "dur": dur,
            "engine": "e0", "data": data}


def _decode_pair(rid, t0, gap, filler=None):
    """Two decode steps with a gap between them, optionally overlapped by
    a filler span; returns the events."""
    evs = [_ev("decode_step", t0, 0.01, rid=rid),
           _ev("decode_step", t0 + 0.01 + gap, 0.01, rid=rid)]
    if filler is not None:
        evs.append(filler)
    return evs


def test_gap_cause_probe_shadow_attribution():
    events = []
    # rid 1: gap fully covered by a probe forward
    events += _decode_pair(1, 0.0, 0.1,
                           _ev("probe", 0.02, 0.08, logits_err_var=0.1))
    # rid 2: gap covered by a shadow replay
    events += _decode_pair(2, 1.0, 0.2,
                           _ev("shadow", 1.02, 0.15, tokens=8, matches=8))
    # rid 3: nothing ran in the gap
    events += _decode_pair(3, 2.0, 0.3)
    # rid 4: a zero-duration probe marker must NOT claim the gap
    events += _decode_pair(4, 3.0, 0.25, _ev("probe", 3.05, 0.0))
    gaps = {g["rid"]: g["cause"]
            for g in trace_report._stall_attribution(events, top=10)}
    assert gaps[1] == "probe"
    assert gaps[2] == "shadow"
    assert gaps[3] == "scheduler_idle"
    assert gaps[4] == "scheduler_idle"


def test_gap_cause_precedence_over_probe():
    # prefill interference wins even when a probe also ran in the gap
    events = _decode_pair(1, 0.0, 0.2,
                          _ev("probe", 0.05, 0.1))
    events.append(_ev("prefill_chunk", 0.04, 0.05, rid=9))
    (gap,) = trace_report._stall_attribution(events, top=1)
    assert gap["cause"] == "prefill_interference"


def _synthetic_obs_events():
    events = []
    for i in range(3):
        events.append(_ev("metrics_window", 0.1 * (i + 1), 0.0,
                          t_rel=None, gen_tok_per_s=100.0 + i,
                          probe_runs=1, probe_logits_err_var=1e-4,
                          probe_max_layer_err_var=2e-4 * (i + 1),
                          probe_worst_layer="blocks/0/q",
                          probe_layers={"blocks/0/q": 2e-4 * (i + 1),
                                        "blocks/1/k": 1e-5},
                          tokens_by_numerics={"int8": 40},
                          modeled_mac_units=1000.0,
                          modeled_mac_units_saved=300.0,
                          modeled_power_saving_pct=30.0))
    events.append(_ev("shadow", 0.25, 0.02, rid=0, tokens=8, matches=7,
                      logits_err_var=1e-3))
    return events


def test_dashboard_smoke_render():
    doc, rendered = obs_dashboard.render(
        _synthetic_obs_events(),
        verdicts=[{"primary": "int8", "shadow": "serve-default",
                   "verdict": "keep-primary", "reason": "test",
                   "token_match_rate": 0.875, "tokens": 8,
                   "sampled_requests": 1, "logits_err_var": 1e-3,
                   "power_delta_pct": 34.6}])
    assert rendered["windows"] and rendered["heatmap"]
    assert rendered["shadow"] and rendered["power"]
    assert not rendered["governor"]  # no switches in these events
    assert "<svg" in doc and "blocks/0/q" in doc
    assert "keep-primary" in doc


def test_dashboard_cli_assert_sections(tmp_path):
    trace = tmp_path / "trace.jsonl"
    with open(trace, "w") as f:
        for e in _synthetic_obs_events():
            f.write(json.dumps({"kind": e["kind"], "t": e["t"],
                                "dur": e["dur"], "rid": e["rid"],
                                "engine": e["engine"], **e["data"]}) + "\n")
    out = tmp_path / "dash.html"
    assert obs_dashboard.main([str(trace), "--out", str(out),
                               "--assert-sections", "windows", "heatmap",
                               "shadow", "power"]) == 0
    assert "<html" in out.read_text()
    # governor section did not render -> assertion path returns nonzero
    assert obs_dashboard.main([str(trace), "--out", str(out),
                               "--assert-sections", "governor"]) == 2


# ---------------------------------------------------------------------------
# shadow control experiment (reduced model)
# ---------------------------------------------------------------------------


def test_engine_shadow_control_is_exact():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params_float = api.init(jax.random.PRNGKey(0))
    spec = get_preset("int8")
    params = build_serving_params(params_float, cfg, ServeConfig(spec=spec))

    def run(shadow):
        eng = ServingEngine(
            cfg, params,
            EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                         cache_dtype="float32",
                         shadow_fraction=1.0 if shadow else 0.0),
            api=api, numerics=spec.name,
            shadow_params=params if shadow else None,
            shadow_numerics=spec.name if shadow else None)
        rng = np.random.default_rng(11)
        for _ in range(3):
            eng.submit(rng.integers(0, cfg.vocab, 9).tolist(), 6)
        finished = eng.run()
        assert len(finished) == 3
        return [r.generated for r in finished], eng

    baseline, _ = run(shadow=False)
    shadowed, eng = run(shadow=True)
    # replay must not perturb the primary's own emitted tokens
    assert shadowed == baseline
    v = eng.shadow_verdict()
    assert v is not None and v["sampled_requests"] == 3
    # identical packs: perfect token match, zero error, zero power delta
    assert v["token_match_rate"] == 1.0
    assert v["logits_err_var"] == 0.0
    assert v["power_delta_pct"] == 0.0
    assert v["verdict"] == "keep-primary"
    snap = eng.metrics.snapshot()
    assert snap["shadow"]["sampled_requests"] == 3
    assert snap["shadow"]["token_match_rate"] == 1.0
