"""The declarative numerics subsystem: spec JSON round-trip, rule
precedence, segment-anchored matching (the SERVE_SKIP substring-fragility
regression), plan/apply equivalence with the legacy uniform-policy path,
auto-rule lowering, checkpoint spec persistence, and the serve CLI surface.
"""

import dataclasses
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.approx_linear import pack_params, packed_layer_paths
from repro.core.policy import INT8_EXACT, ApproxPolicy, uniform_policy
from repro.launch.serve import ServeConfig, build_serving_params, _cache_dt
from repro.models import build_model
from repro.numerics import (FLOAT, NumericsSpec, PackPlan, Rule,
                            apply_numerics, auto, get_preset, match_path,
                            paper_grid_specs, uniform_spec)


def _toy_params(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": {"table": jnp.zeros((32, 8))},
        "blocks": {
            "attn": {"q": {"w": jax.random.normal(k1, (8, 8)) * 0.3},
                     "router": {"w": jnp.zeros((8, 4))}},
            "denormalizer": {"w": jax.random.normal(k2, (8, 8)) * 0.3},
            "attn_norm": {"scale": jnp.ones(8)},
        },
        "lm_head": {"w": jax.random.normal(k3, (8, 32)) * 0.3,
                    "b": jnp.zeros(32)},
    }


# ---------------------------------------------------------------------------
# matching semantics
# ---------------------------------------------------------------------------


def test_glob_patterns_anchor_on_segments():
    # a bare pattern must match a WHOLE segment, not a substring of one
    assert match_path("norm", ("blocks", "0", "norm"))
    assert not match_path("norm", ("blocks", "0", "denormalizer"))
    assert match_path("*norm", ("blocks", "0", "attn_norm"))
    assert not match_path("*norm", ("blocks", "0", "denormalizer"))
    # path patterns: * stays within a segment, ** spans segments
    assert match_path("blocks/*/q", ("blocks", "7", "q"))
    assert not match_path("blocks/*/q", ("blocks", "7", "mlp", "q"))
    assert match_path("blocks/**/q", ("blocks", "7", "mlp", "q"))
    assert not match_path("blocks/*", ("blocks",))
    # regex rules search the joined path
    assert match_path(r"attn/(q|v)$", ("blocks", "attn", "q"), kind="regex")
    assert not match_path(r"attn/(q|v)$", ("blocks", "attn", "o"), kind="regex")


def test_serve_skip_substring_regression():
    """The old SERVE_SKIP substring test would keep a hypothetical
    `denormalizer` layer float because it contains "norm"; the preset's
    segment-anchored rules must pack it while still skipping router."""
    params = _toy_params()
    plan = get_preset("serve-default").resolve(params)
    by_path = {e.path: e for e in plan.entries}
    assert by_path["blocks/denormalizer"].policy is not None  # packed now
    assert by_path["blocks/attn/router"].policy is None  # still float
    assert by_path["blocks/attn/router"].rule == "router"


def test_rule_precedence_first_match_wins():
    spec = NumericsSpec(
        name="prec",
        rules=(Rule("lm_head", ApproxPolicy("truncated", 5)),
               Rule("lm_head", FLOAT),  # shadowed by the rule above
               Rule("**/q", FLOAT),
               Rule("router", FLOAT)),
        default=ApproxPolicy("perforated", 2))
    params = _toy_params()
    plan = spec.resolve(params)
    by_path = {e.path: e for e in plan.entries}
    assert by_path["lm_head"].policy == ApproxPolicy("truncated", 5)
    assert by_path["blocks/attn/q"].policy is None
    assert by_path["blocks/denormalizer"].policy == ApproxPolicy("perforated", 2)
    assert by_path["blocks/denormalizer"].rule == "default"


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def test_spec_json_round_trip_identical_plan():
    spec = NumericsSpec(
        name="rt",
        rules=(Rule("*norm", FLOAT, note="norms stay float"),
               Rule("router", FLOAT),
               Rule("lm_head", ApproxPolicy("recursive", 3, groups=2)),
               Rule(r"attn/(q|v)$", auto(budget=0.1), kind="regex")),
        default=ApproxPolicy("perforated", 2))
    spec2 = NumericsSpec.from_json(spec.to_json())
    assert spec2 == spec

    # auto-free subset resolves identically through the JSON round trip
    plain = dataclasses.replace(spec, rules=spec.rules[:3])
    params = _toy_params()
    plan = plain.resolve(params)
    plan2 = NumericsSpec.from_json(plain.to_json()).resolve(params)
    assert plan == plan2

    # the plan itself round-trips too (it travels in engine/checkpoint metadata)
    assert PackPlan.from_json(plan.to_json()) == plan


def test_spec_json_round_trip_on_real_model():
    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    spec = get_preset("serve-default")
    plan = spec.resolve(params)
    plan2 = NumericsSpec.from_json(spec.to_json()).resolve(params)
    assert plan == plan2
    assert len(plan.entries) > 0
    # resolution is pure shape work: it ran on an abstract eval_shape tree


def test_unknown_preset_and_bad_actions_raise():
    with pytest.raises(ValueError, match="unknown numerics preset"):
        get_preset("nope")
    with pytest.raises(ValueError, match="unknown candidate set"):
        auto(candidates="nope")
    with pytest.raises(ValueError):
        Rule("x", kind="substring")


# ---------------------------------------------------------------------------
# plan/apply equivalence with the legacy path
# ---------------------------------------------------------------------------


def test_apply_equivalent_to_legacy_uniform_policy():
    """spec.resolve + apply_numerics must be bit-identical to the old
    pack_params(uniform_policy(...)) call it replaces."""
    params = _toy_params()
    policy = ApproxPolicy("perforated", 2, use_cv=True)
    spec = uniform_spec(policy, rules=(Rule("router"),))
    new = apply_numerics(params, spec.resolve(params))
    old = pack_params(params, uniform_policy(policy, skip=("router",)))
    assert packed_layer_paths(new) == packed_layer_paths(old)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    from repro.core.approx_linear import dense
    y_new = dense(new["blocks"]["attn"]["q"], x)
    y_old = dense(old["blocks"]["attn"]["q"], x)
    assert np.array_equal(np.asarray(y_new), np.asarray(y_old))


def test_serve_default_token_identical_to_legacy_serving_params():
    """Acceptance: the serve-default preset through spec/plan/apply yields
    logits token-identical (in fact bit-identical) to the legacy
    policy-shorthand build_serving_params on olmo-1b-reduced."""
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab)

    legacy = build_serving_params(
        params, cfg, ServeConfig(policy=ApproxPolicy("perforated", 2,
                                                     use_cv=True)))
    spec = get_preset("serve-default")
    plan = spec.resolve(params)
    new = build_serving_params(params, cfg, ServeConfig(spec=spec), plan=plan)

    lg_legacy = api.forward(legacy, {"tokens": toks})
    lg_new = api.forward(new, {"tokens": toks})
    assert np.array_equal(np.asarray(lg_legacy), np.asarray(lg_new))
    assert np.array_equal(np.asarray(jnp.argmax(lg_legacy, -1)),
                          np.asarray(jnp.argmax(lg_new, -1)))


def test_apply_rejects_mismatched_plan():
    params = _toy_params()
    plan = get_preset("serve-default").resolve(params)
    del params["lm_head"]
    with pytest.raises(ValueError, match="does not match"):
        apply_numerics(params, plan)


def test_paper_grid_specs_match_paper_policies():
    from repro.core.policy import paper_policies

    specs = paper_grid_specs(use_cv=True)
    policies = paper_policies(use_cv=True)
    assert [s.default for s in specs] == policies
    assert all(not s.rules for s in specs)  # sweep packs every layer


# ---------------------------------------------------------------------------
# auto lowering
# ---------------------------------------------------------------------------


def test_auto_rule_lowers_to_concrete_policies():
    from repro.core.approx_linear import dense

    params = _toy_params()

    def apply_fn(p, x):  # a small dense stack routed through every layer
        h = dense(p["blocks"]["attn"]["q"], x)
        h = dense(p["blocks"]["denormalizer"], jax.nn.gelu(h))
        return dense(p["lm_head"], h)

    spec = NumericsSpec(
        name="auto-test",
        rules=(Rule("router", FLOAT), Rule("embed*", FLOAT)),
        default=auto(budget=0.15))
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
    plan = spec.resolve(params, apply_fn=apply_fn, calib_inputs=x)
    by_path = {e.path: e for e in plan.entries}
    # every auto layer lowered to a CONCRETE policy (auto never reaches apply)
    for e in plan.entries:
        assert e.policy is None or isinstance(e.policy, ApproxPolicy)
    assert by_path["blocks/attn/router"].policy is None
    lowered = [e for e in plan.entries if "auto" in e.rule]
    assert lowered and all(e.policy is not None for e in lowered)

    # budget respected end to end
    packed = apply_numerics(params, plan)
    ref = apply_fn(params, x)
    out = apply_fn(packed, x)
    rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-12))
    assert rel < 0.6, rel


def test_auto_requires_calibration_inputs():
    params = _toy_params()
    spec = NumericsSpec(name="a", default=auto(budget=0.1))
    with pytest.raises(ValueError, match="auto"):
        spec.resolve(params)


# ---------------------------------------------------------------------------
# spec persistence in checkpoints
# ---------------------------------------------------------------------------


def test_checkpoint_persists_numerics_spec():
    from repro.checkpoint import CheckpointManager, read_meta

    spec = get_preset("int8")
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(tree, 3, numerics=spec)
        restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
        assert step == 3
        assert np.array_equal(np.asarray(restored["w"]), tree["w"])
        assert mgr.numerics() == spec
        # raw metadata is readable without decoding tensors
        meta = read_meta(os.path.join(d, "step_0000000003",
                                      "shard_00000.ckpt"))
        assert meta["numerics"]["name"] == "int8"
        # steps saved without a spec report None
        mgr.save(tree, 4)
        assert mgr.numerics(4) is None


def test_save_pytree_meta_reserved_key():
    from repro.checkpoint import save_pytree

    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="reserved"):
            save_pytree({"w": np.zeros(2)}, os.path.join(d, "x.ckpt"),
                        meta={"codec": "zstd"})


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------


def test_cache_dtype_error_lists_choices():
    with pytest.raises(ValueError) as e:
        _cache_dt(ServeConfig(cache_dtype="fp8"))
    msg = str(e.value)
    assert "fp8" in msg and "bfloat16" in msg and "int8" in msg


def test_plan_cli_runs_without_packing(capsys):
    from repro.launch.serve import main

    main(["plan", "--arch", "olmo-1b-reduced"])
    out = capsys.readouterr().out
    assert "perforated(m=2)+cv(g=1)" in out
    assert "packed" in out

    main(["plan", "--arch", "olmo-1b-reduced", "--preset", "int8", "--json"])
    out = capsys.readouterr().out
    plan = PackPlan.from_dict(json.loads(out))
    assert plan.spec_name == "int8"
    assert all(e.policy in (None, INT8_EXACT) for e in plan.entries)


def test_engine_metrics_expose_numerics_label():
    from repro.configs.base import EngineConfig
    from repro.serving import ServingEngine

    cfg = get_config("olmo-1b-reduced")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    spec = get_preset("serve-default")
    packed = build_serving_params(params, cfg, ServeConfig(spec=spec))
    eng = ServingEngine(cfg, packed,
                        EngineConfig(slots=2, max_len=32, prefill_chunk=8),
                        numerics=spec.name)
    eng.submit([1, 2, 3], 2)
    eng.run()
    assert eng.metrics.snapshot()["numerics"] == spec.name
    eng.reset_metrics()  # warmup reset keeps the label
    assert eng.metrics.snapshot()["numerics"] == spec.name
