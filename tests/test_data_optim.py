"""Data pipeline determinism + optimizer behavior + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ShardedLoader, SyntheticLMConfig, VisionConfig
from repro.data.synthetic import lm_batch
from repro.data.vision import make_sample, make_vision_dataset
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_decompress,
    compressor_init,
    global_norm,
)


def test_lm_batch_deterministic_and_shard_disjoint():
    cfg = SyntheticLMConfig(vocab=512, seq_len=64, batch=4)
    a = lm_batch(cfg, 3, shard=0, n_shards=2)
    b = lm_batch(cfg, 3, shard=0, n_shards=2)
    c = lm_batch(cfg, 3, shard=1, n_shards=2)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_stream_is_learnable_structure():
    """The synthetic stream must be compressible: a bigram model fit on it
    beats the uniform entropy by a wide margin."""
    cfg = SyntheticLMConfig(vocab=128, seq_len=512, batch=8)
    toks = lm_batch(cfg, 0)["tokens"]
    counts = np.ones((128, 128))
    for row in toks:
        np.add.at(counts, (row[:-1], row[1:]), 1)
    probs = counts / counts.sum(1, keepdims=True)
    test = lm_batch(cfg, 1)["tokens"]
    nll = -np.log(probs[test[:, :-1], test[:, 1:]]).mean()
    assert nll < 0.8 * np.log(128), nll


def test_loader_skip_to_resume():
    cfg = SyntheticLMConfig(vocab=64, seq_len=16, batch=2)
    loader = ShardedLoader(lambda s, sh, ns: lm_batch(cfg, s, sh, ns))
    seq = [next(loader)["tokens"] for _ in range(4)]
    loader.skip_to(2)
    again = next(loader)["tokens"]
    loader.close()
    assert np.array_equal(seq[2], again)


def test_vision_dataset_separable():
    """Classes must be distinguishable: a nearest-centroid classifier on raw
    pixels beats chance by a big margin."""
    cfg = VisionConfig(num_classes=10)
    xtr, ytr = make_vision_dataset(cfg, "train", 300)
    xte, yte = make_vision_dataset(cfg, "test", 150)
    cents = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
    d = ((xte[:, None] - cents[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == yte).mean()
    assert acc > 0.5, acc


def test_vision_deterministic():
    cfg = VisionConfig(num_classes=100)
    img1, l1 = make_sample(cfg, "train", 42)
    img2, l2 = make_sample(cfg, "train", 42)
    assert l1 == l2 and np.array_equal(img1, img2)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, cfg, 0.1)
    assert float(loss(params)) < 1e-3


def test_adamw_skips_decay_on_norms():
    params = {"w": jnp.ones((4, 4)), "attn_norm": {"scale": jnp.ones((4,))}}
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0)  # only decay would move params
    state = adamw_init(params, cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _ = adamw_update(params, zeros, state, cfg, 0.0)
    assert np.allclose(np.asarray(p2["attn_norm"]["scale"]), 1.0)
    assert np.allclose(np.asarray(p2["w"]), 1.0)  # lr==0: no update at all


def test_grad_compression_error_feedback_unbiased():
    """Error feedback: the ACCUMULATED compressed signal converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))
    state = compressor_init({"g": g_true})
    total = np.zeros(64)
    n = 50
    for _ in range(n):
        deq, state = compress_decompress({"g": g_true}, state)
        total += np.asarray(deq["g"])
    err = np.abs(total / n - np.asarray(g_true)).max()
    assert err < 0.02, err


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2.0}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
