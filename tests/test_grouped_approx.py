"""Per-expert approximate quantized GEMMs (the MoE serving path): the
grouped/ragged execution must match running each expert's tokens through the
single-layer quantized path one by one."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx_linear import pack_dense, QuantizedDense
from repro.core.grouped_approx import grouped_quantized_dense, grouped_quantized_swiglu
from repro.core.policy import ApproxPolicy
from repro.quant.quantize import quantized_linear, QuantParams


@pytest.mark.parametrize("mode,m", [("exact", 0), ("perforated", 2),
                                    ("recursive", 3), ("truncated", 5)])
def test_grouped_matches_per_expert(mode, m):
    rng = np.random.default_rng(0)
    E, k, n = 4, 32, 16
    w = jnp.asarray(rng.normal(0, 0.1, (E, k, n)), jnp.float32)
    qd = pack_dense({"w": w}, ApproxPolicy(mode, m), (-4.0, 4.0))
    gs = jnp.asarray([3, 0, 5, 2], jnp.int32)
    M = int(gs.sum())
    xs = jnp.asarray(rng.normal(0, 1.0, (M, k)), jnp.float32)

    out = np.asarray(grouped_quantized_dense(qd, xs, gs))

    # reference: per-expert quantized_linear on that expert's rows
    row = 0
    for e in range(E):
        cnt = int(gs[e])
        if cnt == 0:
            continue
        pack_e = jax.tree.map(lambda a: a[e], qd.pack)
        qp_e = QuantParams(qd.a_qp.scale[e], qd.a_qp.zero_point[e])
        ref = np.asarray(quantized_linear(
            xs[row:row+cnt], pack_e, qp_e, mode, m, use_cv=True))
        np.testing.assert_allclose(out[row:row+cnt], ref, rtol=1e-5, atol=1e-3)
        row += cnt


def test_moe_with_packed_experts_runs():
    """End to end: pack a MoE layer's expert stacks and run moe_apply."""
    from repro.nn import moe as moelib
    from repro.numerics import Rule, apply_numerics, uniform_spec

    cfg = moelib.MoEConfig(d_model=32, d_ff_expert=16, n_experts=8, top_k=2,
                           n_shared=1)
    p = moelib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    ref = moelib.moe_apply(p, x, cfg)

    spec = uniform_spec(ApproxPolicy("perforated", 1),
                        rules=(Rule("router"),))
    packed = apply_numerics(p, spec.resolve(p), default_range=(-6.0, 6.0))
    assert isinstance(packed["experts"]["gate"], QuantizedDense)
    out = moelib.moe_apply(packed, x, cfg)
    assert out.shape == ref.shape and bool(jnp.isfinite(out).all())
    # mild approximation + CV: outputs track the float MoE
    rel = float(jnp.abs(out - ref).mean() / (jnp.abs(ref).mean() + 1e-9))
    assert rel < 0.25, rel
