"""Paged KV subsystem: allocator/prefix-cache units, copy-on-write rules,
and the core contract — the paged engine is greedy-token-identical to the
contiguous engine and the sequential baseline, across block sizes
(including ones that do not divide the prefill chunk), cursor-at-boundary
writes, shared-prefix attachment, and release-while-shared refcounts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.models import build_model
from repro.serving import RequestState, ServingEngine
from repro.serving.paged import (BlockAllocator, PagedKVPool, PrefixCache,
                                 block_hashes)

# ---------------------------------------------------------------------------
# units (no model)
# ---------------------------------------------------------------------------


def test_allocator_refcounts_and_null_block():
    a = BlockAllocator(5)  # ids 1..4 usable; 0 reserved NULL
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [1, 2, 3, 4] and 0 not in got
    assert a.n_free == 0 and a.n_used == 4 and a.peak_used == 4
    with pytest.raises(RuntimeError):
        a.alloc()
    a.incref(got[0])
    assert not a.decref(got[0])  # still shared
    assert a.decref(got[0])  # now freed
    assert a.n_free == 1 and a.refcount(got[0]) == 0
    assert a.alloc() == got[0]  # recycled


def test_block_hashes_chain_commits_to_prefix():
    h1 = block_hashes([1, 2, 3, 4, 5, 6, 7], 4)
    assert len(h1) == 1  # only FULL blocks are hashed
    h2 = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert h1[0] == h2[0]  # same first block
    h3 = block_hashes([0, 2, 3, 4, 9, 9, 9, 9], 4)
    # a differing token in block 0 changes EVERY downstream hash
    assert h3[0] != h2[0] and h3[1] != h2[1]
    assert block_hashes([1, 2, 3], 4) == []


def test_prefix_cache_match_register_lru():
    a = BlockAllocator(8)
    c = PrefixCache()
    hs = block_hashes(list(range(12)), 4)  # 3 full blocks
    bids = [a.alloc() for _ in range(3)]
    for h, b in zip(hs, bids):
        assert c.register(h, b, a)
        assert not c.register(h, b, a)  # idempotent, refresh only
    assert a.refcount(bids[0]) == 2  # cache holds its own ref
    assert c.match(hs) == bids
    # a diverging prompt matches only the shared full-block prefix
    other = block_hashes(list(range(8)) + [99, 99, 99, 99], 4)
    assert c.match(other) == bids[:2]
    # entries whose blocks live requests still hold are NOT evictable —
    # freeing them reclaims nothing and would only destroy reuse
    assert not c.evict_lru(a)
    # drop our "request" refs: blocks 0-1 become cache-only, hence
    # freeable; match() must not have skewed recency, so with touch()
    # refreshing blocks 0-1 the eviction order starts at block 2
    for b in bids:
        a.decref(b)
    c.touch(hs[:2])
    assert c.evict_lru(a)
    assert c.match(hs) == bids[:2]  # chain now stops before block 2
    assert a.refcount(bids[2]) == 0  # freed: only the cache held it
    assert c.evict_lru(a) and c.evict_lru(a)
    assert c.match(hs) == [] and not c.evict_lru(a)
    assert a.refcount(bids[0]) == 0 and a.n_free == 7


def _engine(cfg, params, layout, bs=8, blocks=0, prefix=True, slots=3,
            max_len=64, chunk=16, mixed=True):
    return ServingEngine(cfg, params, EngineConfig(
        slots=slots, max_len=max_len, prefill_chunk=chunk,
        cache_dtype="float32", mixed_batches=mixed, kv_layout=layout,
        kv_block_size=bs, kv_blocks=blocks, prefix_cache=prefix))


@pytest.fixture(scope="module")
def olmo():
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


def _baseline(api, params, prompt, gen, max_len, decode=None):
    decode = decode or jax.jit(api.decode_step)
    logits, cache = api.prefill(params, {"tokens": jnp.asarray([prompt])},
                                max_len=max_len, cache_dtype=jnp.float32)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, jnp.asarray([[tok]]), cache)
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# pool-level behavior
# ---------------------------------------------------------------------------


class _Req:
    def __init__(self, rid, prompt, gen):
        self.rid, self.prompt, self.max_new_tokens = rid, prompt, gen
        self.prefix_hit_tokens = 0
        self.block_hashes = None


def test_pool_reserves_upfront_and_stalls_on_block_exhaustion(olmo):
    cfg, api, _ = olmo
    ecfg = EngineConfig(slots=4, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="paged",
                        kv_block_size=8, kv_blocks=10)  # 10 usable blocks
    pool = PagedKVPool(api, ecfg)
    # 40 prompt + 8 gen = 6 blocks reserved up front
    s0 = pool.acquire_for(_Req(0, list(range(1, 41)), 8))
    assert s0 is not None and pool.allocator.n_used == 6
    # second request needs 6 more but only 4 remain -> capacity stall,
    # even though 3 slots are still free
    assert pool.n_free == 3
    assert pool.acquire_for(_Req(1, list(range(1, 41)), 8)) is None
    pool.release(s0)
    assert pool.allocator.n_used == 0  # no prefix published: all freed
    assert pool.acquire_for(_Req(2, list(range(1, 41)), 8)) is not None


def test_pool_cow_swaps_shared_block_and_keeps_original(olmo):
    cfg, api, _ = olmo
    ecfg = EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="paged",
                        kv_block_size=8)
    pool = PagedKVPool(api, ecfg)
    prompt = list(range(1, 17))  # exactly 2 full blocks
    r0 = _Req(0, prompt, 4)
    s0 = pool.acquire_for(r0)
    pool.advance(np.asarray([16, 0]))  # pretend the prefill ran
    pool.register_prefix(s0, len(prompt), 16)
    # identical prompt: full match, capped one token early -> attaches both
    # blocks plus one COW reserve
    r1 = _Req(1, prompt, 4)
    s1 = pool.acquire_for(r1)
    assert r1.prefix_hit_tokens == 15
    shared = pool._tables[s1].blocks[1]
    assert shared == pool._tables[s0].blocks[1]
    assert pool.allocator.refcount(shared) == 3  # owner + cache + sharer
    # the re-prefill of token 15 writes block 1 -> COW must swap it
    pool.ensure_writable(s1, 1)
    assert pool.cow_copies == 1
    assert pool._tables[s1].blocks[1] != shared  # diverged physically
    assert pool._tables[s0].blocks[1] == shared  # original untouched
    assert pool.allocator.refcount(shared) == 2
    assert pool._pending_copies and pool._pending_copies[0][0] == shared
    pool.flush_copies()
    assert not pool._pending_copies
    # owned blocks (including the unused reserve) all return on release
    used_before = pool.allocator.n_used
    pool.release(s1)
    assert pool.allocator.n_used < used_before


def test_pool_release_while_shared_keeps_blocks_alive(olmo):
    cfg, api, _ = olmo
    ecfg = EngineConfig(slots=2, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="paged",
                        kv_block_size=8)
    pool = PagedKVPool(api, ecfg)
    prompt = list(range(1, 25))  # 3 full blocks
    r0 = _Req(0, prompt, 2)
    s0 = pool.acquire_for(r0)
    pool.advance(np.asarray([24, 0]))
    pool.register_prefix(s0, 24, 24)
    r1 = _Req(1, prompt + [99] * 8, 2)
    s1 = pool.acquire_for(r1)
    assert r1.prefix_hit_tokens == 24
    shared = list(pool._tables[s0].blocks[:3])
    assert pool._tables[s1].blocks[:3] == shared
    pool.release(s0)  # writer leaves first
    # cache ref + sharer ref keep every shared block alive
    assert all(pool.allocator.refcount(b) == 2 for b in shared)
    pool.release(s1)
    assert all(pool.allocator.refcount(b) == 1 for b in shared)  # cache only
    # evicting the cache entries finally frees them
    while pool.prefix.evict_lru(pool.allocator):
        pass
    assert all(pool.allocator.refcount(b) == 0 for b in shared)
    assert pool.allocator.n_used == 0


def test_paged_pool_rejects_recurrent_arch():
    rcfg = get_config("rwkv6-1.6b-reduced")
    rapi = build_model(rcfg)
    with pytest.raises(NotImplementedError):
        PagedKVPool(rapi, EngineConfig(slots=2, max_len=32,
                                       kv_layout="paged"))


def test_engine_rejects_unknown_layout(olmo):
    cfg, api, params = olmo
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, EngineConfig(kv_layout="interleaved"))


# ---------------------------------------------------------------------------
# token identity: paged vs contiguous vs sequential
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_size", [8, 12, 16],
                         ids=["bs8", "bs12-undivides-chunk", "bs16"])
def test_paged_token_identical_across_block_sizes(olmo, block_size):
    """The mixed-length trace (multi-chunk prompts, heterogeneous gens)
    must come out token-identical from the paged engine for any block
    size — including 12, which divides neither the chunk (16) nor
    max_len (64), so chunk writes straddle block boundaries."""
    cfg, api, params = olmo
    rng = np.random.default_rng(3)
    trace = [(rng.integers(0, cfg.vocab, p).tolist(), g)
             for p, g in [(3, 4), (17, 6), (33, 5), (9, 8), (40, 3)]]
    eng = _engine(cfg, params, "paged", bs=block_size)
    reqs = [eng.submit(p, g) for p, g in trace]
    assert len(eng.run()) == len(trace)
    assert eng.compile_count() <= 2
    decode = jax.jit(api.decode_step)
    for r, (prompt, gen) in zip(reqs, trace):
        assert r.generated == _baseline(api, params, prompt, gen, 64, decode), \
            (block_size, r.rid)


def test_paged_mixed_batches_token_identical(olmo):
    """Decode rows riding chunk calls (mixed batches) while other slots
    prefill — the PR 4 scenario — must hold under the paged layout too,
    in both scheduler modes."""
    cfg, api, params = olmo
    rng = np.random.default_rng(23)
    prompt_a = rng.integers(0, cfg.vocab, 6).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 35).tolist()
    outs = {}
    for mixed in (True, False):
        eng = _engine(cfg, params, "paged", bs=8, slots=2, mixed=mixed)
        ra = eng.submit(prompt_a, 12)
        eng.step()  # A decodes while B's multi-chunk prefill arrives
        assert ra.state == RequestState.DECODE
        rb = eng.submit(prompt_b, 5)
        eng.run()
        assert eng.compile_count() <= 2
        outs[mixed] = [ra.generated, rb.generated]
    assert outs[True] == outs[False]
    decode = jax.jit(api.decode_step)
    assert outs[True][0] == _baseline(api, params, prompt_a, 12, 64, decode)
    assert outs[True][1] == _baseline(api, params, prompt_b, 5, 64, decode)


def test_paged_cursor_at_block_boundary_writes(olmo):
    """Direct decode_slots check: a chunk that ends exactly on a block
    boundary, then single-token decode writes that start a fresh block.
    The paged cache must agree with the contiguous cache bit for bit on
    the logical view."""
    cfg, api, params = olmo
    BS, S = 8, 32
    nb = S // BS
    cont = api.init_slot_cache(2, S, jnp.float32)
    paged = api.init_paged_cache(2 * nb + 1, BS, 2, jnp.float32)
    bt = np.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    rng = np.random.default_rng(0)
    # chunk of 16 = exactly 2 blocks, cursor lands on boundary 16
    toks = np.zeros((2, 16), np.int32)
    toks[0] = rng.integers(0, cfg.vocab, 16)
    lg_c, cont = api.decode_slots(params, jnp.asarray(toks), cont,
                                  jnp.asarray([16, 0], np.int32))
    lg_p, paged = api.decode_slots(params, jnp.asarray(toks), paged,
                                   jnp.asarray([16, 0], np.int32),
                                   block_tables=jnp.asarray(bt))
    np.testing.assert_allclose(np.asarray(lg_c[0]), np.asarray(lg_p[0]),
                               rtol=1e-5, atol=1e-5)
    # two decode tokens: positions 16 (first col of block 3) and 17
    for _ in range(2):
        t = np.zeros((2, 1), np.int32)
        t[0] = rng.integers(0, cfg.vocab)
        _, cont = api.decode_slots(params, jnp.asarray(t), cont,
                                   jnp.asarray([1, 0], np.int32))
        _, paged = api.decode_slots(params, jnp.asarray(t), paged,
                                    jnp.asarray([1, 0], np.int32),
                                    block_tables=jnp.asarray(bt))
    assert int(paged["lengths"][0]) == 18
    # reassemble slot 0's logical K/V from its blocks and compare
    for key in ("k", "v"):
        pool = np.asarray(paged[key])  # (L, NB, H, BS, d)
        view = np.concatenate([pool[:, b] for b in bt[0]], axis=2)
        np.testing.assert_array_equal(view, np.asarray(cont[key])[:, 0])


def test_paged_mla_arch_token_identical():
    """MLA latent/rope paging plus unscanned first-dense-layer leaves
    (deepseek-v2-lite) go through the same gather/scatter path."""
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b-reduced"),
                              compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    trace = [(rng.integers(0, cfg.vocab, p).tolist(), g)
             for p, g in [(7, 4), (21, 5), (12, 3)]]
    outs = {}
    for layout in ("contiguous", "paged"):
        eng = _engine(cfg, params, layout, bs=12, slots=2, max_len=48)
        reqs = [eng.submit(p, g) for p, g in trace]
        assert len(eng.run()) == len(trace)
        outs[layout] = [r.generated for r in reqs]
    assert outs["contiguous"] == outs["paged"]


# ---------------------------------------------------------------------------
# copy-on-write + prefix reuse through the engine
# ---------------------------------------------------------------------------


def test_engine_prefix_hit_skips_prefill_and_cow_diverges(olmo):
    """Warmed shared prompt: a suffix request attaches block-aligned (no
    COW); a FULL-prompt request attaches everything, re-prefills one
    capped token into a shared block, and must trigger exactly the COW
    path — all token-identical to the sequential baseline, with the
    original cached blocks still matchable afterwards."""
    cfg, api, params = olmo
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, 32).tolist()  # 4 blocks of 8
    eng = _engine(cfg, params, "paged", bs=8)
    warm = eng.submit(shared, 2)
    eng.run()
    assert warm.prefix_hit_tokens == 0
    base_shared = _baseline(api, params, shared, 4, 64)
    assert warm.generated == base_shared[:2]

    full = eng.submit(shared, 4)  # identical prompt
    suffixed = eng.submit(shared + rng.integers(0, cfg.vocab, 5).tolist(), 4)
    eng.run()
    assert full.prefix_hit_tokens == 31  # capped one token early
    assert suffixed.prefix_hit_tokens == 32  # whole shared prefix
    assert eng.pool.cow_copies == 1  # only the capped re-prefill copies
    assert full.generated == base_shared
    assert suffixed.generated == _baseline(api, params, suffixed.prompt, 4, 64)
    snap = eng.metrics.snapshot()
    assert snap["prefix_hits"] == 2 and snap["prefix_hit_tokens"] == 63
    assert snap["cow_copies"] == 1
    assert snap["kv_layout"] == "paged"
    assert snap["mean_block_utilization"] is not None
    assert 0 <= snap["mean_block_fragmentation"] <= 1
    # cache survived the COW: a third full-prompt request still hits
    again = eng.submit(shared, 3)
    eng.run()
    assert again.prefix_hit_tokens == 31
    assert again.generated == base_shared[:3]


def test_engine_concurrent_sharers_decode_correctly(olmo):
    """Two requests sharing a warmed prefix decode SIMULTANEOUSLY: their
    batch rows gather the same physical blocks, write only their own
    fresh blocks, and both match the baseline (the duplicate-scatter
    safety argument, exercised)."""
    cfg, api, params = olmo
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab, 16).tolist()
    eng = _engine(cfg, params, "paged", bs=8)
    eng.submit(shared, 2)
    eng.run()
    sufs = [rng.integers(0, cfg.vocab, 3).tolist() for _ in range(2)]
    rs = [eng.submit(shared + s, 6) for s in sufs]
    eng.run()
    assert all(r.prefix_hit_tokens == 16 for r in rs)
    for r in rs:
        assert r.generated == _baseline(api, params, r.prompt, 6, 64)


def test_engine_no_capacity_stall_metric(olmo):
    """A block pool too small for two concurrent residents: the second
    request waits (stall counter, NOT a rejection) and completes once the
    first releases its blocks."""
    cfg, api, params = olmo
    # 40+8 -> 6 blocks each; 8 usable blocks hold one resident at a time
    eng = _engine(cfg, params, "paged", bs=8, blocks=8, prefix=False,
                  slots=2)
    rng = np.random.default_rng(5)
    ra = eng.submit(rng.integers(0, cfg.vocab, 40).tolist(), 8)
    rb = eng.submit(rng.integers(0, cfg.vocab, 40).tolist(), 8)
    fin = eng.run()
    assert {r.rid for r in fin} == {ra.rid, rb.rid}
    snap = eng.metrics.snapshot()
    assert snap["no_capacity_stalls"] > 0
    assert snap["requests_rejected"] == 0
    decode = jax.jit(api.decode_step)
    for r in (ra, rb):
        assert r.generated == _baseline(api, params, r.prompt, 8, 64, decode)


def test_engine_rejects_request_larger_than_block_pool(olmo):
    """A request whose worst-case block need exceeds the WHOLE pool can
    never be placed; it must be REJECTED at submit (leaving it queued
    would wedge the FIFO head in an eternal capacity stall and hang
    run())."""
    cfg, api, params = olmo
    eng = _engine(cfg, params, "paged", bs=8, blocks=5, prefix=False,
                  slots=2)
    rng = np.random.default_rng(4)
    # 40 + 8 = 6 blocks > 5 in the pool
    r = eng.submit(rng.integers(0, cfg.vocab, 40).tolist(), 8)
    assert r.state == RequestState.REJECTED
    assert "KV blocks" in r.reject_reason
    # a fitting request still serves normally
    ok = eng.submit(rng.integers(0, cfg.vocab, 24).tolist(), 8)
    assert len(eng.run()) == 1 and ok.finished
    # the pool itself also refuses a direct oversized placement
    with pytest.raises(ValueError):
        eng.pool.acquire_for(_Req(99, list(range(1, 41)), 8))


def test_eviction_skips_entries_still_referenced(olmo):
    """_make_room under pressure must not drain the prefix cache: entries
    whose blocks live requests hold free nothing when evicted, so they
    are skipped and stay matchable."""
    cfg, api, _ = olmo
    ecfg = EngineConfig(slots=3, max_len=64, prefill_chunk=16,
                        cache_dtype="float32", kv_layout="paged",
                        kv_block_size=8, kv_blocks=10)
    pool = PagedKVPool(api, ecfg)
    # resident publishes 2 blocks and KEEPS them (still active)
    resident = _Req(0, list(range(1, 17)), 8)  # 3 blocks
    s0 = pool.acquire_for(resident)
    pool.advance(np.asarray([16, 0, 0]))
    pool.register_prefix(s0, 16, 16)
    assert len(pool.prefix) == 2
    # released request publishes 2 freeable blocks
    other = _Req(1, [7] * 16, 8)  # 3 blocks
    s1 = pool.acquire_for(other)
    pool.advance(np.asarray([0, 16, 0]))
    pool.register_prefix(s1, 16, 16)
    pool.release(s1)
    assert len(pool.prefix) == 4 and pool.allocator.n_used == 5
    # 5 in use, 5 free; this needs 6 -> evicts ONLY the freeable entries
    big = _Req(2, list(range(100, 140)), 8)
    assert pool.acquire_for(big) is not None
    assert pool.prefix_evictions == 1
    # the resident's entries survived and still match
    assert pool.prefix.match(resident.block_hashes) == \
        pool._tables[s0].blocks[:2]


def test_prefix_cache_eviction_under_pressure(olmo):
    """When fresh allocation cannot be satisfied, cold prefix-cache
    entries are evicted (counted) to make room — and the engine keeps
    serving correctly."""
    cfg, api, params = olmo
    eng = _engine(cfg, params, "paged", bs=8, blocks=10, slots=2)
    rng = np.random.default_rng(19)
    # distinct prompts, each publishing 3 blocks, overflowing 10 blocks
    prompts = [rng.integers(0, cfg.vocab, 24).tolist() for _ in range(4)]
    for p in prompts:
        r = eng.submit(p, 2)
        eng.run()
        assert r.finished
    assert eng.pool.prefix_evictions > 0
    assert eng.metrics.snapshot()["prefix_evictions"] > 0
