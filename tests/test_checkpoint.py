"""Checkpointing: exact restore, async commit, crash consistency, retention,
and elastic re-mesh restore (multi-device, run in a subprocess)."""

import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.normal(size=(16, 8)).astype(np.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)},
        "opt": {"step": np.int32(7), "m": rng.normal(size=(16, 8)).astype(np.float32)},
    }


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_save_restore_exact():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        t = _tree()
        mgr.save(t, 5)
        restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, t))
        assert step == 5
        _assert_tree_equal(t, restored)


def test_async_save_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(_tree(s), s, blocking=False)
        mgr.wait()
        mgr.save(_tree(5), 5)  # triggers gc
        assert mgr.steps() == [4, 5]
        restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
        assert step == 5
        _assert_tree_equal(_tree(5), restored)


def test_crash_consistency_ignores_incomplete():
    """A step dir without the DONE marker (crash mid-commit) is invisible."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(_tree(1), 1)
        # simulate a crash: shard file written but no DONE marker
        broken = os.path.join(d, "step_0000000002")
        os.makedirs(broken)
        save_pytree(_tree(2), os.path.join(broken, "shard_00000.ckpt"))
        assert mgr.latest_step() == 1
        restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
        assert step == 1
        _assert_tree_equal(_tree(1), restored)


def test_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.ckpt")
        save_pytree({"w": np.zeros((4, 4))}, path)
        with pytest.raises(ValueError):
            load_pytree({"w": jnp.zeros((5, 4))}, path)


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager, restore_with_sharding
    from repro.launch.mesh import make_test_mesh

    d = sys.argv[1]
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,), jnp.float32)}

    # save under mesh A (2x4)
    mesh_a = make_test_mesh((2, 4), ("data", "model"))
    sh_a = {"w": NamedSharding(mesh_a, P("data", "model")),
            "b": NamedSharding(mesh_a, P("model"))}
    placed = jax.tree.map(jax.device_put, tree, sh_a)
    mgr = CheckpointManager(d)
    mgr.save(placed, 3)

    # elastic restore under mesh B (8x1) — simulated re-provisioned cluster
    mesh_b = make_test_mesh((8, 1), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("data", "model")),
            "b": NamedSharding(mesh_b, P())}
    restored, step = restore_with_sharding(mgr, jax.tree.map(jnp.zeros_like, tree), sh_b)
    assert step == 3
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.mesh.shape["data"] == 8
    print("ELASTIC_OK")
""")


def test_elastic_remesh_restore():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c", ELASTIC_SCRIPT, d],
            capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        )
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
