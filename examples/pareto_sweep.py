"""Paper Fig. 10 in miniature: sweep the paper-grid numerics specs x
{CV, no-CV} on one trained CNN and print the accuracy-loss vs
modeled-power Pareto points.

Trains (or loads the cached) resnet44 on the procedural dataset first —
expect a few minutes cold, seconds warm.

    PYTHONPATH=src python examples/pareto_sweep.py
"""

from benchmarks.tables2_4_accuracy import (
    N_CALIB, _accuracy, _calibrate, _train_cnn)
from repro.configs.cnn_suite import get_cnn
from repro.core import cost_model as cm
from repro.data.vision import VisionConfig, make_vision_dataset
from repro.numerics import apply_numerics, paper_grid_specs


def main() -> None:
    vcfg = VisionConfig(num_classes=10)
    xtr, ytr = make_vision_dataset(vcfg, "train", 4000)
    xte, yte = make_vision_dataset(vcfg, "test", 1000)
    cfg = get_cnn("resnet44", 10)
    params = _train_cnn("resnet44", cfg, xtr, ytr)
    acc_f = _accuracy(params, cfg, xte, yte)
    ranges = _calibrate(params, cfg, xtr[:N_CALIB])
    print(f"float accuracy: {acc_f:.3f}\n")
    print(f"{'config':22s} {'norm power':>10s} {'dAcc (CV)':>10s} {'dAcc (no CV)':>13s}")

    points = []
    for spec_cv, spec_no in zip(paper_grid_specs(use_cv=True),
                                paper_grid_specs(use_cv=False)):
        mode, m = spec_cv.default.mode, spec_cv.default.m
        accs = {}
        for cv, spec in ((True, spec_cv), (False, spec_no)):
            packed = apply_numerics(params, spec.resolve(params),
                                    act_ranges=ranges)
            accs[cv] = _accuracy(packed, cfg, xte, yte)
        power = 1 - cm.power_saving(mode, m, 64) / 100
        d_cv, d_no = 100 * (acc_f - accs[True]), 100 * (acc_f - accs[False])
        points.append((power, d_cv, f"{mode}/m{m}"))
        print(f"{mode+'/m'+str(m):22s} {power:10.3f} {d_cv:9.2f}% {d_no:12.2f}%")

    front = []
    for p in sorted(points):
        if not front or p[1] < front[-1][1]:
            front.append(p)
    print("\nPareto front (power, accuracy-loss):")
    for p, d, lbl in front:
        print(f"  {lbl:20s} power={p:.3f}  dAcc={d:.2f}%")


if __name__ == "__main__":
    main()
