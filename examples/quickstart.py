"""Quickstart: the paper's technique in 40 lines.

Trains nothing — takes a tiny randomly-initialized transformer, describes
the numerics declaratively (NumericsSpec -> PackPlan -> apply), packs it
for an approximate-multiplier MAC array (uint8 codes + control-variate
constants), and shows the CV recovering the logits that aggressive
approximation destroys.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import ApproxPolicy
from repro.launch.serve import ServeConfig, build_serving_params
from repro.models import build_model
from repro.numerics import get_preset


def main() -> None:
    cfg = dataclasses.replace(get_config("olmo-1b-reduced"), compute_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)

    ref = api.forward(params, {"tokens": toks})  # float reference

    # the spec is declarative and serializable: audit the per-layer plan
    # before packing anything (same table as `python -m repro.launch.serve plan`)
    spec = get_preset("serve-default")
    print(spec.resolve(params).table())
    print()

    print(f"{'numerics':34s} {'mean |logit err|':>18s}")
    for mode, m, cv in [
        ("exact", 0, True),          # plain int8 quantization
        ("perforated", 3, False),    # aggressive approximation, no correction
        ("perforated", 3, True),     # the paper: + control variate
        ("recursive", 4, False),
        ("recursive", 4, True),
        ("truncated", 6, False),
        ("truncated", 6, True),
    ]:
        policy = ApproxPolicy(mode, m, use_cv=cv)
        spec = get_preset("serve-default", policy=policy)
        packed = build_serving_params(params, cfg, ServeConfig(spec=spec))
        logits = api.forward(packed, {"tokens": toks})
        err = float(jnp.abs(logits - ref).mean())
        print(f"{policy.label():34s} {err:18.4f}")


if __name__ == "__main__":
    main()
