"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic stream, with the full production stack — sharded loader,
AdamW, checkpoint/restart, straggler monitoring.

Default is a 100M-class config (12L x 768) so a few hundred steps finish on
CPU in minutes-to-tens-of-minutes; pass --small for the 2-minute smoke
version.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --small --steps 60
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import ShardedLoader, SyntheticLMConfig
from repro.data.synthetic import lm_batch
from repro.launch.train import TrainConfig, init_train_state, make_train_step
from repro.runtime import RetryPolicy, StragglerMonitor, run_resilient


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("olmo-1b")
    if args.small:
        cfg = get_config("olmo-1b-reduced")
    else:  # ~100M: 12 x 768, vocab 8192
        cfg = dataclasses.replace(
            base, name="olmo-100m", n_layers=12, d_model=768, n_heads=12,
            kv_heads=12, head_dim=64, d_ff=3072, vocab=8192,
            compute_dtype="float32", remat="none")
    n_params = cfg.param_count()
    print(f"arch={cfg.name}  ~{n_params/1e6:.0f}M params")

    tcfg = TrainConfig(base_lr=3e-3, warmup_steps=20, total_steps=args.steps)
    dcfg = SyntheticLMConfig(vocab=cfg.vocab, seq_len=args.seq,
                             batch=args.batch, markov_states=64)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    monitor = StragglerMonitor()
    manager = CheckpointManager(args.ckpt_dir)
    loader = ShardedLoader(lambda s, sh, ns: lm_batch(dcfg, s, sh, ns))
    losses = []

    def wrapped(state, batch, step):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}", flush=True)
        return state

    t0 = time.time()
    run_resilient(
        init_state=lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0)),
        step_fn=wrapped,
        loader=loader,
        manager=manager,
        total_steps=args.steps,
        policy=RetryPolicy(checkpoint_every=50),
        monitor=monitor,
    )
    loader.close()
    print(f"\n{args.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"stragglers flagged: {len(monitor.flagged)}; "
          f"checkpoints at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
